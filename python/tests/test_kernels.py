"""Hypothesis sweeps: Pallas kernels vs pure-jnp references.

This is the L1 correctness signal — every kernel is checked against ref.py
across randomized shapes (paper-relevant ranges) before AOT lowering.
"""

import pytest

pytest.importorskip("jax", reason="JAX unavailable — kernel sweeps skipped")
pytest.importorskip("hypothesis", reason="hypothesis unavailable — kernel sweeps skipped")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import oats_kernels as K
from compile.kernels import ref as R

DEADLINE = None  # interpret-mode pallas is slow; disable per-case deadline


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=20, deadline=DEADLINE)
@given(m=st.integers(1, 300), n=st.integers(1, 80), seed=st.integers(0, 2**16))
def test_scale_columns_matches_ref(m, n, seed):
    w = rand(seed, m, n)
    d = jnp.abs(rand(seed + 1, n)) + 0.01
    np.testing.assert_allclose(
        K.scale_columns(w, d), R.scale_columns_ref(w, d), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=20, deadline=DEADLINE)
@given(m=st.integers(1, 300), n=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_apply_row_threshold_matches_ref(m, n, seed):
    a = rand(seed, m, n)
    t = jnp.abs(rand(seed + 1, m)) * 0.5
    np.testing.assert_allclose(
        K.apply_row_threshold(a, t), R.apply_row_threshold_ref(a, t), rtol=1e-6
    )


@settings(max_examples=15, deadline=DEADLINE)
@given(
    b=st.integers(1, 200),
    din=st.integers(1, 48),
    dout=st.integers(1, 48),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_spl_matmul_matches_ref(b, din, dout, r, seed):
    x = rand(seed, b, din)
    s = rand(seed + 1, dout, din)
    u = rand(seed + 2, dout, r)
    vt = rand(seed + 3, r, din)
    np.testing.assert_allclose(
        K.spl_matmul(x, s, u, vt), R.spl_matmul_ref(x, s, u, vt), rtol=1e-3, atol=1e-3
    )


@settings(max_examples=10, deadline=DEADLINE)
@given(
    h=st.integers(1, 4),
    s=st.integers(1, 96),
    hd=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(h, s, hd, causal, seed):
    q = rand(seed, h, s, hd)
    k = rand(seed + 1, h, s, hd)
    v = rand(seed + 2, h, s, hd)
    np.testing.assert_allclose(
        K.attention(q, k, v, causal=causal),
        R.attention_ref(q, k, v, causal=causal),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=10, deadline=DEADLINE)
@given(m=st.integers(4, 64), r=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_orthonormalize_produces_orthonormal_columns(m, r, seed):
    r = min(r, m)
    y = rand(seed, m, r)
    q = R.orthonormalize_ref(y)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(r), atol=5e-3)


@settings(max_examples=8, deadline=DEADLINE)
@given(seed=st.integers(0, 2**16))
def test_truncated_svd_exact_on_lowrank(seed):
    # Planted rank-3 matrix is recovered near-exactly.
    a = rand(seed, 24, 3) @ rand(seed + 1, 3, 20)
    omega = rand(seed + 2, 20, 3)
    u, vt = R.truncated_svd_ref(a, omega, power_iters=6)
    err = float(jnp.linalg.norm(a - u @ vt) / jnp.linalg.norm(a))
    assert err < 1e-2, err


def test_rowwise_topk_keeps_k_per_row():
    a = rand(0, 16, 32)
    out = R.rowwise_topk_threshold_ref(a, 8)
    nnz_per_row = np.asarray((out != 0).sum(axis=1))
    assert (nnz_per_row == 8).all()


def test_oats_step_residual_decreases():
    wd = rand(1, 32, 32)
    s = jnp.zeros((32, 32))
    omega = rand(2, 32, 4)
    resids = []
    for _ in range(6):
        u, vt, s = R.oats_step_ref(wd, s, omega, k=512, power_iters=4)
        resids.append(float(jnp.linalg.norm(wd - u @ vt - s)))
    assert resids[-1] <= resids[0] + 1e-5, resids


def test_vmem_footprint_estimates():
    # DESIGN.md §Perf: footprints must fit a 16 MiB VMEM budget at the
    # paper-relevant sizes.
    assert K.vmem_footprint_bytes("spl_matmul", b=128, din=1024, dout=1024, r=128) < 16 * 2**20
    assert K.vmem_footprint_bytes("attention", s=2048, hd=128) < 16 * 2**20
    assert K.vmem_footprint_bytes("scale_columns", m=4096, n=4096) < 16 * 2**20
