"""L2 model tests: shapes, parity between pallas/ref paths, training-step
behaviour, ViT, and the LAPACK-free decomposition building blocks."""

import pytest

pytest.importorskip("jax", reason="JAX unavailable — model tests skipped")

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

CFG = dict(vocab=64, d_model=32, n_heads=4, n_layers=2, d_ff=64, seq_len=16)
VCFG = dict(image_side=16, n_classes=8, d_model=32, n_heads=4, n_layers=2, d_ff=64)


def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def toks(key, b=2, s=16):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0, CFG["vocab"])


def test_param_names_cover_shapes():
    names = M.param_names(CFG["n_layers"])
    shapes = M.param_shapes(CFG)
    assert set(names) == set(shapes)
    assert names[0] == "tok_emb" and names[-1] == "head"


def test_logits_shape_and_finite():
    logits = M.lm_logits(params(), toks(1), CFG)
    assert logits.shape == (2, 16, 64)
    assert bool(jnp.isfinite(logits).all())


def test_pallas_and_ref_paths_agree():
    p = params()
    t = toks(2)
    a = M.lm_logits(p, t, CFG, use_pallas=False)
    b = M.lm_logits(p, t, CFG, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_causality():
    p = params()
    t1 = toks(3).at[:, -1].set(0)
    t2 = toks(3).at[:, -1].set(5)
    l1 = M.lm_logits(p, t1, CFG)
    l2 = M.lm_logits(p, t2, CFG)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)


def test_loss_near_log_vocab_at_init():
    t = toks(4)
    loss = float(M.lm_loss(params(), t, toks(5), CFG))
    assert abs(loss - np.log(CFG["vocab"])) < 1.0


def test_train_step_decreases_loss():
    p = params()
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    step = jnp.int32(0)
    t_in, t_out = toks(6), toks(7)
    losses = []
    fn = jax.jit(lambda p_, m_, v_, s_: M.train_step(p_, m_, v_, s_, t_in, t_out, CFG, lr=1e-3))
    for _ in range(40):
        p, m, v, step, loss = fn(p, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    assert int(step) == 40


def test_adamw_skips_decay_on_vectors():
    # ln gains should not be decayed toward zero when grads are zero-ish:
    # check decay masks by inspecting one step with zero grads is impossible
    # directly; instead verify update leaves ones-vector ln gains near 1.
    p = params()
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    p2, *_ = M.train_step(p, m, v, jnp.int32(0), toks(8), toks(9), CFG, lr=1e-3, wd=0.5)
    g0 = float(jnp.abs(p2["block0.ln1_g"] - p["block0.ln1_g"]).max())
    assert g0 < 0.1  # moved only by gradient, not by 0.5 weight decay


def test_oats_step_budget_and_convergence():
    key = jax.random.PRNGKey(3)
    wd = jax.random.normal(key, (48, 48))
    s = jnp.zeros_like(wd)
    omega = jax.random.normal(key, (48, 6))
    k = 1024
    resids = []
    for _ in range(5):
        u, vt, s = M.oats_step(wd, s, omega, k)
        resids.append(float(jnp.linalg.norm(wd - u @ vt - s)))
    per_row = k // 48
    assert int((s != 0).sum(axis=1).max()) <= per_row
    assert resids[-1] <= resids[0]


def test_vit_logits_shape():
    p = M.vit_init_params(VCFG, jax.random.PRNGKey(1))
    imgs = jax.random.uniform(jax.random.PRNGKey(2), (4, 256))
    logits = M.vit_logits(p, imgs, VCFG)
    assert logits.shape == (4, 8)
    assert bool(jnp.isfinite(logits).all())


def test_vit_train_step_decreases_loss():
    p = M.vit_init_params(VCFG, jax.random.PRNGKey(4))
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    step = jnp.int32(0)
    imgs = jax.random.uniform(jax.random.PRNGKey(5), (16, 256))
    labels = jnp.arange(16, dtype=jnp.int32) % 8
    fn = jax.jit(lambda p_, m_, v_, s_: M.vit_train_step(p_, m_, v_, s_, imgs, labels, VCFG, lr=3e-3))
    losses = []
    for _ in range(40):
        p, m, v, step, loss = fn(p, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_patchify_matches_rust_layout():
    # pixel value = row-major index; patch (0,0) must start 0,1,..; the
    # second row of the patch starts at 16 (matching rust/src/vit tests).
    img = jnp.arange(256, dtype=jnp.float32)[None, :]
    p = M._patchify(img, 16)
    assert p.shape == (1, 16, 16)
    assert float(p[0, 0, 0]) == 0.0
    assert float(p[0, 0, 1]) == 1.0
    assert float(p[0, 0, 4]) == 16.0
    assert float(p[0, 1, 0]) == 4.0
