"""Unit tests for the oats-tidy static analysis layer in ci/analysis/.

Every rule is exercised against synthetic fixture trees with a passing
and a failing snippet, the suppression mechanism is tested end to end,
and the schema lock is driven through drift in both directions — plus
in-sync checks against the real repository tree, so the acceptance
criterion "`oats_tidy.py --all` exits 0 with zero suppressions" is
itself a test. Dependency-free by design, like test_ci_gates.py.
"""

import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "ci" / "analysis"))

import cow_guard  # noqa: E402
import dim_source  # noqa: E402
import float_sort  # noqa: E402
import numerics_contract  # noqa: E402
import oats_tidy  # noqa: E402
import schema_lock  # noqa: E402
import thread_probe  # noqa: E402
import tidy_core  # noqa: E402
import trace_hygiene  # noqa: E402
import unsafe_hygiene  # noqa: E402


def make_scan(tmp_path, files):
    """Write a synthetic repo tree and return a RepoScan over it."""
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return tidy_core.RepoScan(str(tmp_path))


def rust(tmp_path, text, rel="rust/src/sample.rs"):
    return make_scan(tmp_path, {rel: text})


# ---------------------------------------------------------------------------
# lexer
# ---------------------------------------------------------------------------


def test_lexer_blanks_comments_and_strings_preserving_lines():
    text = 'let a = 1; // unsafe partial_cmp\nlet s = "mul_add";\n'
    code, comments = tidy_core.lex_rust(text)
    assert len(code) == len(text)
    assert code.count("\n") == text.count("\n")
    assert "unsafe" not in code and "partial_cmp" not in code
    assert "mul_add" not in code
    assert '"' in code, "string delimiters survive, contents do not"
    assert "unsafe partial_cmp" in comments[1]


def test_lexer_handles_nested_block_comments():
    text = "a /* outer /* inner */ still comment */ b\n"
    code, comments = tidy_core.lex_rust(text)
    assert "inner" not in code and "still" not in code
    assert code.startswith("a ") and code.rstrip().endswith("b")
    assert "inner" in comments[1]


def test_lexer_multiline_block_comment_covers_every_line():
    text = "x\n/* one\ntwo\nthree */\ny\n"
    code, comments = tidy_core.lex_rust(text)
    assert set(comments) == {2, 3, 4}
    assert "two" in comments[3]
    assert code.splitlines()[4] == "y"


def test_lexer_raw_strings_and_escapes():
    text = 'let r = r#"unsafe "quoted" here"#; let e = "a\\"unsafe";\n'
    code, _ = tidy_core.lex_rust(text)
    assert "unsafe" not in code


def test_lexer_char_literal_vs_lifetime():
    text = "fn f<'a>(x: &'a u8) { let c = 'u'; let n = '\\n'; }\n"
    code, _ = tidy_core.lex_rust(text)
    # Lifetimes survive as code; char literal contents are blanked.
    assert "'a" in code
    assert "'u'" not in code


def test_lexer_keep_strings_preserves_literals_not_comments():
    text = 'o.set("key", v); // set("not_a_key", w)\n'
    code, _ = tidy_core.lex_rust(text, keep_strings=True)
    assert '"key"' in code
    assert "not_a_key" not in code


# ---------------------------------------------------------------------------
# unsafe-hygiene
# ---------------------------------------------------------------------------

UNSAFE_BAD = """\
pub fn f(p: *mut f32) {
    unsafe { *p = 0.0; }
}
"""

UNSAFE_GOOD_ABOVE = """\
pub fn f(p: *mut f32) {
    // SAFETY: caller guarantees p is valid and exclusive.
    unsafe { *p = 0.0; }
}
"""

UNSAFE_GOOD_THROUGH_ATTRS = """\
// SAFETY: unsafe fn solely because of #[target_feature]; the dispatcher
// checks detected_isa before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn kernel() {}
"""

UNSAFE_SEVERED = """\
// SAFETY: this comment documents g, not f.
fn g() {}
unsafe fn f() {}
"""


def test_unsafe_without_safety_comment_fails(tmp_path):
    scan = rust(tmp_path, UNSAFE_BAD)
    fs = unsafe_hygiene.check(scan)
    assert len(fs) == 1
    assert fs[0].line == 2
    assert fs[0].rule == "unsafe-hygiene"


def test_unsafe_with_safety_above_passes(tmp_path):
    assert unsafe_hygiene.check(rust(tmp_path, UNSAFE_GOOD_ABOVE)) == []


def test_safety_comment_reaches_through_attributes(tmp_path):
    assert unsafe_hygiene.check(rust(tmp_path, UNSAFE_GOOD_THROUGH_ATTRS)) == []


def test_code_line_severs_safety_association(tmp_path):
    fs = unsafe_hygiene.check(rust(tmp_path, UNSAFE_SEVERED))
    assert len(fs) == 1 and fs[0].line == 3


def test_unsafe_in_comment_or_string_is_ignored(tmp_path):
    text = '// unsafe in prose\nlet s = "unsafe";\n'
    assert unsafe_hygiene.check(rust(tmp_path, text)) == []


def test_two_unsafe_tokens_one_line_one_finding(tmp_path):
    text = "unsafe fn f() { unsafe { () } }\n"
    assert len(unsafe_hygiene.check(rust(tmp_path, text))) == 1


# ---------------------------------------------------------------------------
# numerics-contract
# ---------------------------------------------------------------------------


def test_mul_add_in_kernel_path_fails(tmp_path):
    scan = rust(tmp_path, "let y = a.mul_add(b, c);\n", rel="rust/src/sparse/kern.rs")
    fs = numerics_contract.check(scan)
    assert len(fs) == 1 and "mul_add" in fs[0].message


def test_fma_intrinsic_in_tensor_fails(tmp_path):
    scan = rust(
        tmp_path,
        "let v = _mm256_fmadd_ps(a, b, c);\n",
        rel="rust/src/tensor.rs",
    )
    fs = numerics_contract.check(scan)
    assert len(fs) == 1 and "FMA" in fs[0].message


def test_fast_math_intrinsic_in_model_fails(tmp_path):
    scan = rust(tmp_path, "let y = fadd_fast(a, b);\n", rel="rust/src/model/lm.rs")
    assert len(numerics_contract.check(scan)) == 1


def test_mul_add_outside_contract_paths_is_fine(tmp_path):
    scan = rust(tmp_path, "let y = a.mul_add(b, c);\n", rel="rust/src/vit/mod.rs")
    assert numerics_contract.check(scan) == []


def test_mul_add_in_doc_comment_does_not_trip(tmp_path):
    text = "/// Unlike `mul_add`, this keeps two roundings.\nfn f() {}\n"
    scan = rust(tmp_path, text, rel="rust/src/sparse/kern.rs")
    assert numerics_contract.check(scan) == []


# ---------------------------------------------------------------------------
# float-sort
# ---------------------------------------------------------------------------


def test_partial_cmp_unwrap_in_sort_by_fails(tmp_path):
    text = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"
    fs = float_sort.check(rust(tmp_path, text))
    assert len(fs) == 1 and fs[0].rule == "float-sort"


def test_partial_cmp_unwrap_in_max_by_fails(tmp_path):
    text = "let m = xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap());\n"
    assert len(float_sort.check(rust(tmp_path, text))) == 1


def test_total_cmp_comparator_passes(tmp_path):
    text = "xs.sort_by(|a, b| a.total_cmp(b));\n"
    assert float_sort.check(rust(tmp_path, text)) == []


def test_unwrap_or_fallback_is_tolerated(tmp_path):
    text = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));\n"
    assert float_sort.check(rust(tmp_path, text)) == []


def test_partial_cmp_outside_sort_is_not_flagged(tmp_path):
    text = "let o = a.partial_cmp(&b).unwrap();\n"
    assert float_sort.check(rust(tmp_path, text)) == []


def test_multiline_comparator_is_caught(tmp_path):
    text = "xs.sort_by(|a, b| {\n    b.partial_cmp(a).unwrap()\n});\n"
    fs = float_sort.check(rust(tmp_path, text))
    assert len(fs) == 1 and fs[0].line == 2


# ---------------------------------------------------------------------------
# thread-probe
# ---------------------------------------------------------------------------


def test_available_parallelism_outside_threadpool_fails(tmp_path):
    text = "let n = std::thread::available_parallelism().unwrap();\n"
    fs = thread_probe.check(rust(tmp_path, text, rel="rust/src/bench.rs"))
    assert len(fs) == 1 and "available_threads" in fs[0].message


def test_available_parallelism_in_threadpool_passes(tmp_path):
    text = "let n = thread::available_parallelism().ok();\n"
    scan = rust(tmp_path, text, rel="rust/src/util/threadpool.rs")
    assert thread_probe.check(scan) == []


# ---------------------------------------------------------------------------
# cow-guard
# ---------------------------------------------------------------------------


def test_k_row_mut_outside_lm_fails(tmp_path):
    text = "let row = cache.k_row_mut(layer, pos);\n"
    scan = rust(tmp_path, text, rel="rust/src/coordinator/engine/mod.rs")
    fs = cow_guard.check(scan)
    assert len(fs) == 1 and "k_row_mut" in fs[0].message


def test_v_row_mut_in_lm_passes(tmp_path):
    text = "let row = self.v_row_mut(layer, pos);\n"
    scan = rust(tmp_path, text, rel="rust/src/model/lm.rs")
    assert cow_guard.check(scan) == []


def test_row_mut_mention_in_comment_passes(tmp_path):
    text = "// the engine never calls .k_row_mut( directly\nfn f() {}\n"
    scan = rust(tmp_path, text, rel="rust/src/coordinator/serve.rs")
    assert cow_guard.check(scan) == []


# ---------------------------------------------------------------------------
# dim-source
# ---------------------------------------------------------------------------

DIM_BAD_FORWARD = """\
impl Lm {
    pub fn forward(&self, cfg: &Config, x: &[f32]) -> Vec<f32> {
        let mut buf = vec![0.0; cfg.d_ff];
        buf
    }
}
"""

DIM_GOOD_FORWARD = """\
impl Lm {
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut buf = vec![0.0; self.up.out_dim()];
        buf
    }
}
"""

DIM_CONSTRUCTION_TIME = """\
impl Lm {
    pub fn init(cfg: &Config) -> Self {
        let w = vec![0.0; cfg.d_ff * cfg.d_model];
        Self { w }
    }
}
"""


def test_cfg_dim_inside_forward_body_fails(tmp_path):
    scan = rust(tmp_path, DIM_BAD_FORWARD, rel="rust/src/model/lm.rs")
    fs = dim_source.check(scan)
    assert len(fs) == 1 and fs[0].rule == "dim-source"
    assert fs[0].line == 3
    assert "cfg.d_ff" in fs[0].message and "forward" in fs[0].message


def test_layer_sourced_dims_pass(tmp_path):
    scan = rust(tmp_path, DIM_GOOD_FORWARD, rel="rust/src/model/lm.rs")
    assert dim_source.check(scan) == []


def test_construction_time_cfg_dims_are_fine(tmp_path):
    scan = rust(tmp_path, DIM_CONSTRUCTION_TIME, rel="rust/src/model/lm.rs")
    assert dim_source.check(scan) == []


def test_cfg_dims_outside_model_tree_are_fine(tmp_path):
    scan = rust(tmp_path, DIM_BAD_FORWARD, rel="rust/src/coordinator/pipeline.rs")
    assert dim_source.check(scan) == []


def test_decode_step_batch_ws_is_covered(tmp_path):
    text = DIM_BAD_FORWARD.replace("fn forward", "fn decode_step_batch_ws").replace(
        "cfg.d_ff", "cfg.d_model"
    )
    scan = rust(tmp_path, text, rel="rust/src/model/lm.rs")
    fs = dim_source.check(scan)
    assert len(fs) == 1 and "cfg.d_model" in fs[0].message
    assert "decode_step_batch_ws" in fs[0].message


def test_cfg_dim_in_comment_inside_forward_is_ignored(tmp_path):
    text = (
        "impl Lm {\n"
        "    pub fn forward(&self, x: &[f32]) -> Vec<f32> {\n"
        "        // cfg.d_ff would be wrong here: layers know their width\n"
        "        vec![0.0; self.up.out_dim()]\n"
        "    }\n"
        "}\n"
    )
    scan = rust(tmp_path, text, rel="rust/src/model/lm.rs")
    assert dim_source.check(scan) == []


# ---------------------------------------------------------------------------
# trace-hygiene
# ---------------------------------------------------------------------------

TRACE_REGISTRY = json.dumps({"names": ["engine_step", "queue_depth"]})


def trace_tree(tmp_path, text, registry=TRACE_REGISTRY):
    files = {"rust/src/sample.rs": text}
    if registry is not None:
        files["ci/analysis/trace_registry.json"] = registry
    return make_scan(tmp_path, files)


def test_registered_literal_names_pass(tmp_path):
    text = (
        'let _s = trace::span("engine_step");\n'
        'trace::counter("queue_depth", 1.0);\n'
        'let t = trace::timed("engine_step");\n'
    )
    assert trace_hygiene.check(trace_tree(tmp_path, text)) == []


def test_unregistered_name_fails(tmp_path):
    text = 'let _s = trace::span("mystery_span");\n'
    fs = trace_hygiene.check(trace_tree(tmp_path, text))
    assert len(fs) == 1 and fs[0].rule == "trace-hygiene"
    assert "not in ci/analysis/trace_registry.json" in fs[0].message


def test_non_snake_case_name_fails(tmp_path):
    text = 'trace::instant("EngineStep");\n'
    fs = trace_hygiene.check(trace_tree(tmp_path, text))
    assert len(fs) == 1 and "not snake_case" in fs[0].message


def test_runtime_built_name_fails(tmp_path):
    text = "let _s = trace::span_args(name, &tags);\n"
    fs = trace_hygiene.check(trace_tree(tmp_path, text))
    assert len(fs) == 1 and "not a string literal" in fs[0].message


def test_rustfmt_broken_call_site_is_still_read(tmp_path):
    # rustfmt puts wide call sites one-arg-per-line; the literal is found
    # across the newline.
    text = 'trace::instant_args(\n    "engine_step",\n    &[("id", 1.0)],\n);\n'
    assert trace_hygiene.check(trace_tree(tmp_path, text)) == []
    bad = text.replace("engine_step", "ghost_span")
    fs = trace_hygiene.check(trace_tree(tmp_path, bad))
    assert len(fs) == 1 and fs[0].line == 1


def test_trace_call_in_comment_is_ignored(tmp_path):
    text = '// e.g. trace::span("bogus_name") would allocate\nfn f() {}\n'
    assert trace_hygiene.check(trace_tree(tmp_path, text)) == []


def test_missing_registry_is_a_finding(tmp_path):
    text = 'let _s = trace::span("engine_step");\n'
    fs = trace_hygiene.check(trace_tree(tmp_path, text, registry=None))
    assert len(fs) == 1
    assert fs[0].path == "ci/analysis/trace_registry.json"
    assert "missing or unparseable" in fs[0].message


def test_recorder_unit_tests_are_exempt(tmp_path):
    scan = make_scan(
        tmp_path,
        {
            "rust/src/util/trace.rs": 'let _s = trace::span("unit_probe_nested");\n',
            "ci/analysis/trace_registry.json": TRACE_REGISTRY,
        },
    )
    assert trace_hygiene.check(scan) == []


def test_trace_hygiene_suppression_is_tracked(tmp_path):
    text = (
        "// tidy-allow(trace-hygiene): migration shim, registry entry follows\n"
        'let _s = trace::span("legacy_name_not_yet_registered");\n'
    )
    scan = trace_tree(tmp_path, text)
    findings = trace_hygiene.check(scan)
    used = tidy_core.apply_suppressions(findings, scan)
    assert len(findings) == 1 and findings[0].suppressed
    assert used[0][2] == "trace-hygiene"


def test_real_call_sites_all_registered():
    # Every trace:: call in the real tree resolves against the committed
    # registry — the acceptance criterion for the rule, as a test.
    scan = tidy_core.RepoScan(str(REPO))
    assert trace_hygiene.check(scan) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

SUPPRESSED_SAME_LINE = (
    "xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); "
    "// tidy-allow(float-sort): inputs clamped finite above\n"
)

SUPPRESSED_LINE_ABOVE = """\
// tidy-allow(float-sort): inputs clamped finite above
xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
"""

WRONG_RULE_SUPPRESSION = """\
// tidy-allow(unsafe-hygiene): wrong rule id
xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
"""


@pytest.mark.parametrize("text", [SUPPRESSED_SAME_LINE, SUPPRESSED_LINE_ABOVE])
def test_tidy_allow_suppresses_and_is_tracked(tmp_path, text):
    scan = rust(tmp_path, text)
    findings = float_sort.check(scan)
    used = tidy_core.apply_suppressions(findings, scan)
    assert len(findings) == 1 and findings[0].suppressed
    assert len(used) == 1
    assert used[0][2] == "float-sort"
    assert "clamped finite" in used[0][3]


def test_suppression_for_wrong_rule_does_not_apply(tmp_path):
    scan = rust(tmp_path, WRONG_RULE_SUPPRESSION)
    findings = float_sort.check(scan)
    used = tidy_core.apply_suppressions(findings, scan)
    assert used == []
    assert not findings[0].suppressed


def test_list_suppressions_finds_the_comment(tmp_path):
    scan = rust(tmp_path, SUPPRESSED_LINE_ABOVE)
    sups = oats_tidy.list_suppressions(scan)
    assert sups == [
        ("rust/src/sample.rs", 1, "float-sort", "inputs clamped finite above")
    ]


# ---------------------------------------------------------------------------
# schema-lock (synthetic tree)
# ---------------------------------------------------------------------------

EMITTER = """\
fn to_json(&self) -> Json {
    Json::obj().set("alpha", self.a).set("beta", self.b)
}
"""

GATE = """\
def main(doc):
    a = doc["alpha"]
    b = doc.get("beta", 0)
    doc["note"] = "stores are not reads"
    return a + b
"""


def lock_doc(emitter_keys, gate_reads, ignore=()):
    return {
        "emitters": {"rust/src/bench.rs": sorted(emitter_keys)},
        "gates": {
            "ci/gates/g.py": {"reads": sorted(gate_reads), "ignore": sorted(ignore)}
        },
    }


def schema_tree(tmp_path, lock, emitter=EMITTER, gate=GATE):
    return make_scan(
        tmp_path,
        {
            "rust/src/bench.rs": emitter,
            "ci/gates/g.py": gate,
            "ci/analysis/schema_lock.json": json.dumps(lock),
        },
    )


def test_schema_lock_in_sync_passes(tmp_path):
    scan = schema_tree(tmp_path, lock_doc(["alpha", "beta"], ["alpha", "beta"]))
    assert schema_lock.check(scan) == []


def test_emitted_key_missing_from_lock_fails(tmp_path):
    scan = schema_tree(tmp_path, lock_doc(["alpha"], ["alpha", "beta"]))
    msgs = [f.message for f in schema_lock.check(scan)]
    assert any('emitted key "beta" is not in the schema lock' in m for m in msgs)


def test_locked_key_no_longer_emitted_fails(tmp_path):
    lock = lock_doc(["alpha", "beta", "gamma"], ["alpha", "beta"])
    scan = schema_tree(tmp_path, lock)
    msgs = [f.message for f in schema_lock.check(scan)]
    assert any('locked key "gamma" is no longer emitted' in m for m in msgs)


def test_gate_read_missing_from_lock_fails(tmp_path):
    scan = schema_tree(tmp_path, lock_doc(["alpha", "beta"], ["alpha"]))
    msgs = [f.message for f in schema_lock.check(scan)]
    assert any('gate reads key "beta" not recorded' in m for m in msgs)


def test_locked_read_no_longer_read_fails(tmp_path):
    scan = schema_tree(tmp_path, lock_doc(["alpha", "beta"], ["alpha", "beta", "delta"]))
    msgs = [f.message for f in schema_lock.check(scan)]
    assert any('locked gate read "delta" is no longer read' in m for m in msgs)


def test_gate_read_never_emitted_fails(tmp_path):
    gate = GATE + "    c = doc['ghost']\n"
    lock = lock_doc(["alpha", "beta"], ["alpha", "beta", "ghost"])
    scan = schema_tree(tmp_path, lock, gate=gate)
    msgs = [f.message for f in schema_lock.check(scan)]
    assert any('"ghost" that no locked emitter emits' in m for m in msgs)


def test_store_subscripts_are_not_reads(tmp_path):
    # doc["note"] = ... in GATE must not register as a read.
    scan = schema_tree(tmp_path, lock_doc(["alpha", "beta"], ["alpha", "beta"]))
    text = (tmp_path / "ci/gates/g.py").read_text()
    assert "note" not in schema_lock.extract_gate_reads(text)


def test_ignore_list_waives_gate_internal_keys(tmp_path):
    gate = GATE + "    h = hist['ratios']\n"
    lock = lock_doc(["alpha", "beta"], ["alpha", "beta"], ignore=["ratios"])
    scan = schema_tree(tmp_path, lock, gate=gate)
    assert schema_lock.check(scan) == []


def test_missing_lock_is_a_finding(tmp_path):
    scan = make_scan(tmp_path, {"rust/src/bench.rs": EMITTER})
    fs = schema_lock.check(scan)
    assert len(fs) == 1 and "missing" in fs[0].message


def test_update_lock_round_trips(tmp_path):
    # Start with a drifted lock; regenerate; the tree then checks clean,
    # and the ignore list survives regeneration.
    gate = GATE + "    h = hist['ratios']\n"
    lock = lock_doc(["alpha"], ["alpha"], ignore=["ratios"])
    scan = schema_tree(tmp_path, lock, gate=gate)
    assert schema_lock.check(scan) != []
    schema_lock.write_lock(scan)
    fresh = tidy_core.RepoScan(str(tmp_path))
    assert schema_lock.check(fresh) == []
    new_lock = json.loads((tmp_path / "ci/analysis/schema_lock.json").read_text())
    assert new_lock["gates"]["ci/gates/g.py"]["ignore"] == ["ratios"]
    assert new_lock["emitters"]["rust/src/bench.rs"] == ["alpha", "beta"]


# ---------------------------------------------------------------------------
# schema-lock (real tree): the committed contract round-trips
# ---------------------------------------------------------------------------


def copy_schema_slice(tmp_path):
    """Copy the real lock + every file it names into a scratch tree."""
    lock = json.loads((REPO / "ci" / "analysis" / "schema_lock.json").read_text())
    rels = list(lock["emitters"]) + list(lock["gates"])
    for rel in rels + ["ci/analysis/schema_lock.json"]:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        dst.write_text((REPO / rel).read_text())
    return lock


def test_real_lock_matches_real_emitters_and_gates(tmp_path):
    copy_schema_slice(tmp_path)
    scan = tidy_core.RepoScan(str(tmp_path))
    assert schema_lock.check(scan) == []


def test_deleting_any_emitted_key_from_real_lock_fails(tmp_path):
    lock = copy_schema_slice(tmp_path)
    for emitter, keys in lock["emitters"].items():
        assert keys, f"lock lists no keys for {emitter}"
    # Drop one key from each emitter's locked list: every drop must fail.
    mutated = json.loads(json.dumps(lock))
    dropped = [keys.pop(0) for keys in mutated["emitters"].values()]
    (tmp_path / "ci/analysis/schema_lock.json").write_text(json.dumps(mutated))
    msgs = [f.message for f in schema_lock.check(tidy_core.RepoScan(str(tmp_path)))]
    for key in dropped:
        assert any(f'"{key}" is not in the schema lock' in m for m in msgs), key


def test_removing_a_gate_read_key_from_real_emitters_fails(tmp_path):
    lock = copy_schema_slice(tmp_path)
    # Pick a key a real gate reads that a real emitter emits, rename it in
    # the emitter source: the read-but-never-emitted check must fire.
    emitted = {k for keys in lock["emitters"].values() for k in keys}
    key = None
    for entry in lock["gates"].values():
        for k in entry["reads"]:
            if k in emitted:
                key = k
                break
        if key:
            break
    assert key is not None, "no gate-read key overlaps the emitters"
    for emitter in lock["emitters"]:
        p = tmp_path / emitter
        p.write_text(p.read_text().replace(f'.set("{key}"', f'.set("{key}_x"'))
    msgs = [f.message for f in schema_lock.check(tidy_core.RepoScan(str(tmp_path)))]
    assert any(f'"{key}" that no locked emitter emits' in m for m in msgs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_list_rules_exits_zero(capsys):
    assert oats_tidy.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in oats_tidy.RULES:
        assert rid in out


def test_cli_unknown_rule_is_usage_error(tmp_path):
    with pytest.raises(SystemExit) as e:
        oats_tidy.main(["no-such-rule", "--root", str(tmp_path)])
    assert e.value.code == 2


def test_cli_fails_then_passes_after_fix(tmp_path, capsys):
    rust(tmp_path, "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n")
    assert oats_tidy.main(["float-sort", "--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "rust/src/sample.rs:1: [float-sort]" in out
    (tmp_path / "rust/src/sample.rs").write_text(
        "xs.sort_by(|a, b| a.total_cmp(b));\n"
    )
    assert oats_tidy.main(["float-sort", "--root", str(tmp_path)]) == 0


def test_cli_reports_suppressions_but_exits_zero(tmp_path, capsys):
    rust(tmp_path, SUPPRESSED_LINE_ABOVE)
    assert oats_tidy.main(["float-sort", "--root", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "note: suppressed at rust/src/sample.rs:1" in out
    assert "1 suppressed" in out


def test_cli_update_lock_writes_file(tmp_path, capsys):
    schema_tree(tmp_path, lock_doc(["alpha"], ["alpha", "beta"]))
    assert oats_tidy.main(["--update-lock", "--root", str(tmp_path)]) == 0
    fresh = tidy_core.RepoScan(str(tmp_path))
    assert schema_lock.check(fresh) == []


# ---------------------------------------------------------------------------
# The real tree is clean — the acceptance criterion, as a test
# ---------------------------------------------------------------------------


def test_real_tree_has_no_findings_and_no_suppressions():
    scan = tidy_core.RepoScan(str(REPO))
    findings, used = oats_tidy.run_rules(scan, list(oats_tidy.RULES))
    live = [f for f in findings if not f.suppressed]
    assert live == [], f"tree has unsuppressed findings: {live}"
    banned = [u for u in used if u[2] in ("float-sort", "thread-probe")]
    assert banned == [], f"float-sort/thread-probe may not be suppressed: {banned}"
