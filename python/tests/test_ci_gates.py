"""Unit tests for the CI gate scripts in ci/gates/.

The gates used to live as heredocs inside the workflow YAML, where nothing
exercised them until a real CI run tripped (or silently failed to trip).
These tests drive both scripts against synthetic pass/fail JSON fixtures so
a broken gate fails the ordinary pytest job. Dependency-free by design —
they must run on runners without JAX.
"""

import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "ci" / "gates"))

import bench_gate  # noqa: E402
import serve_gate  # noqa: E402
import trace_gate  # noqa: E402


# ---------------------------------------------------------------------------
# bench_gate
# ---------------------------------------------------------------------------


def write_bench(dirpath, comparisons):
    doc = {"comparisons": [{"label": l, "speedup": s} for l, s in comparisons]}
    (dirpath / "BENCH_micro.json").write_text(json.dumps(doc))


GOOD_COMPARISONS = [
    ("bcsr_vs_csr(tiny)", 1.3),
    ("qbcsr_vs_bcsr(tiny)", 0.9),
    ("bcsr_simd_vs_generic(tiny)", 1.2),
    ("fused_simd_vs_generic(tiny)", 1.1),
]


def test_bench_gate_passes_good_run(tmp_path):
    write_bench(tmp_path, GOOD_COMPARISONS)
    assert bench_gate.main(["--bench-dir", str(tmp_path), "--history", str(tmp_path / "h.jsonl")]) == 0


def test_bench_gate_fails_below_fixed_floor(tmp_path):
    write_bench(tmp_path, [("bcsr_vs_csr(tiny)", 0.4)])
    assert bench_gate.main(["--bench-dir", str(tmp_path), "--history", str(tmp_path / "h.jsonl")]) == 1


def test_bench_gate_fails_when_no_comparisons_found(tmp_path):
    write_bench(tmp_path, [("unrelated_label", 2.0)])
    assert bench_gate.main(["--bench-dir", str(tmp_path), "--history", str(tmp_path / "h.jsonl")]) == 1


def test_ratchet_raises_floor_above_fixed():
    # History sustains 2.0x: the effective floor becomes 1.0x (0.5 x median),
    # above the 0.7x fixed floor, so a run at 0.8x now fails.
    entries = [{"ratios": {"bcsr_vs_csr": 2.0}} for _ in range(5)]
    floor = bench_gate.effective_floor("bcsr_vs_csr", entries)
    assert floor == pytest.approx(1.0)
    ok, failed, _ = bench_gate.gate([("bcsr_vs_csr(tiny)", 0.8)], entries)
    assert not ok and len(failed) == 1


def test_ratchet_never_lowers_fixed_floor():
    # A history of terrible ratios must not relax the fixed floor.
    entries = [{"ratios": {"bcsr_vs_csr": 0.2}} for _ in range(5)]
    assert bench_gate.effective_floor("bcsr_vs_csr", entries) == bench_gate.FLOORS["bcsr_vs_csr"]


def test_ratchet_uses_rolling_window():
    # Ancient fast history beyond the window must age out.
    entries = [{"ratios": {"bcsr_vs_csr": 4.0}}] * 5 + [
        {"ratios": {"bcsr_vs_csr": 1.0}}
    ] * bench_gate.HISTORY_WINDOW
    assert bench_gate.effective_floor("bcsr_vs_csr", entries) == pytest.approx(0.7)


def test_append_records_ratios_and_feeds_next_run(tmp_path):
    write_bench(tmp_path, GOOD_COMPARISONS)
    hist = tmp_path / "h.jsonl"
    rc = bench_gate.main(
        ["--bench-dir", str(tmp_path), "--history", str(hist), "--append", "--note", "unit"]
    )
    assert rc == 0
    entries = bench_gate.read_history(hist)
    assert len(entries) == 1
    assert entries[0]["ratios"]["bcsr_vs_csr"] == pytest.approx(1.3)
    assert entries[0]["note"] == "unit"
    # The appended entry participates in the next gate's ratchet.
    assert bench_gate.effective_floor("bcsr_vs_csr", entries) == pytest.approx(0.7)


def test_committed_history_parses_and_covers_all_floors():
    entries = bench_gate.read_history(REPO / "ci" / "bench_history.jsonl")
    assert entries, "committed bench history is empty"
    for prefix in bench_gate.FLOORS:
        assert bench_gate.history_ratios(entries, prefix), f"no history for {prefix}"
        # Seeds are modest: the fixed floors must still dominate, so CI
        # behaviour is unchanged until maintainers record faster history.
        assert bench_gate.effective_floor(prefix, entries) == bench_gate.FLOORS[prefix]


# ---------------------------------------------------------------------------
# serve_gate
# ---------------------------------------------------------------------------


def serve_doc(**overrides):
    doc = {
        "schema": "oats-serve-v1",
        "tokens_per_second": 120.0,
        "joins": 22,
        "leaves": 22,
        "requests": 24,
        "truncated": 1,
        "capacity_stopped": 1,
        "slot_occupancy": {"mean": 0.8},
        "page_occupancy": {"mean": 0.7},
        "pages_in_use_at_drain": 0,
        "ws_buffer_allocs": 9,
        "kv_arena_bytes": 1 << 20,
        "decode_batch": {"max": 4.0},
        "latency_s": {"p50": 0.01, "p95": 0.02, "p99": 0.03},
        "prefill_tokens_saved": 0,
        "shared_pages": 0,
        "cow_forks": 0,
        "completions_digest": "00c0ffee00c0ffee",
        "preemptions": 0,
        "shed": 0,
        "victim_recompute_tokens": 0,
        "goodput_under_slo": 1.0,
        "arrivals": "closed",
        "first_token_latency_interactive": {"n": 0, "p99": 0.0},
        "first_token_latency_batch": {"n": 0, "p99": 0.0},
        "first_token_latency_background": {"n": 0, "p99": 0.0},
        "queue_wait": {"n": 24, "mean": 0.002},
        "time_admit_s": 0.01,
        "time_prefill_s": 0.2,
        "time_decode_s": 0.5,
        "time_retire_s": 0.01,
        "time_step_s": 0.8,
        "kernel_time": {},
    }
    doc.update(overrides)
    return doc


def full_fleet():
    """A passing four-run fleet: whole, paged, shared, noshare."""
    return {
        "SERVE_tiny.json": serve_doc(decode_batch={"max": 3.0}),
        "SERVE_tiny_paged.json": serve_doc(decode_batch={"max": 6.0}),
        "SERVE_tiny_shared.json": serve_doc(
            prefill_tokens_saved=160, shared_pages=12, cow_forks=2
        ),
        "SERVE_tiny_noshare.json": serve_doc(),
    }


def overload_trio():
    """A passing overload + storm A/B trio (rides along with full_fleet)."""
    return {
        # 24 requests: 1 truncated, 2 shed, 21 admitted; 3 preemptions each
        # re-join their victim, so joins = 21 + 3 = 24.
        "SERVE_tiny_overload.json": serve_doc(
            joins=24,
            leaves=24,
            preemptions=3,
            victim_recompute_tokens=40,
            shed=2,
            goodput_under_slo=0.8,
            arrivals="burst:6:4",
            first_token_latency_interactive={"n": 8, "p99": 0.012},
            first_token_latency_batch={"n": 7, "p99": 0.055},
        ),
        "SERVE_tiny_storm_on.json": serve_doc(
            joins=25,
            leaves=25,
            preemptions=2,
            victim_recompute_tokens=24,
            arrivals="burst:6:4",
        ),
        "SERVE_tiny_storm_off.json": serve_doc(arrivals="burst:6:4"),
    }


def run_gate(runs, require_shared=True, require_overload=False):
    return serve_gate.gate(
        runs,
        "tiny_paged",
        "tiny_shared",
        "tiny_noshare",
        require_shared,
        require_overload=require_overload,
    )


def test_serve_gate_passes_full_fleet():
    assert run_gate(full_fleet()) == []


def test_serve_gate_catches_page_leak():
    runs = full_fleet()
    runs["SERVE_tiny_paged.json"]["pages_in_use_at_drain"] = 3
    assert any("leaked at drain" in e for e in run_gate(runs))


def test_serve_gate_catches_narrow_paged_decode():
    runs = full_fleet()
    runs["SERVE_tiny_paged.json"]["decode_batch"] = {"max": 2.0}
    assert any("decode wider" in e for e in run_gate(runs))


def test_serve_gate_catches_unequal_arena_bytes():
    runs = full_fleet()
    runs["SERVE_tiny_paged.json"]["kv_arena_bytes"] = 1 << 19
    assert any("arena bytes" in e for e in run_gate(runs))


def test_serve_gate_requires_actual_prefix_reuse():
    runs = full_fleet()
    runs["SERVE_tiny_shared.json"]["prefill_tokens_saved"] = 0
    assert any("saved no prefill" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny_shared.json"]["shared_pages"] = 0
    assert any("no shared pages" in e for e in run_gate(runs))


def test_serve_gate_requires_digest_equality():
    runs = full_fleet()
    runs["SERVE_tiny_shared.json"]["completions_digest"] = "deadbeefdeadbeef"
    assert any("digests differ" in e for e in run_gate(runs))


def test_serve_gate_rejects_uncomputed_digest():
    runs = full_fleet()
    for name in ("SERVE_tiny_shared.json", "SERVE_tiny_noshare.json"):
        runs[name]["completions_digest"] = "0" * 16
    assert any("never computed" in e for e in run_gate(runs))


def test_serve_gate_rejects_reuse_in_opted_out_run():
    runs = full_fleet()
    runs["SERVE_tiny_noshare.json"]["shared_pages"] = 4
    assert any("opted-out run reused" in e for e in run_gate(runs))


def test_serve_gate_missing_shared_pair_only_fails_when_required():
    runs = {k: v for k, v in full_fleet().items() if "shared" not in k and "noshare" not in k}
    assert any("missing tiny_shared" in e for e in run_gate(runs, require_shared=True))
    assert run_gate(runs, require_shared=False) == []


def test_serve_gate_per_run_checks_still_bite():
    runs = full_fleet()
    runs["SERVE_tiny.json"]["joins"] = 0
    assert any("join/leave" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny.json"]["capacity_stopped"] = 0
    assert any("capacity-stopped" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny.json"]["latency_s"] = {"p50": 0.03, "p95": 0.02, "p99": 0.03}
    assert any("unordered percentiles" in e for e in run_gate(runs))


def test_serve_gate_catches_bad_queue_wait_and_phases():
    runs = full_fleet()
    runs["SERVE_tiny.json"]["queue_wait"] = {"n": 7, "mean": 0.002}
    assert any("queue_wait n" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny.json"]["queue_wait"] = {"n": 24, "mean": -1.0}
    assert any("negative mean queue wait" in e for e in run_gate(runs))
    runs = full_fleet()
    for phase in ("time_admit_s", "time_prefill_s", "time_decode_s", "time_retire_s"):
        runs["SERVE_tiny.json"][phase] = 0.0
    assert any("clocks never ran" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny.json"]["time_decode_s"] = 5.0
    assert any("exceeds step wall-clock" in e for e in run_gate(runs))
    runs = full_fleet()
    runs["SERVE_tiny.json"]["kernel_time"] = {"bcsr": -0.1}
    assert any("negative kernel time" in e for e in run_gate(runs))


def test_serve_gate_passes_overload_trio():
    assert run_gate({**full_fleet(), **overload_trio()}, require_overload=True) == []


def test_serve_gate_missing_overload_trio_only_fails_when_required():
    runs = full_fleet()
    assert any("missing tiny_overload" in e for e in run_gate(runs, require_overload=True))
    assert run_gate(runs, require_overload=False) == []


def test_serve_gate_requires_preemption_and_shed_in_overload_run():
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["preemptions"] = 0
    runs["SERVE_tiny_overload.json"]["victim_recompute_tokens"] = 0
    runs["SERVE_tiny_overload.json"]["joins"] = 21
    runs["SERVE_tiny_overload.json"]["leaves"] = 21
    assert any("never preempted" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["victim_recompute_tokens"] = 0
    assert any("recomputed nothing" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["shed"] = 0
    runs["SERVE_tiny_overload.json"]["joins"] = 26
    runs["SERVE_tiny_overload.json"]["leaves"] = 26
    assert any("never shed" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["goodput_under_slo"] = 0.0
    assert any("zero goodput" in e for e in run_gate(runs))


def test_serve_gate_catches_priority_inversion():
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["first_token_latency_interactive"]["p99"] = 0.5
    assert any("priority inversion" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_overload.json"]["first_token_latency_batch"]["n"] = 0
    assert any("both interactive and batch" in e for e in run_gate(runs))


def test_serve_gate_storm_ab_must_be_digest_equal_with_shed_off():
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_storm_on.json"]["completions_digest"] = "deadbeefdeadbeef"
    assert any("preemption-on" in e and "digests differ" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_storm_off.json"]["preemptions"] = 1
    runs["SERVE_tiny_storm_off.json"]["victim_recompute_tokens"] = 8
    assert any("storm_off run preempted" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_storm_on.json"]["shed"] = 1
    runs["SERVE_tiny_storm_on.json"]["joins"] = 24
    runs["SERVE_tiny_storm_on.json"]["leaves"] = 24
    assert any("shedding off" in e for e in run_gate(runs))
    runs = {**full_fleet(), **overload_trio()}
    runs["SERVE_tiny_storm_on.json"]["kv_arena_bytes"] = 1 << 19
    assert any("storm arena bytes differ" in e for e in run_gate(runs))


def test_serve_gate_shed_accounting_must_balance():
    # A shed that the outcome counters don't cover (joins too low) trips
    # the generalized conservation check.
    runs = full_fleet()
    runs["SERVE_tiny.json"]["shed"] = 5
    runs["SERVE_tiny.json"]["joins"] = 10
    runs["SERVE_tiny.json"]["leaves"] = 10
    assert any("inconsistent outcome counters" in e for e in run_gate(runs))
    # Recompute tokens can only come from a preemption.
    runs = full_fleet()
    runs["SERVE_tiny.json"]["victim_recompute_tokens"] = 9
    assert any("recompute tokens without a preemption" in e for e in run_gate(runs))
    # Goodput is a fraction of requests.
    runs = full_fleet()
    runs["SERVE_tiny.json"]["goodput_under_slo"] = 1.4
    assert any("outside [0, 1]" in e for e in run_gate(runs))


def test_serve_gate_end_to_end_on_disk(tmp_path, capsys):
    serve_dir = tmp_path / "serve-out"
    serve_dir.mkdir()
    for name, doc in full_fleet().items():
        (serve_dir / name).write_text(json.dumps(doc))
    rc = serve_gate.main(["--serve-dir", str(serve_dir), "--require-shared"])
    assert rc == 0
    assert "4 runs checked" in capsys.readouterr().out

    (serve_dir / "SERVE_tiny_shared.json").write_text(
        json.dumps(serve_doc(prefill_tokens_saved=0, shared_pages=0))
    )
    assert serve_gate.main(["--serve-dir", str(serve_dir), "--require-shared"]) == 1


# ---------------------------------------------------------------------------
# trace_gate
# ---------------------------------------------------------------------------


def trace_event(name, ph, ts, pid=1, tid=1, **extra):
    ev = {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}
    ev.update(extra)
    return ev


def lifecycle(rid, enq, adm, ft, ret):
    return [
        trace_event(name, "i", ts, s="t", args={"id": rid})
        for name, ts in [
            ("request_enqueued", enq),
            ("request_admitted", adm),
            ("request_first_token", ft),
            ("request_retired", ret),
        ]
    ]


def trace_doc(events, dropped=0):
    return {
        "schema": "oats-trace-v1",
        "displayTimeUnit": "ms",
        "droppedEvents": dropped,
        "traceEvents": events,
    }


def good_trace():
    events = [
        trace_event("engine_step", "X", 0.0, dur=100.0),
        trace_event("prefill_chunk", "X", 10.0, dur=30.0),
        trace_event("decode_batch", "X", 50.0, dur=40.0),
        trace_event("kernel_bcsr", "X", 55.0, dur=10.0, tid=2),
        trace_event("queue_depth", "C", 5.0, args={"value": 3.0}),
    ]
    events += lifecycle(1, 1.0, 12.0, 60.0, 95.0)
    events += lifecycle(2, 2.0, 13.0, 61.0, 96.0)
    return trace_doc(events)


def trace_errs(doc, min_chains=1):
    errs, _ = trace_gate.check_trace("t.json", doc, min_chains)
    return errs


def test_trace_gate_passes_good_trace():
    assert trace_errs(good_trace()) == []


def test_trace_gate_rejects_wrong_schema_and_empty():
    assert any("unexpected schema" in e for e in trace_errs({"schema": "nope"}))
    assert any("missing or empty" in e for e in trace_errs(trace_doc([])))


def test_trace_gate_rejects_malformed_events():
    doc = trace_doc([{"name": "engine_step", "ph": "X", "ts": 0.0}])
    assert any("missing" in e for e in trace_errs(doc))
    doc = trace_doc([trace_event("engine_step", "B", 0.0)])
    assert any("unknown phase" in e for e in trace_errs(doc))
    doc = trace_doc([trace_event("engine_step", "X", -1.0, dur=5.0)])
    assert any("bad ts" in e for e in trace_errs(doc))
    doc = trace_doc([trace_event("engine_step", "X", 0.0, dur=-5.0)])
    assert any("bad dur" in e for e in trace_errs(doc))


def test_trace_gate_rejects_straddling_spans():
    doc = good_trace()
    doc["traceEvents"].append(trace_event("decode_batch", "X", 90.0, dur=20.0))
    assert any("straddles" in e for e in trace_errs(doc))
    # The same span on its own thread track nests fine.
    doc = good_trace()
    doc["traceEvents"].append(trace_event("decode_batch", "X", 90.0, dur=20.0, tid=3))
    assert trace_errs(doc) == []


def test_trace_gate_rejects_unordered_or_incomplete_chains():
    doc = good_trace()
    doc["traceEvents"] += lifecycle(3, 10.0, 5.0, 60.0, 95.0)
    assert any("admission" in e and "outside" in e for e in trace_errs(doc))
    doc = good_trace()
    doc["traceEvents"] += lifecycle(4, 10.0, 20.0, 120.0, 95.0)
    assert any("first token" in e and "outside" in e for e in trace_errs(doc))
    doc = good_trace()
    doc["traceEvents"] += [
        trace_event("request_first_token", "i", 50.0, s="t", args={"id": 5}),
        trace_event("request_enqueued", "i", 1.0, s="t", args={"id": 5}),
        trace_event("request_retired", "i", 95.0, s="t", args={"id": 5}),
    ]
    assert any("no admission" in e for e in trace_errs(doc))
    doc = good_trace()
    doc["traceEvents"].append(trace_event("request_enqueued", "i", 1.0, s="t", args={"id": 6}))
    assert any("lacks enqueued/retired" in e for e in trace_errs(doc))


def test_trace_gate_enforces_min_chains():
    assert trace_errs(good_trace(), min_chains=2) == []
    assert any("complete request chains" in e for e in trace_errs(good_trace(), min_chains=3))


def preempted_lifecycle(rid, enq, adm, pre, req, rea, ret, ft=None):
    names = [
        ("request_enqueued", enq),
        ("request_admitted", adm),
        ("preempt", pre),
        ("requeue", req),
        ("readmit_recompute", rea),
        ("request_retired", ret),
    ]
    if ft is not None:
        names.append(("request_first_token", ft))
    return [trace_event(name, "i", ts, s="t", args={"id": rid}) for name, ts in names]


def test_trace_gate_passes_a_preemption_round_trip():
    doc = good_trace()
    doc["traceEvents"] += preempted_lifecycle(7, 1.0, 12.0, 30.0, 31.0, 50.0, 95.0, ft=60.0)
    errs, summary = trace_gate.check_trace("t.json", doc, 1, 1)
    assert errs == []
    assert "1 preemption round trips" in summary


def test_trace_gate_rejects_disordered_preemption_chains():
    # Preempted before it was ever admitted.
    doc = good_trace()
    doc["traceEvents"] += preempted_lifecycle(7, 1.0, 40.0, 30.0, 41.0, 50.0, 95.0)
    assert any("preempted" in e and "before admission" in e for e in trace_errs(doc))
    # Requeue precedes the eviction that caused it.
    doc = good_trace()
    doc["traceEvents"] += preempted_lifecycle(7, 1.0, 12.0, 35.0, 30.0, 50.0, 95.0)
    assert any("requeued" in e and "before preempt" in e for e in trace_errs(doc))
    # Recompute before the victim was back in the queue.
    doc = good_trace()
    doc["traceEvents"] += preempted_lifecycle(7, 1.0, 12.0, 30.0, 45.0, 40.0, 95.0)
    assert any("readmitted" in e and "before requeue" in e for e in trace_errs(doc))
    # A preempt with no matching requeue is a half-recorded eviction.
    doc = good_trace()
    doc["traceEvents"].append(trace_event("preempt", "i", 30.0, s="t", args={"id": 8}))
    doc["traceEvents"] += lifecycle(8, 1.0, 12.0, 60.0, 95.0)
    assert any("partial preempt/requeue pair" in e for e in trace_errs(doc))


def test_trace_gate_enforces_min_preempted():
    # A clean trace with zero preemptions passes by default but fails the
    # overload bar.
    errs, _ = trace_gate.check_trace("t.json", good_trace(), 1, 0)
    assert errs == []
    errs, _ = trace_gate.check_trace("t.json", good_trace(), 1, 1)
    assert any("complete preemption chains" in e for e in errs)


def test_trace_gate_dropped_events_warn_but_pass():
    errs, summary = trace_gate.check_trace("t.json", trace_doc(good_trace()["traceEvents"], dropped=7), 1)
    assert errs == []
    assert "warning" in summary and "7 dropped" in summary


def test_trace_gate_end_to_end_on_disk(tmp_path, capsys):
    good = tmp_path / "TRACE_good.json"
    good.write_text(json.dumps(good_trace()))
    assert trace_gate.main([str(good)]) == 0
    assert "1 traces checked" in capsys.readouterr().out

    bad = tmp_path / "TRACE_bad.json"
    doc = good_trace()
    doc["traceEvents"] += lifecycle(9, 50.0, 5.0, 60.0, 95.0)
    bad.write_text(json.dumps(doc))
    assert trace_gate.main([str(good), str(bad)]) == 1

    assert trace_gate.main([str(tmp_path / "TRACE_absent.json")]) == 1
