"""Dependency-free smoke tests.

These keep `pytest python/tests` meaningful — and its exit code zero — on
runners without JAX, where the kernel/model suites self-skip at import. They
also act as a syntax gate for the L2 sources: a SyntaxError in
`python/compile/` fails here without needing JAX installed.
"""

import pathlib
import py_compile

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_compile_sources_are_valid_python(tmp_path):
    srcs = sorted((ROOT / "compile").rglob("*.py"))
    assert srcs, "python/compile sources missing"
    for i, src in enumerate(srcs):
        py_compile.compile(str(src), cfile=str(tmp_path / f"{i}.pyc"), doraise=True)


def test_expected_layout():
    for rel in ("compile/aot.py", "compile/model.py", "compile/kernels/oats_kernels.py"):
        assert (ROOT / rel).is_file(), f"missing {rel}"
