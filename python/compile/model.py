"""Layer-2 JAX model: the transformer LM and ViT whose forward/backward are
AOT-lowered to HLO artifacts executed by the rust runtime.

Architecture parity contract (verified by rust integration tests):
pre-LN blocks, eps 1e-5, tanh-GELU, causal MHA with 1/sqrt(hd) scaling,
learned positional embeddings, untied head, no linear biases. Parameter
order matches ``rust/src/model/io.rs::param_names`` exactly.
"""

import jax
import jax.numpy as jnp

from .kernels import oats_kernels as K
from .kernels import ref as R

LN_EPS = 1e-5


# ───────────────────────────── parameters ─────────────────────────────


def param_names(n_layers):
    """Canonical parameter order — mirror of rust io::param_names."""
    names = ["tok_emb", "pos_emb"]
    for b in range(n_layers):
        for t in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w_up", "w_down"]:
            names.append(f"block{b}.{t}")
    names += ["lnf_g", "lnf_b", "head"]
    return names


def param_shapes(cfg):
    """name → shape for the LM. cfg: dict with vocab, d_model, n_heads,
    n_layers, d_ff, seq_len."""
    d, dff = cfg["d_model"], cfg["d_ff"]
    shapes = {
        "tok_emb": (cfg["vocab"], d),
        "pos_emb": (cfg["seq_len"], d),
        "lnf_g": (d,),
        "lnf_b": (d,),
        "head": (cfg["vocab"], d),
    }
    for b in range(cfg["n_layers"]):
        shapes[f"block{b}.ln1_g"] = (d,)
        shapes[f"block{b}.ln1_b"] = (d,)
        shapes[f"block{b}.wq"] = (d, d)
        shapes[f"block{b}.wk"] = (d, d)
        shapes[f"block{b}.wv"] = (d, d)
        shapes[f"block{b}.wo"] = (d, d)
        shapes[f"block{b}.ln2_g"] = (d,)
        shapes[f"block{b}.ln2_b"] = (d,)
        shapes[f"block{b}.w_up"] = (dff, d)
        shapes[f"block{b}.w_down"] = (d, dff)
    return shapes


def init_params(cfg, key):
    """Initialize LM parameters (same scheme as the rust init)."""
    shapes = param_shapes(cfg)
    resid = 0.02 / (2 * cfg["n_layers"]) ** 0.5
    params = {}
    for name in param_names(cfg["n_layers"]):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("ln1_g", "ln2_g", "lnf_g")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("ln1_b", "ln2_b", "lnf_b")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = resid if name.endswith(("wo", "w_down")) else 0.02
            if name == "pos_emb":
                std = 0.01
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def params_to_list(params, n_layers):
    return [params[n] for n in param_names(n_layers)]


def list_to_params(lst, n_layers):
    return dict(zip(param_names(n_layers), lst))


# ───────────────────────────── LM forward ─────────────────────────────


def _layernorm(x, g, b):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g + b


def _block(params, b, h, n_heads, use_pallas):
    """One pre-LN transformer block. h: [B, S, d]."""
    p = lambda t: params[f"block{b}.{t}"]
    B, S, d = h.shape
    hd = d // n_heads
    x = _layernorm(h, p("ln1_g"), p("ln1_b"))
    q = x @ p("wq").T
    k = x @ p("wk").T
    v = x @ p("wv").T
    # [B, S, d] → [B, heads, S, hd]
    split = lambda t: t.reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    qh, kh, vh = split(q), split(k), split(v)
    if use_pallas:
        ctx = jax.vmap(lambda qq, kk, vv: K.attention(qq, kk, vv, causal=True))(qh, kh, vh)
    else:
        ctx = jax.vmap(lambda qq, kk, vv: R.attention_ref(qq, kk, vv, causal=True))(qh, kh, vh)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, S, d)
    h = h + ctx @ p("wo").T
    x2 = _layernorm(h, p("ln2_g"), p("ln2_b"))
    u = jax.nn.gelu(x2 @ p("w_up").T, approximate=True)
    return h + u @ p("w_down").T


def lm_logits(params, tokens, cfg, use_pallas=False):
    """tokens: [B, S] int32 → logits [B, S, vocab]."""
    B, S = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :S, :]
    for b in range(cfg["n_layers"]):
        h = _block(params, b, h, cfg["n_heads"], use_pallas)
    h = _layernorm(h, params["lnf_g"], params["lnf_b"])
    return h @ params["head"].T


def lm_loss(params, tokens, targets, cfg, use_pallas=False):
    """Mean next-token cross entropy (nats)."""
    logits = lm_logits(params, tokens, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


# ───────────────────────────── AdamW ─────────────────────────────


ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def train_step(params, m, v, step, tokens, targets, cfg, lr=3e-4, wd=0.01,
               use_pallas=False):
    """One AdamW step. params/m/v: dicts; step: scalar int32 (1-based after
    this step). Returns (params', m', v', step+1, loss)."""
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, tokens, targets, cfg, use_pallas)
    )(params)
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for n in params:
        g = grads[n]
        nm = ADAM_B1 * m[n] + (1 - ADAM_B1) * g
        nv = ADAM_B2 * v[n] + (1 - ADAM_B2) * g * g
        update = (nm / bc1) / (jnp.sqrt(nv / bc2) + ADAM_EPS)
        decay = 0.0 if params[n].ndim == 1 else wd  # no decay on ln/bias vecs
        new_p[n] = params[n] - lr * (update + decay * params[n])
        new_m[n] = nm
        new_v[n] = nv
    return new_p, new_m, new_v, step, loss


# ───────────────────────────── OATS step (L2) ─────────────────────────────


def oats_step(wd_mat, s, omega, k, power_iters=4, use_pallas=False):
    """One alternating-thresholding iteration, LAPACK-free (DESIGN.md):
    subspace-iteration truncated SVD + row-wise hard threshold.

    wd_mat: [dout, din] scaled weights; s: current sparse term; omega:
    [din, r] test matrix; k: per-layer nonzero budget (static).
    Returns (u [dout, r], vt [r, din], s_new).
    """
    u, vt = R.truncated_svd_ref(wd_mat - s, omega, power_iters)
    resid = wd_mat - u @ vt
    per_row = k // wd_mat.shape[0]
    mag = jnp.abs(resid)
    kth = jnp.sort(mag, axis=1)[:, wd_mat.shape[1] - per_row]
    if use_pallas:
        s_new = K.apply_row_threshold(resid, kth)
    else:
        s_new = R.apply_row_threshold_ref(resid, kth)
    return u, vt, s_new


# ───────────────────────────── ViT ─────────────────────────────

VIT_PATCH = 4


def vit_param_names(n_layers):
    names = ["patch_proj", "cls", "pos_emb"]
    for b in range(n_layers):
        for t in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w_up", "w_down"]:
            names.append(f"block{b}.{t}")
    names += ["lnf_g", "lnf_b", "head"]
    return names


def vit_param_shapes(cfg):
    """cfg: dict with image_side, n_classes, d_model, n_heads, n_layers, d_ff."""
    d, dff = cfg["d_model"], cfg["d_ff"]
    pe = cfg["image_side"] // VIT_PATCH
    t = pe * pe + 1
    shapes = {
        "patch_proj": (d, VIT_PATCH * VIT_PATCH),
        "cls": (d,),
        "pos_emb": (t, d),
        "lnf_g": (d,),
        "lnf_b": (d,),
        "head": (cfg["n_classes"], d),
    }
    for b in range(cfg["n_layers"]):
        shapes[f"block{b}.ln1_g"] = (d,)
        shapes[f"block{b}.ln1_b"] = (d,)
        shapes[f"block{b}.wq"] = (d, d)
        shapes[f"block{b}.wk"] = (d, d)
        shapes[f"block{b}.wv"] = (d, d)
        shapes[f"block{b}.wo"] = (d, d)
        shapes[f"block{b}.ln2_g"] = (d,)
        shapes[f"block{b}.ln2_b"] = (d,)
        shapes[f"block{b}.w_up"] = (dff, d)
        shapes[f"block{b}.w_down"] = (d, dff)
    return shapes


def vit_init_params(cfg, key):
    shapes = vit_param_shapes(cfg)
    resid = 0.02 / (2 * cfg["n_layers"]) ** 0.5
    params = {}
    for name in vit_param_names(cfg["n_layers"]):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("ln1_g", "ln2_g", "lnf_g")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("ln1_b", "ln2_b", "lnf_b")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "patch_proj":
            params[name] = 0.05 * jax.random.normal(sub, shape, jnp.float32)
        elif name == "pos_emb":
            params[name] = 0.01 * jax.random.normal(sub, shape, jnp.float32)
        else:
            std = resid if name.endswith(("wo", "w_down")) else 0.02
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def _patchify(images, side):
    """images: [B, side*side] → [B, P, patch_dim], matching rust layout."""
    B = images.shape[0]
    pe = side // VIT_PATCH
    x = images.reshape(B, pe, VIT_PATCH, pe, VIT_PATCH)
    return x.transpose(0, 1, 3, 2, 4).reshape(B, pe * pe, VIT_PATCH * VIT_PATCH)


def _vit_block(params, b, h, n_heads, use_pallas):
    p = lambda t: params[f"block{b}.{t}"]
    B, T, d = h.shape
    hd = d // n_heads
    x = _layernorm(h, p("ln1_g"), p("ln1_b"))
    split = lambda t: t.reshape(B, T, n_heads, hd).transpose(0, 2, 1, 3)
    qh = split(x @ p("wq").T)
    kh = split(x @ p("wk").T)
    vh = split(x @ p("wv").T)
    if use_pallas:
        ctx = jax.vmap(lambda qq, kk, vv: K.attention(qq, kk, vv, causal=False))(qh, kh, vh)
    else:
        ctx = jax.vmap(lambda qq, kk, vv: R.attention_ref(qq, kk, vv, causal=False))(qh, kh, vh)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(B, T, d)
    h = h + ctx @ p("wo").T
    x2 = _layernorm(h, p("ln2_g"), p("ln2_b"))
    u = jax.nn.gelu(x2 @ p("w_up").T, approximate=True)
    return h + u @ p("w_down").T


def vit_logits(params, images, cfg, use_pallas=False):
    """images: [B, side²] → class logits [B, n_classes]."""
    B = images.shape[0]
    patches = _patchify(images, cfg["image_side"])
    h = patches @ params["patch_proj"].T  # [B, P, d]
    cls = jnp.broadcast_to(params["cls"][None, None, :], (B, 1, h.shape[-1]))
    h = jnp.concatenate([cls, h], axis=1) + params["pos_emb"][None, :, :]
    for b in range(cfg["n_layers"]):
        h = _vit_block(params, b, h, cfg["n_heads"], use_pallas)
    cls_out = _layernorm(h[:, 0, :], params["lnf_g"], params["lnf_b"])
    return cls_out @ params["head"].T


def vit_loss(params, images, labels, cfg, use_pallas=False):
    logits = vit_logits(params, images, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def vit_train_step(params, m, v, step, images, labels, cfg, lr=1e-3, wd=0.01,
                   use_pallas=False):
    loss, grads = jax.value_and_grad(
        lambda p: vit_loss(p, images, labels, cfg, use_pallas)
    )(params)
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for n in params:
        g = grads[n]
        nm = ADAM_B1 * m[n] + (1 - ADAM_B1) * g
        nv = ADAM_B2 * v[n] + (1 - ADAM_B2) * g * g
        update = (nm / bc1) / (jnp.sqrt(nv / bc2) + ADAM_EPS)
        decay = 0.0 if params[n].ndim == 1 else wd
        new_p[n] = params[n] - lr * (update + decay * params[n])
        new_m[n] = nm
        new_v[n] = nv
    return new_p, new_m, new_v, step, loss
