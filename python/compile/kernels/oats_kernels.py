"""Layer-1 Pallas kernels (interpret=True — see DESIGN.md §Hardware-Adaptation).

Three kernels cover the stack's compute hot-spots:

* :func:`scale_columns` — the outlier scaling W·D (paper §2.3), elementwise
  with a broadcast vector; BlockSpec tiles stream W through VMEM row-blocks.
* :func:`apply_row_threshold` — hard-threshold application given per-row
  magnitude cutoffs (the data-parallel half of HARDTHRESHOLD; the cutoff
  search is a sort, which stays in XLA where it is already optimal).
* :func:`spl_matmul` — the serving hot path x(S + UVᵀ)ᵀ fused into one
  kernel: the sparse term is an MXU matmul over a masked dense tile (on a
  real TPU the mask becomes an N:M structured tile), the low-rank term is
  two skinny MXU matmuls through a VMEM accumulator.
* :func:`attention` — tiled causal attention for the L2 model forward.

TPU adaptation notes: the paper's CPU/GPU speedups come from *skipping*
zeros (DeepSparse) or sparse tensor cores (2:4). On TPU the MXU has no
unstructured-sparse mode, so the win OATS offers is shifting κ of the
budget into the *dense low-rank* term which the MXU executes at full
utilization — exactly what spl_matmul expresses: the low-rank factors tile
into VMEM (r ≪ d so both skinny matmuls are VMEM-resident), while the
sparse term's tile is bandwidth-bound. interpret=True keeps all of this
runnable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size for elementwise kernels (VMEM-friendly: 128×din f32).
_ROW_BLOCK = 128


def _ceil_to(x, m):
    return (x + m - 1) // m * m


def scale_columns(w, d):
    """W · diag(d) via a row-blocked Pallas kernel. w: [m, n], d: [n]."""
    m, n = w.shape
    bm = min(_ROW_BLOCK, m)

    def kernel(w_ref, d_ref, o_ref):
        o_ref[...] = w_ref[...] * d_ref[...][None, :]

    grid = ((m + bm - 1) // bm,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, d)


def apply_row_threshold(a, thresh):
    """Zero |a[i,j]| < thresh[i]; row-blocked. a: [m, n], thresh: [m]."""
    m, n = a.shape
    bm = min(_ROW_BLOCK, m)

    def kernel(a_ref, t_ref, o_ref):
        av = a_ref[...]
        o_ref[...] = jnp.where(jnp.abs(av) >= t_ref[...][:, None], av, 0.0)

    grid = ((m + bm - 1) // bm,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, thresh)


def spl_matmul(x, s, u, vt):
    """Fused x @ (S + U·Vt)ᵀ. x: [b, din], s: [dout, din], u: [dout, r],
    vt: [r, din] → [b, dout].

    Grid tiles the batch; each program holds one x-block in VMEM, runs the
    two skinny low-rank matmuls into a VMEM accumulator, then the (masked)
    dense sparse-term matmul on the MXU.
    """
    b, din = x.shape
    dout, r = u.shape
    bb = min(_ROW_BLOCK, b)

    def kernel(x_ref, s_ref, u_ref, vt_ref, o_ref):
        xb = x_ref[...]
        # low-rank path: (x @ Vtᵀ) @ Uᵀ — both VMEM-resident skinny matmuls
        t = jnp.dot(xb, vt_ref[...].T)
        lr = jnp.dot(t, u_ref[...].T)
        # sparse path: masked-dense MXU matmul (N:M tile on real hardware)
        sp = jnp.dot(xb, s_ref[...].T)
        o_ref[...] = sp + lr

    grid = ((b + bb - 1) // bb,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, din), lambda i: (i, 0)),
            pl.BlockSpec((dout, din), lambda i: (0, 0)),
            pl.BlockSpec((dout, r), lambda i: (0, 0)),
            pl.BlockSpec((r, din), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dout), x.dtype),
        interpret=True,
    )(x, s, u, vt)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, causal=True):
    """Tiled attention. q/k/v: [heads, seq, head_dim] → same shape.

    One program per (head, query-block); keys/values stream as full-length
    VMEM blocks (seq is small in this regime; a real-TPU deployment would
    add a kv-block loop with online softmax à la FlashAttention).
    """
    h, s, hd = q.shape
    bq = min(64, s)
    scale = 1.0 / (hd ** 0.5)

    def kernel(q_ref, k_ref, v_ref, o_ref):
        iq = pl.program_id(1)
        qb = q_ref[0]  # [bq, hd]
        kb = k_ref[0]  # [s, hd]
        vb = v_ref[0]  # [s, hd]
        scores = jnp.dot(qb, kb.T) * scale  # [bq, s]
        if causal:
            qpos = iq * bq + jax.lax.iota(jnp.int32, bq)[:, None]
            kpos = jax.lax.iota(jnp.int32, s)[None, :]
            scores = jnp.where(kpos <= qpos, scores, -1e30)
        m = scores.max(axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        p = p / p.sum(axis=-1, keepdims=True)
        o_ref[0] = jnp.dot(p, vb)

    grid = (h, (s + bq - 1) // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, s, hd), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda ih, iq: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, hd), q.dtype),
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(kernel_name, **dims):
    """Estimated per-program VMEM footprint (DESIGN.md §Perf: on-TPU cost is
    estimated from BlockSpec shapes, since interpret=True timings are
    CPU-numpy timings)."""
    f32 = 4
    if kernel_name == "scale_columns":
        bm, n = min(_ROW_BLOCK, dims["m"]), dims["n"]
        return f32 * (2 * bm * n + n)
    if kernel_name == "spl_matmul":
        bb = min(_ROW_BLOCK, dims["b"])
        din, dout, r = dims["din"], dims["dout"], dims["r"]
        return f32 * (bb * din + dout * din + dout * r + r * din + bb * dout)
    if kernel_name == "attention":
        bq = min(64, dims["s"])
        s, hd = dims["s"], dims["hd"]
        return f32 * (bq * hd + 2 * s * hd + bq * s + bq * hd)
    raise ValueError(kernel_name)
