"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in :mod:`oats_kernels` has a reference implementation here;
pytest + hypothesis assert allclose between the two over shape/dtype sweeps.
These references are also what the L2 model uses when ``use_pallas=False``
(the two paths lower to equivalent HLO and are cross-checked).
"""

import jax.numpy as jnp


def scale_columns_ref(w, d):
    """W · diag(d): scale column j of w by d[j] (paper §2.3 outlier scaling)."""
    return w * d[None, :]


def spl_matmul_ref(x, s, u, vt):
    """Fused sparse-plus-low-rank linear layer: x @ (S + U·Vt)ᵀ.

    x: [b, din], s: [dout, din] (sparse-as-dense), u: [dout, r], vt: [r, din].
    """
    return x @ s.T + (x @ vt.T) @ u.T


def apply_row_threshold_ref(a, thresh):
    """Zero entries with |a[i, j]| < thresh[i] (hard-threshold application)."""
    return jnp.where(jnp.abs(a) >= thresh[:, None], a, 0.0)


def rowwise_topk_threshold_ref(a, k):
    """Per-row hard threshold keeping the k largest |entries| of each row.

    Returns the thresholded matrix. Ties broken by keeping values ≥ the kth
    magnitude (may keep extra entries only when exact ties occur).
    """
    mag = jnp.abs(a)
    kth = jnp.sort(mag, axis=1)[:, a.shape[1] - k]
    return jnp.where(mag >= kth[:, None], a, 0.0)


def attention_ref(q, k, v, causal=True):
    """Multi-head scaled-dot-product attention.

    q, k, v: [heads, seq, head_dim] → [heads, seq, head_dim].
    """
    hd = q.shape[-1]
    scores = jnp.einsum("htd,hud->htu", q, k) / jnp.sqrt(jnp.float32(hd))
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("htu,hud->htd", probs, v)


def orthonormalize_ref(y, iters=12):
    """Orthonormalize the columns of y [m, r] without LAPACK custom-calls.

    Newton–Schulz iteration for the inverse matrix square root of yᵀy:
        Q = y · (yᵀy)^(-1/2).
    Pure matmuls, so the lowered HLO is loadable by xla_extension 0.5.1
    (jnp.linalg.qr would emit a lapack custom-call — see DESIGN.md).
    """
    g = y.T @ y  # [r, r]
    # Normalize so the spectrum is in (0, 1] — required for NS convergence.
    norm = jnp.trace(g) + 1e-12
    gn = g / norm
    r = y.shape[1]
    eye = jnp.eye(r, dtype=y.dtype)
    t = eye
    for _ in range(iters):
        tgt = t @ gn @ t
        t = 0.5 * t @ (3.0 * eye - tgt)
    # t ≈ gn^(-1/2) ⇒ g^(-1/2) = t / sqrt(norm)
    return y @ (t / jnp.sqrt(norm))


def truncated_svd_ref(a, omega, power_iters=4, ns_iters=12):
    """Rank-r approximation via randomized subspace iteration.

    a: [m, n]; omega: [n, r] Gaussian test matrix. Returns (u, vt) with
    L = u @ vt ≈ SVD_r(a); u has orthonormal columns.
    """
    y = a @ omega
    for _ in range(power_iters):
        q = orthonormalize_ref(y, ns_iters)
        y = a @ (a.T @ q)
    q = orthonormalize_ref(y, ns_iters)
    return q, q.T @ a


def oats_step_ref(wd, s, omega, k, power_iters=4):
    """One OATS alternating-thresholding iteration (paper Algorithm 1 body).

    L = TruncatedSVD(WD − S, r);  S' = HardThreshold_rowwise(WD − L, k).
    Returns (u, vt, s_new).
    """
    u, vt = truncated_svd_ref(wd - s, omega, power_iters)
    low = u @ vt
    s_new = rowwise_topk_threshold_ref(wd - low, k)
    return u, vt, s_new
