"""AOT lowering: JAX → HLO text artifacts + manifest, consumed by the rust
runtime (`rust/src/runtime/`).

HLO **text** is the interchange format — xla_extension 0.5.1 rejects
jax≥0.5 serialized HloModuleProtos (64-bit instruction ids), while the text
parser reassigns ids (see /opt/xla-example/README.md and aot_recipe).

Usage:
    python -m compile.aot --preset tiny --out ../artifacts
    python -m compile.aot --preset base --vit --out ../artifacts

Artifacts per preset (written to <out>/<preset>/):
    lm_fwd.hlo.txt           (params..., tokens) → (logits,)
    lm_fwd_pallas.hlo.txt    same, attention via the Pallas kernel
    lm_loss.hlo.txt          (params..., tokens, targets) → (loss,)
    train_step.hlo.txt       (params..., m..., v..., step, tokens, targets)
                             → (params'..., m'..., v'..., step', loss)
    oats_step.hlo.txt        (wd, s, omega) → (u, vt, s_new)
    spl_matmul.hlo.txt       (x, s, u, vt) → (y,)
    vit_fwd.hlo.txt / vit_train_step.hlo.txt  (with --vit)
    manifest.json            config, param order/shapes, artifact signatures
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

PRESETS = {
    # keep in sync with rust/src/config.rs::ModelConfig::preset
    "tiny": dict(vocab=256, d_model=64, n_heads=4, n_layers=2, d_ff=256, seq_len=64),
    "small": dict(vocab=256, d_model=128, n_heads=4, n_layers=4, d_ff=512, seq_len=128),
    "base": dict(vocab=512, d_model=256, n_heads=8, n_layers=6, d_ff=1024, seq_len=128),
    "large": dict(vocab=512, d_model=384, n_heads=8, n_layers=8, d_ff=1536, seq_len=128),
    "alt": dict(vocab=256, d_model=128, n_heads=4, n_layers=4, d_ff=768, seq_len=128),
}

VIT_PRESET = dict(image_side=16, n_classes=8, d_model=64, n_heads=4, n_layers=3, d_ff=256)

TRAIN_BATCH = 8
LM_LR, LM_WD = 1e-3, 0.01
VIT_LR, VIT_WD = 1e-3, 0.01
OATS_RANK_FRACTION = 0.25  # κ for the representative oats_step artifact
OATS_RATE = 0.5
OATS_POWER_ITERS = 4


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def f32_specs(shapes):
    return [spec(s) for s in shapes]


def lower_and_write(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def describe(args_specs, outs):
    """Signature record for the manifest."""
    def one(s):
        return {"shape": list(s.shape), "dtype": str(s.dtype)}
    return {"inputs": [one(s) for s in args_specs], "outputs": outs}


def build_lm_artifacts(cfg, outdir, manifest):
    n_layers = cfg["n_layers"]
    names = M.param_names(n_layers)
    shapes = M.param_shapes(cfg)
    pspecs = f32_specs([shapes[n] for n in names])
    np_ = len(names)
    B, S = TRAIN_BATCH, cfg["seq_len"]
    tok = spec((B, S), jnp.int32)

    # lm_fwd (ref attention) and lm_fwd_pallas (L1 kernel attention)
    for tag, use_pallas in [("lm_fwd", False), ("lm_fwd_pallas", True)]:
        def fwd(*args, _up=use_pallas):
            params = M.list_to_params(list(args[:np_]), n_layers)
            return (M.lm_logits(params, args[np_], cfg, use_pallas=_up),)

        n = lower_and_write(fwd, pspecs + [tok], os.path.join(outdir, f"{tag}.hlo.txt"))
        manifest["artifacts"][tag] = describe(
            pspecs + [tok], [{"shape": [B, S, cfg["vocab"]], "dtype": "float32"}]
        )
        print(f"  {tag}: {n} chars")

    # lm_loss
    def loss_fn(*args):
        params = M.list_to_params(list(args[:np_]), n_layers)
        return (M.lm_loss(params, args[np_], args[np_ + 1], cfg),)

    lower_and_write(loss_fn, pspecs + [tok, tok], os.path.join(outdir, "lm_loss.hlo.txt"))
    manifest["artifacts"]["lm_loss"] = describe(
        pspecs + [tok, tok], [{"shape": [], "dtype": "float32"}]
    )
    print("  lm_loss: ok")

    # train_step
    def step_fn(*args):
        params = M.list_to_params(list(args[:np_]), n_layers)
        m = M.list_to_params(list(args[np_:2 * np_]), n_layers)
        v = M.list_to_params(list(args[2 * np_:3 * np_]), n_layers)
        step, tokens, targets = args[3 * np_], args[3 * np_ + 1], args[3 * np_ + 2]
        p2, m2, v2, s2, loss = M.train_step(
            params, m, v, step, tokens, targets, cfg, lr=LM_LR, wd=LM_WD
        )
        return (
            tuple(M.params_to_list(p2, n_layers))
            + tuple(M.params_to_list(m2, n_layers))
            + tuple(M.params_to_list(v2, n_layers))
            + (s2, loss)
        )

    step_spec = spec((), jnp.int32)
    args = pspecs + pspecs + pspecs + [step_spec, tok, tok]
    lower_and_write(step_fn, args, os.path.join(outdir, "train_step.hlo.txt"))
    manifest["artifacts"]["train_step"] = describe(
        args,
        [{"shape": list(shapes[n]), "dtype": "float32"} for n in names] * 3
        + [{"shape": [], "dtype": "int32"}, {"shape": [], "dtype": "float32"}],
    )
    print("  train_step: ok")

    # oats_step on the attention projection shape (d × d)
    d = cfg["d_model"]
    keep = (1.0 - OATS_RATE) * d * d
    rank = max(1, int(round(OATS_RANK_FRACTION * keep / (2 * d))))
    k = int((1.0 - OATS_RANK_FRACTION) * keep)

    def oats_fn(wd_mat, s, omega):
        return M.oats_step(wd_mat, s, omega, k, power_iters=OATS_POWER_ITERS)

    oats_args = f32_specs([(d, d), (d, d), (d, rank)])
    lower_and_write(oats_fn, oats_args, os.path.join(outdir, "oats_step.hlo.txt"))
    manifest["artifacts"]["oats_step"] = describe(
        oats_args,
        [
            {"shape": [d, rank], "dtype": "float32"},
            {"shape": [rank, d], "dtype": "float32"},
            {"shape": [d, d], "dtype": "float32"},
        ],
    )
    manifest["oats_step_params"] = {"rank": rank, "nonzeros": k, "dout": d, "din": d,
                                    "power_iters": OATS_POWER_ITERS}
    print(f"  oats_step: rank={rank} k={k}")

    # fused SPL matmul kernel artifact (L1 standalone)
    from .kernels import oats_kernels as K

    bx = 32

    def spl_fn(x, s, u, vt):
        return (K.spl_matmul(x, s, u, vt),)

    spl_args = f32_specs([(bx, d), (d, d), (d, rank), (rank, d)])
    lower_and_write(spl_fn, spl_args, os.path.join(outdir, "spl_matmul.hlo.txt"))
    manifest["artifacts"]["spl_matmul"] = describe(
        spl_args, [{"shape": [bx, d], "dtype": "float32"}]
    )
    print("  spl_matmul: ok")


def build_vit_artifacts(vcfg, outdir, manifest):
    n_layers = vcfg["n_layers"]
    names = M.vit_param_names(n_layers)
    shapes = M.vit_param_shapes(vcfg)
    pspecs = f32_specs([shapes[n] for n in names])
    np_ = len(names)
    B = TRAIN_BATCH
    side2 = vcfg["image_side"] ** 2
    img = spec((B, side2))
    lbl = spec((B,), jnp.int32)

    def fwd(*args):
        params = dict(zip(names, args[:np_]))
        return (M.vit_logits(params, args[np_], vcfg),)

    lower_and_write(fwd, pspecs + [img], os.path.join(outdir, "vit_fwd.hlo.txt"))
    manifest["artifacts"]["vit_fwd"] = describe(
        pspecs + [img], [{"shape": [B, vcfg["n_classes"]], "dtype": "float32"}]
    )
    print("  vit_fwd: ok")

    def step_fn(*args):
        params = dict(zip(names, args[:np_]))
        m = dict(zip(names, args[np_:2 * np_]))
        v = dict(zip(names, args[2 * np_:3 * np_]))
        step, images, labels = args[3 * np_], args[3 * np_ + 1], args[3 * np_ + 2]
        p2, m2, v2, s2, loss = M.vit_train_step(
            params, m, v, step, images, labels, vcfg, lr=VIT_LR, wd=VIT_WD
        )
        ordered = lambda d_: tuple(d_[n] for n in names)
        return ordered(p2) + ordered(m2) + ordered(v2) + (s2, loss)

    args = pspecs + pspecs + pspecs + [spec((), jnp.int32), img, lbl]
    lower_and_write(step_fn, args, os.path.join(outdir, "vit_train_step.hlo.txt"))
    manifest["artifacts"]["vit_train_step"] = describe(
        args,
        [{"shape": list(shapes[n]), "dtype": "float32"} for n in names] * 3
        + [{"shape": [], "dtype": "int32"}, {"shape": [], "dtype": "float32"}],
    )
    print("  vit_train_step: ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--vit", action="store_true", help="also lower the ViT artifacts")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    outdir = os.path.join(args.out, args.preset)
    os.makedirs(outdir, exist_ok=True)

    manifest = {
        "preset": args.preset,
        "config": cfg,
        "param_names": M.param_names(cfg["n_layers"]),
        "param_shapes": {n: list(s) for n, s in M.param_shapes(cfg).items()},
        "train": {"batch": TRAIN_BATCH, "lr": LM_LR, "wd": LM_WD},
        "artifacts": {},
    }
    print(f"lowering preset '{args.preset}' → {outdir}")
    build_lm_artifacts(cfg, outdir, manifest)
    if args.vit:
        manifest["vit_config"] = VIT_PRESET
        manifest["vit_param_names"] = M.vit_param_names(VIT_PRESET["n_layers"])
        manifest["vit_param_shapes"] = {
            n: list(s) for n, s in M.vit_param_shapes(VIT_PRESET).items()
        }
        manifest["vit_train"] = {"batch": TRAIN_BATCH, "lr": VIT_LR, "wd": VIT_WD}
        build_vit_artifacts(VIT_PRESET, outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("manifest written")


if __name__ == "__main__":
    main()
