//! Offline stub of the `xla` (PJRT) crate.
//!
//! The serving/compression stack never needs PJRT — only training and the
//! AOT-artifact parity tests do — so environments without the real XLA
//! runtime build against this stub: the API surface `runtime/` and `train.rs`
//! consume compiles unchanged, [`Literal`] host-side plumbing is fully
//! functional, and anything that would actually execute on a device
//! ([`PjRtClient::cpu`], [`PjRtLoadedExecutable::execute`]) returns
//! [`Error::BackendUnavailable`]. Artifact-dependent tests gate on
//! `Engine::available(..)` and self-skip, so `cargo test` stays green.
//!
//! Dropping the real `xla` crate in (same names, same signatures) re-enables
//! the PJRT path without touching the callers.

use std::borrow::Borrow;

/// Stub error type mirroring `xla::Error`'s role.
#[derive(Debug, Clone)]
pub enum Error {
    /// Raised by every operation that needs a real PJRT backend.
    BackendUnavailable(&'static str),
    /// Host-side usage errors (shape mismatch, wrong element type, …).
    Usage(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::BackendUnavailable(what) => {
                write!(f, "PJRT backend unavailable (stub xla crate): {what}")
            }
            Error::Usage(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (the subset the runtime uses).
/// Public only because it appears in the sealed [`NativeType`] signatures.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Sealed conversion trait for native element types.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Elements
    where
        Self: Sized;
    #[doc(hidden)]
    fn unwrap(e: &Elements) -> Option<Vec<Self>>
    where
        Self: Sized;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Elements {
        Elements::F32(data)
    }
    fn unwrap(e: &Elements) -> Option<Vec<f32>> {
        match e {
            Elements::F32(v) => Some(v.clone()),
            Elements::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Elements {
        Elements::I32(data)
    }
    fn unwrap(e: &Elements) -> Option<Vec<i32>> {
        match e {
            Elements::I32(v) => Some(v.clone()),
            Elements::F32(_) => None,
        }
    }
}

/// Host-side literal: flat element storage plus a shape. Fully functional in
/// the stub (the runtime's Literal⇄Matrix plumbing is pure host code).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Elements,
    shape: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: T::wrap(data.to_vec()),
            shape: vec![data.len() as i64],
            tuple: None,
        }
    }

    /// Scalar literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { data: T::wrap(vec![x]), shape: vec![], tuple: None }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error::Usage(format!(
                "reshape to {dims:?} ({want} elements) from {have} elements"
            )));
        }
        Ok(Literal { data: self.data.clone(), shape: dims.to_vec(), tuple: None })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Flat element vector, checked against the requested native type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error::Usage("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(parts) => Ok(parts),
            None => Ok(vec![self]),
        }
    }
}

/// Stub HLO module handle.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    /// Parsing HLO text requires the real XLA parser.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        Err(Error::BackendUnavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub device buffer returned by `execute`.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub compiled executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub PJRT client: construction fails so callers degrade gracefully.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::BackendUnavailable(
            "PjRtClient::cpu — build against the real xla crate to run AOT artifacts",
        ))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.shape(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_counts() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).element_count(), 1);
    }

    #[test]
    fn backend_calls_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtLoadedExecutable.execute::<Literal>(&[]).unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
