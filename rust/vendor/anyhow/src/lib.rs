//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The repository builds fully offline (no registry access), so instead of
//! the crates.io `anyhow` this shim provides the surface the codebase uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters:
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole cause chain joined by `": "` (what `main.rs` relies on
//!   for one-line error reports).
//! * `Debug` (what `.unwrap()` shows) prints the message followed by a
//!   `Caused by:` list.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its source chain.

use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide error-carrying result.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error value. `chain[0]` is the outermost context, the
/// last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Sealed marker for error types `Context` accepts on the `Err` side:
    /// std errors and `anyhow::Error` itself. (Same sealed-trait trick as
    /// upstream; coherence holds because `Error` is not a `std::error::Error`.)
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> crate::Error;
    }

    impl IntoAnyhow for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::from(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the failure into [`Error`].
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoAnyhow> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_layers_and_alternate_display() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        assert_eq!(Some(7u8).context("missing").unwrap(), 7);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u8, std::io::Error> = Ok(1);
        let v = ok.with_context(|| -> String { panic!("must not evaluate") }).unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too large: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn context_on_anyhow_error_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let causes: Vec<&str> = e.chain().collect();
        assert_eq!(causes, vec!["outer", "inner"]);
    }

    #[test]
    fn debug_shows_cause_chain() {
        let r: Result<()> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }
}
