//! Micro-benchmarks for the §Perf pass: GEMM, CSR GEMM, the fused
//! sparse+low-rank apply, randomized SVD, and one full OATS iteration.
//!
//! Run: `cargo bench --bench micro`

use oats::bench::{black_box, Bench};
use oats::linalg::randomized_svd;
use oats::sparse::{Csr, LowRank, SparsePlusLowRank};
use oats::tensor::{matmul, matmul_bt, Matrix};
use oats::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut b = Bench::default();
    println!("== micro benches (d=512 layer scale) ==");

    let d = 512;
    let a = Matrix::randn(d, d, 1.0, &mut rng);
    let bm = Matrix::randn(d, d, 1.0, &mut rng);
    b.run_with_units("gemm 512x512x512", Some((2 * d * d * d) as f64), || {
        black_box(matmul(&a, &bm));
    });

    let x = Matrix::randn(64, d, 1.0, &mut rng);
    b.run_with_units("gemm_bt 64x512 · 512x512", Some((2 * 64 * d * d) as f64), || {
        black_box(matmul_bt(&x, &a));
    });

    // 50% sparse CSR
    let mut s = Matrix::randn(d, d, 1.0, &mut rng);
    for v in s.data.iter_mut() {
        if rng.f64() < 0.5 {
            *v = 0.0;
        }
    }
    let csr = Csr::from_dense(&s);
    b.run_with_units("csr(50%) matmul_xt 64xd", Some((2 * 64 * csr.nnz()) as f64), || {
        black_box(csr.matmul_xt(&x));
    });

    // OATS layer at ρ=0.5, κ=0.25: nnz = 0.375 d², r ≈ 0.0625 d
    let mut s2 = Matrix::randn(d, d, 1.0, &mut rng);
    for v in s2.data.iter_mut() {
        if rng.f64() < 0.625 {
            *v = 0.0;
        }
    }
    let r = d / 16;
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&s2),
        low_rank: Some(LowRank {
            u: Matrix::randn(d, r, 1.0, &mut rng),
            vt: Matrix::randn(r, d, 1.0, &mut rng),
        }),
    };
    b.run("spl(ρ=.5,κ=.25) apply_batch 64xd", || {
        black_box(spl.apply_batch(&x));
    });

    // single-vector decode path
    let xv: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
    let mut y = vec![0.0f32; d];
    b.run("dense matvec d=512", || {
        for (row, out) in y.iter_mut().enumerate() {
            *out = oats::tensor::dot(a.row(row), &xv);
        }
        black_box(&y);
    });
    b.run("csr(50%) matvec d=512", || {
        csr.matvec(&xv, &mut y);
        black_box(&y);
    });
    b.run("spl apply d=512", || {
        spl.apply(&xv, &mut y);
        black_box(&y);
    });

    // randomized SVD — the OATS hot spot
    let w = Matrix::randn(d, d, 1.0, &mut rng);
    for rank in [16, 32, 64] {
        let mut r2 = Rng::new(9);
        b.run(&format!("rsvd d=512 r={rank} p=2"), || {
            black_box(randomized_svd(&w, rank, 8, 2, &mut r2));
        });
    }

    // one full OATS iteration at layer scale
    let p = oats::compress::params::solve(d, d, 0.5, 0.25);
    let mut r3 = Rng::new(11);
    b.run("oats 1 iter d=512 (ρ=.5 κ=.25)", || {
        black_box(oats::compress::oats::alternating_thresholding(
            &w,
            1,
            p.rank,
            p.nonzeros,
            oats::config::SparsityPattern::RowWise,
            false,
            None,
            &mut r3,
        ));
    });
}
