//! Micro-benchmarks for the §Perf pass: GEMM, the sparse kernel family
//! (scalar CSR vs tiled BCSR vs i8-quantized BCSR vs fused
//! sparse+low-rank), randomized SVD, and one full OATS iteration.
//!
//! Run: `cargo bench --bench micro` (add `-- --quick` for the CI smoke
//! sizing). Emits `BENCH_micro.json` (see `$OATS_BENCH_DIR`), including
//! named csr→bcsr and bcsr→qbcsr speedup comparisons at 50–70 % sparsity
//! on a realistic layer shape (2048×2048, batch 8), SIMD-dispatch vs
//! generic-build comparisons for the register-blocked microkernels, and
//! `metrics` entries recording the bcsr vs qbcsr byte footprints plus the
//! microkernel's `simd_dispatch`/`lanes` telemetry. CI's perf gate reads
//! the csr→bcsr, bcsr→qbcsr, sliced-vs-dense, and *_simd_vs_generic
//! `comparisons[].speedup` values against conservative floors.

use oats::bench::{black_box, Bench};
use oats::linalg::randomized_svd;
use oats::sparse::microkernel::{self, with_isa, Isa, LANE_WIDTHS};
use oats::sparse::{Bcsr, Csr, LowRank, PackOptions, PackedLinear, QBcsr, SparsePlusLowRank};
use oats::tensor::{matmul, matmul_bt, Matrix};
use oats::util::prng::Rng;
use oats::util::prop::random_sparse;

/// Kernel-family comparison on one layer shape: dense GEMM vs scalar CSR vs
/// tiled BCSR vs i8-quantized BCSR vs the fused sparse+low-rank paths.
fn kernel_comparison(b: &mut Bench, d: usize, batch: usize, rng: &mut Rng) {
    println!("-- kernel comparison {d}x{d}, batch {batch} --");
    let x = Matrix::randn(batch, d, 1.0, rng);
    let w = Matrix::randn(d, d, 1.0, rng);
    let dense_name = format!("dense gemm_bt {d}x{d} b{batch}");
    let flops = (2 * batch * d * d) as f64;
    b.run_with_units(&dense_name, Some(flops), || {
        black_box(matmul_bt(&x, &w));
    });

    for pct in [50u32, 60, 70] {
        let s = random_sparse(d, d, pct as f64 / 100.0, rng);
        let csr = Csr::from_dense(&s);
        let bcsr = Bcsr::from_dense(&s);
        let qbcsr = QBcsr::quantize(&bcsr);
        let macs = (2 * batch * csr.nnz()) as f64;
        let csr_name = format!("csr({pct}%) matmul_xt {d}x{d} b{batch}");
        let bcsr_name = format!("bcsr({pct}%) matmul_xt {d}x{d} b{batch}");
        let qbcsr_name = format!("qbcsr({pct}%) matmul_xt {d}x{d} b{batch}");
        b.run_with_units(&csr_name, Some(macs), || {
            black_box(csr.matmul_xt(&x));
        });
        b.run_with_units(&bcsr_name, Some(macs), || {
            black_box(bcsr.matmul_xt(&x));
        });
        b.run_with_units(&qbcsr_name, Some(macs), || {
            black_box(qbcsr.matmul_xt(&x));
        });
        let _ = b.compare(&format!("bcsr_vs_csr_{pct}pct_{d}_b{batch}"), &csr_name, &bcsr_name);
        let _ = b.compare(&format!("bcsr_vs_dense_{pct}pct_{d}_b{batch}"), &dense_name, &bcsr_name);
        let _ = b.compare(&format!("qbcsr_vs_bcsr_{pct}pct_{d}_b{batch}"), &bcsr_name, &qbcsr_name);
        // Memory-footprint comparison of the two tile formats (i8 values
        // plus one f32 scale per tile vs f32 values).
        b.metric(&format!("bcsr_bytes_{pct}pct_{d}"), bcsr.memory_bytes() as f64);
        b.metric(&format!("qbcsr_bytes_{pct}pct_{d}"), qbcsr.memory_bytes() as f64);
        let ratio = qbcsr.memory_bytes() as f64 / bcsr.memory_bytes() as f64;
        b.metric(&format!("qbcsr_vs_bcsr_bytes_ratio_{pct}pct_{d}"), ratio);
    }

    // The OATS operating point ρ=0.5, κ=0.25: nnz = 0.375 d², r = d/16 —
    // unfused (scalar CSR + two GEMMs) vs the fused tiled path.
    let s = random_sparse(d, d, 0.625, rng);
    let r = d / 16;
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&s),
        low_rank: Some(LowRank {
            u: Matrix::randn(d, r, 1.0, rng),
            vt: Matrix::randn(r, d, 1.0, rng),
        }),
    };
    let packed = PackedLinear::from_spl(&spl, batch);
    println!("  plan: {}", packed.plan.describe());
    let unfused_name = format!("spl unfused(csr+gemm) {d}x{d} b{batch}");
    let fused_name = format!("spl fused({}) {d}x{d} b{batch}", packed.plan.choice.name());
    b.run(&unfused_name, || {
        black_box(spl.apply_batch(&x));
    });
    b.run(&fused_name, || {
        black_box(packed.forward(&x));
    });
    let _ = b.compare(&format!("fused_vs_unfused_{d}_b{batch}"), &unfused_name, &fused_name);

    // The same operating point through the i8-quantized tiles (low-rank
    // term stays f32), plan telemetry included.
    let qpacked = PackedLinear::from_spl_with(&spl, &PackOptions::quantized(batch));
    println!("  plan: {}", qpacked.plan.describe());
    let qfused_name = format!("spl fused-q({}) {d}x{d} b{batch}", qpacked.plan.choice.name());
    b.run(&qfused_name, || {
        black_box(qpacked.forward(&x));
    });
    let _ = b.compare(&format!("qfused_vs_fused_{d}_b{batch}"), &fused_name, &qfused_name);
}

/// The SIMD-dispatch comparison: the same kernels with the lane fold
/// pinned to the generic (autovectorized) build vs the runtime-dispatched
/// build (`avx2,fma` clones where detected). On hosts without AVX2 both
/// sides run identical code and the speedup sits at ~1.0×; CI floors these
/// labels conservatively so a catastrophic dispatch regression fails.
fn simd_comparison(b: &mut Bench, d: usize, batch: usize, rng: &mut Rng) {
    let isa = microkernel::detected_isa().name();
    println!("-- simd dispatch ({isa}) {d}x{d}, batch {batch} --");
    let s = random_sparse(d, d, 0.5, rng);
    let x = Matrix::randn(batch, d, 1.0, rng);
    let bcsr = Bcsr::from_dense(&s);
    let gen_name = format!("bcsr(50%) generic-isa {d}x{d} b{batch}");
    let simd_name = format!("bcsr(50%) simd-isa {d}x{d} b{batch}");
    b.run(&gen_name, || {
        with_isa(Isa::Generic, || {
            black_box(bcsr.matmul_xt(&x));
        });
    });
    b.run(&simd_name, || {
        black_box(bcsr.matmul_xt(&x));
    });
    let _ = b.compare(&format!("bcsr_simd_vs_generic_{d}_b{batch}"), &gen_name, &simd_name);

    let r = d / 16;
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&random_sparse(d, d, 0.625, rng)),
        low_rank: Some(LowRank {
            u: Matrix::randn(d, r, 1.0, rng),
            vt: Matrix::randn(r, d, 1.0, rng),
        }),
    };
    let packed = PackedLinear::from_spl(&spl, batch);
    let gen_fused = format!("spl fused generic-isa {d}x{d} b{batch}");
    let simd_fused = format!("spl fused simd-isa {d}x{d} b{batch}");
    b.run(&gen_fused, || {
        with_isa(Isa::Generic, || {
            black_box(packed.forward(&x));
        });
    });
    b.run(&simd_fused, || {
        black_box(packed.forward(&x));
    });
    let _ = b.compare(&format!("fused_simd_vs_generic_{d}_b{batch}"), &gen_fused, &simd_fused);
}

/// Rotate-and-slice vs dense on the FFN "down" shape: a sliced layer is a
/// plain GEMM in a narrower shape, so the win tracks the deleted d_ff
/// channels — and the Xᵀ panel the batched kernel streams per call
/// shrinks by the same factor. CI floors the `sliced_vs_dense`
/// comparisons and the footprint metrics record the panel shrinkage.
fn sliced_comparison(b: &mut Bench, d: usize, d_ff: usize, batch: usize, rng: &mut Rng) {
    use oats::compress::slice::{select_channels, select_cols, SliceMap};
    println!("-- sliced vs dense {d}x{d_ff} (down proj), batch {batch} --");
    let w = Matrix::randn(d, d_ff, 1.0, rng);
    let x = Matrix::randn(batch, d_ff, 1.0, rng);
    let dense = PackedLinear::from_dense(&w, batch);
    let dense_name = format!("dense down {d}x{d_ff} b{batch}");
    b.run(&dense_name, || {
        black_box(dense.forward(&x));
    });
    let dense_panel = (4 * batch * d_ff) as f64;
    b.metric(&format!("dense_xt_panel_bytes_{d_ff}_b{batch}"), dense_panel);

    for pct in [25u32, 50] {
        // Synthetic descending energies: the kept set is the first
        // (1 − rate)·d_ff channels, exactly what the energy ranking
        // produces on a layer whose leading channels dominate.
        let energies: Vec<f64> = (0..d_ff).map(|j| (d_ff - j) as f64).collect();
        let map = select_channels(&energies, pct as f64 / 100.0);
        let ws = select_cols(&w, &map.kept);
        let xs = select_cols(&x, &map.kept);
        let keep = map.len();
        let packed = PackedLinear::from_sliced(&ws, map, SliceMap::identity(d), batch);
        println!("  plan: {}", packed.plan.describe());
        let name = format!("sliced({pct}%) down {d}x{keep} b{batch}");
        b.run(&name, || {
            black_box(packed.forward(&xs));
        });
        let _ =
            b.compare(&format!("sliced_vs_dense_{pct}pct_{d_ff}_b{batch}"), &dense_name, &name);
        let panel = (4 * batch * keep) as f64;
        b.metric(&format!("sliced_xt_panel_bytes_{pct}pct_{d_ff}_b{batch}"), panel);
        b.metric(
            &format!("sliced_panel_shrink_{pct}pct_{d_ff}_b{batch}"),
            panel / dense_panel,
        );
    }
}

/// Tracing-overhead comparison: the fused serving kernel with the trace
/// recorder disabled vs enabled. The disabled side pays one relaxed atomic
/// load per dispatch; the enabled side adds the clock reads and the ring
/// push. CI floors the `trace_overhead` comparison so an accidentally
/// heavy span site (allocation, locking) fails the perf gate. The drained
/// events are exported as `TRACE_micro.json`, so the bench artifacts
/// always include a small loadable example trace.
fn trace_overhead(b: &mut Bench, d: usize, batch: usize, rng: &mut Rng) {
    use oats::util::trace;
    println!("-- trace overhead {d}x{d}, batch {batch} --");
    let x = Matrix::randn(batch, d, 1.0, rng);
    let r = d / 16;
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&random_sparse(d, d, 0.625, rng)),
        low_rank: Some(LowRank {
            u: Matrix::randn(d, r, 1.0, rng),
            vt: Matrix::randn(r, d, 1.0, rng),
        }),
    };
    let packed = PackedLinear::from_spl(&spl, batch);
    let off_name = format!("spl fused trace-off {d}x{d} b{batch}");
    let on_name = format!("spl fused trace-on {d}x{d} b{batch}");
    b.run(&off_name, || {
        black_box(packed.forward(&x));
    });
    trace::set_enabled(true);
    b.run(&on_name, || {
        black_box(packed.forward(&x));
    });
    trace::set_enabled(false);
    let _ = b.compare(&format!("trace_overhead_fused_{d}_b{batch}"), &off_name, &on_name);
    let events = trace::drain();
    let dir = std::env::var("OATS_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join("TRACE_micro.json");
    trace::write_chrome_trace(&path, &events).expect("write TRACE_micro.json");
    println!("  trace: {} events → {}", events.len(), path.display());
}

fn main() {
    let mut rng = Rng::new(1);
    let mut b = Bench::from_env();
    println!("== micro benches (d=512 layer scale) ==");
    // Record the microkernel's dispatch decision in the JSON: which ISA
    // the lane kernels run through (1.0 = avx2+fma clones) and the lane
    // ladder the register-blocked fold uses.
    println!("microkernel dispatch: {}", microkernel::detected_isa().name());
    let simd = if microkernel::detected_isa() == Isa::Avx2Fma { 1.0 } else { 0.0 };
    b.metric("simd_dispatch", simd);
    b.metric("lanes", LANE_WIDTHS[0] as f64);

    let d = 512;
    let a = Matrix::randn(d, d, 1.0, &mut rng);
    let bm = Matrix::randn(d, d, 1.0, &mut rng);
    b.run_with_units("gemm 512x512x512", Some((2 * d * d * d) as f64), || {
        black_box(matmul(&a, &bm));
    });

    let x = Matrix::randn(64, d, 1.0, &mut rng);
    b.run_with_units("gemm_bt 64x512 · 512x512", Some((2 * 64 * d * d) as f64), || {
        black_box(matmul_bt(&x, &a));
    });

    // single-vector decode path at layer scale
    let s = random_sparse(d, d, 0.5, &mut rng);
    let csr = Csr::from_dense(&s);
    let bcsr = Bcsr::from_dense(&s);
    let xv: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
    let mut y = vec![0.0f32; d];
    b.run("dense matvec d=512", || {
        for (row, out) in y.iter_mut().enumerate() {
            *out = oats::tensor::dot(a.row(row), &xv);
        }
        black_box(&y);
    });
    b.run("csr(50%) matvec d=512", || {
        csr.matvec(&xv, &mut y);
        black_box(&y);
    });
    b.run("bcsr(50%) matvec d=512", || {
        bcsr.matvec(&xv, &mut y);
        black_box(&y);
    });
    let qbcsr = QBcsr::quantize(&bcsr);
    b.run("qbcsr(50%) matvec d=512", || {
        qbcsr.matvec(&xv, &mut y);
        black_box(&y);
    });
    let r = d / 16;
    let spl = SparsePlusLowRank {
        sparse: Csr::from_dense(&random_sparse(d, d, 0.625, &mut rng)),
        low_rank: Some(LowRank {
            u: Matrix::randn(d, r, 1.0, &mut rng),
            vt: Matrix::randn(r, d, 1.0, &mut rng),
        }),
    };
    b.run("spl apply d=512", || {
        spl.apply(&xv, &mut y);
        black_box(&y);
    });

    // The kernel-family comparisons the dispatch layer is built on:
    // a serving-sized layer (2048², batch 8) plus the d=512 scale.
    kernel_comparison(&mut b, 512, 8, &mut rng);
    kernel_comparison(&mut b, 2048, 8, &mut rng);

    // Register-blocked SIMD dispatch vs the generic build, serving-sized.
    simd_comparison(&mut b, 2048, 8, &mut rng);

    // Rotate-and-slice vs dense on the FFN down-projection shape.
    sliced_comparison(&mut b, 512, 2048, 8, &mut rng);

    // Trace-recorder overhead on the fused serving kernel.
    trace_overhead(&mut b, 512, 8, &mut rng);

    // randomized SVD — the OATS compression hot spot
    let w = Matrix::randn(d, d, 1.0, &mut rng);
    for rank in [16, 32, 64] {
        let mut r2 = Rng::new(9);
        b.run(&format!("rsvd d=512 r={rank} p=2"), || {
            black_box(randomized_svd(&w, rank, 8, 2, &mut r2));
        });
    }

    // one full OATS iteration at layer scale
    let p = oats::compress::params::solve(d, d, 0.5, 0.25);
    let mut r3 = Rng::new(11);
    b.run("oats 1 iter d=512 (ρ=.5 κ=.25)", || {
        black_box(oats::compress::oats::alternating_thresholding(
            &w,
            1,
            p.rank,
            p.nonzeros,
            oats::config::SparsityPattern::RowWise,
            false,
            None,
            &mut r3,
        ));
    });

    b.write_json("micro").expect("write BENCH_micro.json");
}
