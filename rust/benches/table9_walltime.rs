//! Table 9 bench: wall-clock seconds per OATS alternating-thresholding
//! iteration per transformer block, across the model presets (the paper's
//! A40 numbers scale with d_out·d_in·r; ours must show the same scaling).
//!
//! Run: `cargo bench --bench table9_walltime`

use oats::experiments::speed::walltime_table;

fn main() {
    let t = walltime_table(false).unwrap();
    t.print();
    println!("\nScaling check: s/iter should grow ~with d²·(d/16) across presets");
}
