//! Table 9 bench: wall-clock seconds per OATS alternating-thresholding
//! iteration per transformer block, across the model presets (the paper's
//! A40 numbers scale with d_out·d_in·r; ours must show the same scaling).
//! Emits `BENCH_table9.json` (`oats-bench-v1`): one result per
//! (preset, serial|parallel) cell plus `t9_<preset>_parallel_vs_serial`
//! speedup comparisons.
//!
//! Run: `cargo bench --bench table9_walltime [-- --quick]`

use oats::bench::{quick_mode, Bench};
use oats::experiments::speed::{walltime_rows, walltime_table_from_rows};

fn main() {
    let quick = quick_mode();
    let mut b = Bench::from_env();
    // One measurement pass feeds both the paper-style table and the JSON.
    let rows = walltime_rows(quick).unwrap();
    for row in &rows {
        let serial = format!("t9/{}/serial", row.preset);
        let parallel = format!("t9/{}/parallel4", row.preset);
        b.record_sample(&serial, row.serial_s_per_iter, None);
        b.record_sample(&parallel, row.parallel_s_per_iter, None);
        b.compare(&format!("t9_{}_parallel_vs_serial", row.preset), &serial, &parallel);
    }
    walltime_table_from_rows(&rows).print();
    println!("\nScaling check: s/iter should grow ~with d²·(d/16) across presets");
    b.write_json("table9").expect("bench json");
}
