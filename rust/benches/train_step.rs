//! §Perf bench: seconds per PJRT `train_step` execution, per preset.
//! Measures the rust-side driver overhead (literal plumbing) + XLA compute.
//!
//! Run: `cargo bench --bench train_step`

use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::runtime::Engine;
use oats::train::Trainer;
use std::path::PathBuf;

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for preset in ["tiny", "small"] {
        let dir = root.join(preset);
        if !Engine::available(&dir) {
            eprintln!("SKIP {preset}: artifacts missing");
            continue;
        }
        let engine = Engine::load(&dir).unwrap();
        let cfg = engine.model_config().unwrap();
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 1));
        let mut trainer = Trainer::new(engine, 1).unwrap();
        // warmup (includes XLA compile)
        let _ = trainer.train(&corpus, 3).unwrap();
        let n = 30;
        let t0 = std::time::Instant::now();
        let _ = trainer.train(&corpus, n).unwrap();
        let dt = t0.elapsed().as_secs_f64() / n as f64;
        println!("{preset}: {:.1} ms/step ({n} steps)", dt * 1e3);
    }
}
