//! Table 14 bench: long-sequence generation throughput — the regime where
//! compute (not weight bandwidth) dominates and the OATS/unstructured gap
//! narrows, as in the paper's 256-token appendix experiment.
//!
//! Run: `cargo bench --bench table14_seq_throughput`

use oats::calib::CalibSet;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::experiments::speed::sequence_throughput;
use oats::model::TransformerLM;
use oats::report::{speedup, Table};

fn main() {
    let cfg = ModelConfig::preset("small").unwrap();
    let model = TransformerLM::init(&cfg, 7);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 1));
    let calib = CalibSet::sample(&corpus, 8, 32, 8);
    let seq = cfg.seq_len - 4;

    let mut t = Table::new(
        "Table 14 (bench) — long-sequence throughput, 'small' preset",
        &["Compression", "Method", "tokens/s", "Speedup"],
    );
    let dense_tp = sequence_throughput(&model, seq);
    t.row(vec!["0%".into(), "Dense".into(), format!("{dense_tp:.1}"), speedup(1.0)]);

    for rate in [0.3, 0.4, 0.5] {
        for (method, kappa, label) in [
            (Method::Wanda, 0.0, "Unstructured"),
            (Method::Oats, 0.25, "OATS"),
        ] {
            let cc = CompressConfig {
                method,
                rate,
                rank_ratio: kappa,
                iters: 8,
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cc, 6).unwrap();
            let tp = sequence_throughput(&cm, seq);
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                label.into(),
                format!("{tp:.1}"),
                speedup(tp / dense_tp),
            ]);
        }
    }
    t.print();
}
