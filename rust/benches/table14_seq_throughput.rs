//! Table 14 bench: long-sequence generation throughput — the regime where
//! compute (not weight bandwidth) dominates and the OATS/unstructured gap
//! narrows, as in the paper's 256-token appendix experiment. Emits
//! `BENCH_table14.json` (`oats-bench-v1`): one result per (ρ, method)
//! cell with tokens/s throughput plus `*_vs_dense` speedup comparisons.
//!
//! Run: `cargo bench --bench table14_seq_throughput [-- --quick]`

use oats::bench::{quick_mode, Bench};
use oats::calib::CalibSet;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::experiments::speed::sequence_walltime;
use oats::model::TransformerLM;
use oats::report::{speedup, Table};

fn main() {
    let quick = quick_mode();
    let preset = if quick { "tiny" } else { "small" };
    let cfg = ModelConfig::preset(preset).unwrap();
    let model = TransformerLM::init(&cfg, 7);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 1));
    let calib = CalibSet::sample(&corpus, 8, 32, 8);
    let seq = if quick { cfg.seq_len / 2 } else { cfg.seq_len - 4 };

    let mut b = Bench::from_env();
    let mut t = Table::new(
        &format!("Table 14 (bench) — long-sequence throughput, '{preset}' preset"),
        &["Compression", "Method", "tokens/s", "Speedup"],
    );
    let (dense_s, dense_n) = sequence_walltime(&model, seq);
    b.record_sample("t14/dense", dense_s, Some(dense_n as f64));
    let dense_tp = dense_n as f64 / dense_s;
    t.row(vec!["0%".into(), "Dense".into(), format!("{dense_tp:.1}"), speedup(1.0)]);

    for rate in [0.3, 0.4, 0.5] {
        for (method, kappa, label, tag) in [
            (Method::Wanda, 0.0, "Unstructured", "unstructured"),
            (Method::Oats, 0.25, "OATS", "oats"),
        ] {
            let cc = CompressConfig {
                method,
                rate,
                rank_ratio: kappa,
                iters: if quick { 4 } else { 8 },
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cc, 6).unwrap();
            let (secs, n) = sequence_walltime(&cm, seq);
            let pct = (rate * 100.0) as u64;
            let name = format!("t14/{tag}@{pct}pct");
            b.record_sample(&name, secs, Some(n as f64));
            b.compare(&format!("t14_{tag}_{pct}pct_vs_dense"), "t14/dense", &name);
            let tp = n as f64 / secs;
            t.row(vec![
                format!("{pct}%"),
                label.into(),
                format!("{tp:.1}"),
                speedup(tp / dense_tp),
            ]);
        }
    }
    t.print();
    b.write_json("table14").expect("bench json");
}
