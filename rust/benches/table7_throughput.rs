//! Table 7 bench: single-token CPU serving throughput — dense vs
//! unstructured pruning vs OATS, at ρ ∈ {0.3, 0.4, 0.5}.
//!
//! Weight *values* don't affect kernel speed, so this bench compresses a
//! randomly-initialized `small` model (no training required) and measures
//! the KV-cached decode loop through the serving engine.
//!
//! Run: `cargo bench --bench table7_throughput`

use oats::calib::CalibSet;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::experiments::speed::decode_throughput;
use oats::model::TransformerLM;
use oats::report::{speedup, Table};

fn main() {
    let cfg = ModelConfig::preset("small").unwrap();
    let model = TransformerLM::init(&cfg, 7);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 1));
    let calib = CalibSet::sample(&corpus, 8, 32, 8);

    let mut t = Table::new(
        "Table 7 (bench) — single-token throughput, 'small' preset",
        &["Compression", "Method", "tokens/s", "Speedup"],
    );
    let dense_tp = decode_throughput(&model, 48, 4);
    t.row(vec!["0%".into(), "Dense".into(), format!("{dense_tp:.1}"), speedup(1.0)]);

    for rate in [0.3, 0.4, 0.5] {
        for (method, kappa, label) in [
            (Method::Wanda, 0.0, "Unstructured"),
            (Method::Oats, 0.25, "OATS"),
        ] {
            let cc = CompressConfig {
                method,
                rate,
                rank_ratio: kappa,
                iters: 8,
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cc, 6).unwrap();
            let tp = decode_throughput(&cm, 48, 4);
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                label.into(),
                format!("{tp:.1}"),
                speedup(tp / dense_tp),
            ]);
        }
    }
    t.print();
}
