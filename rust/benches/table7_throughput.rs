//! Table 7 bench: single-token CPU serving throughput — dense vs
//! unstructured pruning vs OATS, at ρ ∈ {0.3, 0.4, 0.5} — through the
//! continuous-batching serve engine.
//!
//! Weight *values* don't affect kernel speed, so this bench compresses a
//! randomly-initialized model (no training required) and measures the
//! KV-cached decode loop through the serving engine. Results are emitted
//! as `BENCH_table7.json` (`oats-bench-v1`): one result per (ρ, method)
//! cell with tokens/s throughput, plus `*_vs_dense` speedup comparisons,
//! so serve-perf history accumulates alongside the micro-bench JSON.
//!
//! Run: `cargo bench --bench table7_throughput [-- --quick]`

use oats::bench::{quick_mode, Bench};
use oats::calib::CalibSet;
use oats::config::{CompressConfig, Method, ModelConfig};
use oats::coordinator::pipeline::compress_clone;
use oats::data::{CorpusConfig, SyntheticCorpus};
use oats::experiments::speed::decode_stats;
use oats::model::TransformerLM;
use oats::report::{speedup, Table};

fn main() {
    let quick = quick_mode();
    let preset = if quick { "tiny" } else { "small" };
    let (n_req, gen) = if quick { (16, 4) } else { (48, 4) };
    let cfg = ModelConfig::preset(preset).unwrap();
    let model = TransformerLM::init(&cfg, 7);
    let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 1));
    let calib = CalibSet::sample(&corpus, 8, 32, 8);

    let mut b = Bench::from_env();
    let mut t = Table::new(
        &format!("Table 7 (bench) — single-token throughput, '{preset}' preset"),
        &["Compression", "Method", "tokens/s", "Speedup"],
    );
    let dense = decode_stats(&model, n_req, gen);
    b.record_sample("t7/dense", dense.wall_seconds, Some(dense.tokens_generated as f64));
    let dense_tp = dense.tokens_per_second();
    t.row(vec!["0%".into(), "Dense".into(), format!("{dense_tp:.1}"), speedup(1.0)]);

    for rate in [0.3, 0.4, 0.5] {
        for (method, kappa, label, tag) in [
            (Method::Wanda, 0.0, "Unstructured", "unstructured"),
            (Method::Oats, 0.25, "OATS", "oats"),
        ] {
            let cc = CompressConfig {
                method,
                rate,
                rank_ratio: kappa,
                iters: if quick { 4 } else { 8 },
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cc, 6).unwrap();
            let stats = decode_stats(&cm, n_req, gen);
            let pct = (rate * 100.0) as u64;
            let name = format!("t7/{tag}@{pct}pct");
            b.record_sample(&name, stats.wall_seconds, Some(stats.tokens_generated as f64));
            b.compare(&format!("t7_{tag}_{pct}pct_vs_dense"), "t7/dense", &name);
            let tp = stats.tokens_per_second();
            t.row(vec![
                format!("{pct}%"),
                label.into(),
                format!("{tp:.1}"),
                speedup(tp / dense_tp),
            ]);
        }
    }
    t.print();
    b.write_json("table7").expect("bench json");
}
