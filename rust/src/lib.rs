//! # OATS — Outlier-Aware Pruning Through Sparse and Low Rank Decomposition
//!
//! Full-system reproduction of Zhang & Papyan (ICLR 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the compression-pipeline coordinator, the
//!   compressed-inference serving engine, all pruning baselines, evaluation
//!   harnesses, and every substrate they need (tensor algebra, sparse
//!   formats, randomized linear algebra, JSON, CLI, benchmarking).
//! * **L2/L1 (`python/compile/`)** — the JAX transformer model and Pallas
//!   kernels, AOT-lowered once to HLO text artifacts.
//! * **Runtime (`runtime`)** — loads and executes those artifacts through
//!   the PJRT CPU client (`xla` crate); Python is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index mapping
//! every table and figure of the paper to a module and regenerator binary.

// Style-lint families the numeric-kernel code intentionally trades away
// (index-heavy loops, wide argument lists on the algorithm entry points,
// `to_string` on the hand-rolled Json). Correctness lints stay on; CI runs
// `clippy -- -D warnings`.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::inherent_to_string,
    clippy::type_complexity
)]

pub mod bench;
pub mod calib;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod json;
pub mod linalg;
pub mod model;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod train;
pub mod util;
pub mod vit;
