//! Dense numerical linear algebra: Householder QR, randomized truncated SVD
//! (Halko–Martinsson–Tropp), one-sided Jacobi SVD for small panels, and
//! Cholesky factorization / inversion (for SparseGPT's Hessian).
//!
//! Truncated SVD is the compute hot-spot of OATS' alternating thresholding
//! (paper §A.2: α = dout·din·r per iteration); the randomized range-finder
//! achieves exactly that complexity.

use crate::tensor::{matmul, Matrix};
use crate::util::prng::Rng;

/// Thin QR via Householder reflections. Returns (Q [m×n], R [n×n]) for m≥n.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.rows;
    let n = a.cols;
    assert!(m >= n, "qr_thin requires rows >= cols ({m} < {n})");
    let mut r = a.clone();
    // Store Householder vectors in-place below the diagonal; taus separately.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k.
        let mut norm2 = 0.0f64;
        for i in k..m {
            let x = r.at(i, k) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt() as f32;
        let mut v = vec![0.0f32; m - k];
        if norm > 0.0 {
            let alpha = if r.at(k, k) >= 0.0 { -norm } else { norm };
            for i in k..m {
                v[i - k] = r.at(i, k);
            }
            v[0] -= alpha;
            let vnorm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            if vnorm > 1e-20 {
                for x in v.iter_mut() {
                    *x /= vnorm;
                }
                // Apply reflector to R[k.., k..]: R -= 2 v (vᵀ R)
                for j in k..n {
                    let mut dot = 0.0f32;
                    for i in k..m {
                        dot += v[i - k] * r.at(i, j);
                    }
                    let dot2 = 2.0 * dot;
                    for i in k..m {
                        *r.at_mut(i, j) -= dot2 * v[i - k];
                    }
                }
            } else {
                v.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        vs.push(v);
    }
    // Accumulate Q by applying reflectors to the identity's first n columns.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q.data[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0f32;
            for i in k..m {
                dot += v[i - k] * q.at(i, j);
            }
            let dot2 = 2.0 * dot;
            for i in k..m {
                *q.at_mut(i, j) -= dot2 * v[i - k];
            }
        }
    }
    // Zero the strictly-lower part of the returned R (n×n block).
    let mut r_out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out.data[i * n + j] = r.at(i, j);
        }
    }
    (q, r_out)
}

/// One-sided Jacobi SVD of a small matrix. Returns (U [m×n], s [n], Vt [n×n])
/// with singular values descending. Suitable for n up to a few hundred.
pub fn jacobi_svd(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    let m = a.rows;
    let n = a.cols;
    assert!(m >= n, "jacobi_svd requires rows >= cols");
    let mut u = a.clone(); // columns get orthogonalized in place
    let mut v = Matrix::eye(n);
    let max_sweeps = 60;
    let eps = 1e-9f64;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p,q.
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    let up = u.at(i, p) as f64;
                    let uq = u.at(i, q) as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let up = u.at(i, p);
                    let uq = u.at(i, q);
                    *u.at_mut(i, p) = cf * up - sf * uq;
                    *u.at_mut(i, q) = sf * up + cf * uq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = cf * vp - sf * vq;
                    *v.at_mut(i, q) = sf * vp + cf * vq;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // Column norms are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f32; n];
    for (j, s) in sigmas.iter_mut().enumerate() {
        let mut norm = 0.0f64;
        for i in 0..m {
            norm += (u.at(i, j) as f64).powi(2);
        }
        *s = norm.sqrt() as f32;
    }
    // total_cmp: a NaN column norm (degenerate input) must sort, not panic.
    order.sort_by(|&a, &b| sigmas[b].total_cmp(&sigmas[a]));
    let mut u_out = Matrix::zeros(m, n);
    let mut vt_out = Matrix::zeros(n, n);
    for (jj, &j) in order.iter().enumerate() {
        let s = sigmas[j];
        let inv = if s > 1e-20 { 1.0 / s } else { 0.0 };
        for i in 0..m {
            *u_out.at_mut(i, jj) = u.at(i, j) * inv;
        }
        for i in 0..n {
            *vt_out.at_mut(jj, i) = v.at(i, j);
        }
    }
    let sorted: Vec<f32> = order.iter().map(|&j| sigmas[j]).collect();
    (u_out, sorted, vt_out)
}

/// Rank-r truncated SVD factors (stored as U·diag(s)·Vt).
#[derive(Clone, Debug)]
pub struct TruncatedSvd {
    pub u: Matrix,      // m × r
    pub s: Vec<f32>,    // r
    pub vt: Matrix,     // r × n
}

impl TruncatedSvd {
    /// Reconstruct the rank-r matrix U diag(s) Vᵀ.
    pub fn reconstruct(&self) -> Matrix {
        let r = self.s.len();
        let mut us = self.u.clone();
        for j in 0..r {
            us.scale_column(j, self.s[j]);
        }
        matmul(&us, &self.vt)
    }
}

/// Randomized truncated SVD (HMT 2011) with `oversample` extra columns and
/// `power_iters` subspace iterations for spectral-tail suppression.
///
/// Cost O(m·n·(r+p)) per pass — the paper's α per OATS iteration.
pub fn randomized_svd(
    a: &Matrix,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Rng,
) -> TruncatedSvd {
    let m = a.rows;
    let n = a.cols;
    let r = rank.min(m.min(n)).max(1);
    let l = (r + oversample).min(m.min(n));
    // Range finding on the wider side: if m < n operate on Aᵀ and swap back.
    if m < n {
        let at = a.transpose();
        let svd = randomized_svd(&at, rank, oversample, power_iters, rng);
        return TruncatedSvd { u: svd.vt.transpose(), s: svd.s, vt: svd.u.transpose() };
    }
    // Y = A Ω, Ω ~ N(0,1) [n × l]
    let omega = Matrix::randn(n, l, 1.0, rng);
    let mut y = matmul(a, &omega); // m × l
    // Power iterations with re-orthogonalization.
    for _ in 0..power_iters {
        let (q, _) = qr_thin(&y);
        let z = matmul(&a.transpose(), &q); // n × l
        let (qz, _) = qr_thin(&z);
        y = matmul(a, &qz);
    }
    let (q, _) = qr_thin(&y); // m × l orthonormal
    // B = Qᵀ A  [l × n]. Finish with an l×l symmetric eigenproblem instead
    // of an n×l one-sided Jacobi (§Perf iteration 2: the Gram trick cuts
    // the small-factorization cost from O(sweeps·l²·n) to O(sweeps·l³),
    // ~5× on the d=512 OATS iteration — see EXPERIMENTS.md §Perf).
    let b = matmul(&q.transpose(), a);
    // G = B Bᵀ (l × l, symmetric PSD) = V Λ Vᵀ.
    let g = matmul(&b, &b.transpose());
    let (evals, v) = jacobi_eigh(&g);
    // σ_j = sqrt(λ_j); U = Q V; Vt = diag(1/σ) Vᵀ B.
    let vtb = matmul(&v.transpose(), &b); // l × n
    let u_full = matmul(&q, &v); // m × l
    let mut u = Matrix::zeros(m, r);
    for i in 0..m {
        for j in 0..r {
            u.data[i * r + j] = u_full.at(i, j);
        }
    }
    let mut s = Vec::with_capacity(r);
    let mut vt = Matrix::zeros(r, n);
    for j in 0..r {
        let sigma = evals[j].max(0.0).sqrt();
        s.push(sigma as f32);
        let inv = if sigma > 1e-20 { 1.0 / sigma } else { 0.0 };
        for i in 0..n {
            vt.data[j * n + i] = (vtb.at(j, i) as f64 * inv) as f32;
        }
    }
    TruncatedSvd { u, s, vt }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix: A = V Λ Vᵀ.
/// Returns eigenvalues (descending, as f64) and the orthonormal V whose
/// columns are the eigenvectors. O(sweeps · n³); intended for small n
/// (the randomized-SVD projection size).
pub fn jacobi_eigh(a: &Matrix) -> (Vec<f64>, Matrix) {
    let n = a.rows;
    assert_eq!(n, a.cols, "jacobi_eigh requires square input");
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 40;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                off += apq * apq;
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Rotate rows/cols p, q of M.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // Sort descending by eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[j * n + j].total_cmp(&m[i * n + i]));
    let evals: Vec<f64> = order.iter().map(|&i| m[i * n + i]).collect();
    let mut v_out = Matrix::zeros(n, n);
    for (jj, &j) in order.iter().enumerate() {
        for i in 0..n {
            v_out.data[i * n + jj] = v[i * n + j] as f32;
        }
    }
    (evals, v_out)
}

/// Cholesky factorization A = L Lᵀ for symmetric positive-definite A.
/// Returns the lower-triangular L, or None if A is not PD.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows;
    assert_eq!(n, a.cols, "cholesky requires square input");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= (l.at(i, k) as f64) * (l.at(j, k) as f64);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Invert an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn cholesky_inverse(a: &Matrix) -> Option<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    // Forward-solve L X = I  → X = L⁻¹ (lower triangular).
    let mut linv = Matrix::zeros(n, n);
    for col in 0..n {
        let mut x = vec![0.0f32; n];
        x[col] = 1.0;
        for i in 0..n {
            let mut sum = x[i];
            for k in 0..i {
                sum -= l.at(i, k) * x[k];
            }
            x[i] = sum / l.at(i, i);
        }
        for i in 0..n {
            linv.data[i * n + col] = x[i];
        }
    }
    // A⁻¹ = L⁻ᵀ L⁻¹
    Some(matmul(&linv.transpose(), &linv))
}

/// Upper-triangular Cholesky of the *inverse*: returns R upper-triangular
/// with A⁻¹ = Rᵀ R is false — rather, SparseGPT uses chol(A⁻¹)ᵀ, i.e. the
/// upper Cholesky factor of the inverse Hessian. We compute H⁻¹ then its
/// Cholesky and return the transposed (upper) factor.
pub fn upper_cholesky_of_inverse(a: &Matrix) -> Option<Matrix> {
    let inv = cholesky_inverse(a)?;
    let l = cholesky(&inv)?;
    Some(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn assert_orthonormal_cols(q: &Matrix, tol: f32) {
        let g = matmul(&q.transpose(), q);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.at(i, j) - want).abs() < tol,
                    "gram({i},{j}) = {}",
                    g.at(i, j)
                );
            }
        }
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let (q, r) = qr_thin(&a);
        assert_orthonormal_cols(&q, 1e-4);
        let qr = matmul(&q, &r);
        assert!(a.fro_dist(&qr) < 1e-3, "dist={}", a.fro_dist(&qr));
    }

    #[test]
    fn qr_square() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 6, 1.0, &mut rng);
        let (q, r) = qr_thin(&a);
        assert_orthonormal_cols(&q, 1e-4);
        assert!(a.fro_dist(&matmul(&q, &r)) < 1e-3);
    }

    #[test]
    fn jacobi_svd_reconstructs() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(12, 6, 1.0, &mut rng);
        let (u, s, vt) = jacobi_svd(&a);
        assert_orthonormal_cols(&u, 1e-3);
        let svd = TruncatedSvd { u, s: s.clone(), vt };
        assert!(a.fro_dist(&svd.reconstruct()) < 1e-3);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5, "not descending: {:?}", s);
        }
    }

    #[test]
    fn randomized_svd_exact_on_lowrank() {
        // A = B C with rank 3 exactly — truncated SVD at r=3 must be exact.
        let mut rng = Rng::new(4);
        let b = Matrix::randn(30, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 25, 1.0, &mut rng);
        let a = matmul(&b, &c);
        let svd = randomized_svd(&a, 3, 6, 2, &mut rng);
        let rec = svd.reconstruct();
        let rel = a.fro_dist(&rec) / a.fro_norm();
        assert!(rel < 1e-3, "rel err {rel}");
    }

    #[test]
    fn randomized_svd_wide_matrix() {
        let mut rng = Rng::new(5);
        let b = Matrix::randn(10, 2, 1.0, &mut rng);
        let c = Matrix::randn(2, 40, 1.0, &mut rng);
        let a = matmul(&b, &c);
        let svd = randomized_svd(&a, 2, 4, 2, &mut rng);
        assert_eq!(svd.u.rows, 10);
        assert_eq!(svd.vt.cols, 40);
        assert!(a.fro_dist(&svd.reconstruct()) / a.fro_norm() < 1e-3);
    }

    #[test]
    fn randomized_svd_best_rank_r_error_bound_prop() {
        // ‖A − SVD_r(A)‖F should be within a modest factor of the tail
        // singular mass (we verify against full Jacobi SVD truncation).
        check("rsvd near-optimal", 10, |g| {
            let m = g.usize_range(8, 24);
            let n = g.usize_range(4, m + 1);
            let r = g.usize_range(1, n.min(5));
            let a = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
            let (u, s, vt) = jacobi_svd(&a);
            let opt = TruncatedSvd {
                u: {
                    let mut m2 = Matrix::zeros(u.rows, r);
                    for i in 0..u.rows {
                        for j in 0..r {
                            m2.data[i * r + j] = u.at(i, j);
                        }
                    }
                    m2
                },
                s: s[..r].to_vec(),
                vt: {
                    let mut m2 = Matrix::zeros(r, vt.cols);
                    for i in 0..r {
                        for j in 0..vt.cols {
                            m2.data[i * vt.cols + j] = vt.at(i, j);
                        }
                    }
                    m2
                },
            };
            let opt_err = a.fro_dist(&opt.reconstruct());
            let svd = randomized_svd(&a, r, 8, 3, g.rng());
            let rs_err = a.fro_dist(&svd.reconstruct());
            assert!(
                rs_err <= 1.25 * opt_err + 1e-3,
                "rsvd err {rs_err} vs optimal {opt_err} (m={m} n={n} r={r})"
            );
        });
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(6);
        let b = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut a = matmul(&b, &b.transpose()); // SPD-ish
        for i in 0..8 {
            *a.at_mut(i, i) += 8.0; // ensure well-conditioned
        }
        let l = cholesky(&a).expect("PD");
        let rec = matmul(&l, &l.transpose());
        assert!(a.fro_dist(&rec) < 1e-2);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn cholesky_inverse_is_inverse() {
        let mut rng = Rng::new(7);
        let b = Matrix::randn(6, 6, 1.0, &mut rng);
        let mut a = matmul(&b, &b.transpose());
        for i in 0..6 {
            *a.at_mut(i, i) += 6.0;
        }
        let inv = cholesky_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.fro_dist(&Matrix::eye(6)) < 1e-2, "dist={}", prod.fro_dist(&Matrix::eye(6)));
    }

    #[test]
    fn upper_cholesky_of_inverse_shape() {
        let mut rng = Rng::new(8);
        let b = Matrix::randn(5, 5, 1.0, &mut rng);
        let mut a = matmul(&b, &b.transpose());
        for i in 0..5 {
            *a.at_mut(i, i) += 5.0;
        }
        let r = upper_cholesky_of_inverse(&a).unwrap();
        // Upper triangular:
        for i in 0..5 {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-6);
            }
        }
        // RᵀR = A⁻¹ → A RᵀR = I
        let rtr = matmul(&r.transpose(), &r);
        let prod = matmul(&a, &rtr);
        assert!(prod.fro_dist(&Matrix::eye(5)) < 1e-2);
    }
}
