//! A criterion-style micro/macro benchmark harness (criterion itself is not
//! in the vendored dependency set). Provides warmup, repeated sampling,
//! summary statistics, and a uniform report format shared by all
//! `rust/benches/*` targets and the §Perf iteration logs.

use crate::json::{self, Json};
use crate::util::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// One benchmark's collected timings.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub summary: Summary,
    /// Optional throughput unit count per iteration (e.g. tokens).
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// units/second at the mean time, if a unit count was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.summary.mean)
    }

    /// Machine-readable record (one element of `BENCH_*.json`'s `results`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", json::s(&self.name))
            .set("mean_s", json::num(self.summary.mean))
            .set("p50_s", json::num(self.summary.p50))
            .set("p95_s", json::num(self.summary.p95))
            .set("p99_s", json::num(self.summary.p99))
            .set("min_s", json::num(self.summary.min))
            .set("max_s", json::num(self.summary.max))
            .set("samples", json::num(self.summary.n as f64));
        if let Some(u) = self.units_per_iter {
            o.set("units_per_iter", json::num(u));
        }
        if let Some(tp) = self.throughput() {
            o.set("units_per_s", json::num(tp));
        }
        o
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(tp) if tp >= 100.0 => format!("  {:>12.1} units/s", tp),
            Some(tp) => format!("  {:>12.3} units/s", tp),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10} p50 {:>10} p95 {:>10} (n={}){}",
            self.name,
            fmt_secs(self.summary.mean),
            fmt_secs(self.summary.p50),
            fmt_secs(self.summary.p95),
            self.summary.n,
            tp
        )
    }
}

fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner with warmup and a sample budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Hard cap on total sampling time.
    pub max_seconds: f64,
    pub results: Vec<BenchResult>,
    /// Named (label, base, other, speedup) comparisons recorded via
    /// [`Bench::compare`]; emitted into the JSON report.
    pub comparisons: Vec<(String, String, String, f64)>,
    /// Named scalar metrics (memory footprints, ratios) recorded via
    /// [`Bench::metric`]; emitted into the JSON report alongside timings.
    pub metrics: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            samples: 20,
            max_seconds: 30.0,
            results: Vec::new(),
            comparisons: Vec::new(),
            metrics: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, samples: 5, max_seconds: 10.0, ..Default::default() }
    }

    /// `quick()` when [`quick_mode`] says so; full sampling otherwise.
    pub fn from_env() -> Self {
        if quick_mode() {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Run a benchmark; `f` is one iteration. Returns the recorded result.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.run_with_units(name, None, move || {
            f();
        })
    }

    /// Run with an attached units-per-iteration count for throughput display.
    pub fn run_with_units(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        let budget_start = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.max_seconds {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            units_per_iter,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Record one externally-timed measurement as a result (the serve-load
    /// and table-bench path: the harness inside `run_load` already timed
    /// the work, so re-running it under [`Bench::run`] would double the
    /// cost). Comparisons via [`Bench::compare`] work on these like on any
    /// sampled result.
    pub fn record_sample(
        &mut self,
        name: &str,
        seconds: f64,
        units_per_iter: Option<f64>,
    ) -> &BenchResult {
        let res = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&[seconds]),
            units_per_iter,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Speedup of `b` relative to `a` (a.mean / b.mean) by name lookup.
    pub fn speedup(&self, base: &str, other: &str) -> Option<f64> {
        let a = self.results.iter().find(|r| r.name == base)?;
        let b = self.results.iter().find(|r| r.name == other)?;
        Some(a.summary.mean / b.summary.mean)
    }

    /// Record a named base-vs-other comparison for the JSON report.
    /// Returns the speedup if both names exist.
    pub fn compare(&mut self, label: &str, base: &str, other: &str) -> Option<f64> {
        let s = self.speedup(base, other)?;
        println!("  speedup {label}: {s:.2}x ({base} -> {other})");
        self.comparisons.push((label.to_string(), base.to_string(), other.to_string(), s));
        Some(s)
    }

    /// Record a named scalar metric (e.g. a packed format's byte footprint)
    /// for the JSON report.
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("  metric {name}: {value}");
        self.metrics.push((name.to_string(), value));
    }

    /// The whole suite as one machine-readable document.
    pub fn to_json(&self, suite: &str) -> Json {
        let mut o = Json::obj();
        o.set("suite", json::s(suite))
            .set("schema", json::s("oats-bench-v1"))
            .set("warmup_iters", json::num(self.warmup_iters as f64))
            .set("sample_budget", json::num(self.samples as f64));
        o.set("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect()));
        let comps: Vec<Json> = self
            .comparisons
            .iter()
            .map(|(label, base, other, s)| {
                let mut c = Json::obj();
                c.set("label", json::s(label))
                    .set("base", json::s(base))
                    .set("other", json::s(other))
                    .set("speedup", json::num(*s));
                c
            })
            .collect();
        o.set("comparisons", Json::Arr(comps));
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|(name, value)| {
                let mut m = Json::obj();
                m.set("name", json::s(name)).set("value", json::num(*value));
                m
            })
            .collect();
        o.set("metrics", Json::Arr(metrics));
        o
    }

    /// Write `BENCH_<suite>.json` into `$OATS_BENCH_DIR` (default: cwd)
    /// so CI can collect the artifacts (see `benches/micro.rs`).
    pub fn write_json(&self, suite: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("OATS_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_json_to(suite, std::path::Path::new(&dir))
    }

    /// [`Bench::write_json`] with an explicit output directory.
    pub fn write_json_to(&self, suite: &str, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{suite}.json"));
        std::fs::write(&path, self.to_json(suite).to_pretty())?;
        println!("bench json -> {}", path.display());
        Ok(path)
    }
}

/// True when `--quick` was passed (CI smoke mode: `cargo bench --bench
/// micro -- --quick`) or `$OATS_BENCH_QUICK` is truthy (anything but
/// empty/`0`/`false`) — bench targets also use this to shrink their
/// model/workload sizing, not just the sample budget.
pub fn quick_mode() -> bool {
    let env_quick = matches!(
        std::env::var("OATS_BENCH_QUICK").ok().as_deref(),
        Some(v) if !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
    );
    env_quick || std::env::args().any(|a| a == "--quick")
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench { warmup_iters: 1, samples: 5, max_seconds: 5.0, ..Default::default() };
        b.run("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].summary.n >= 1);
    }

    #[test]
    fn json_report_structure() {
        let mut b = Bench::quick();
        b.run_with_units("a", Some(10.0), || {
            black_box(2 * 2);
        });
        b.run("b", || {
            black_box(3 * 3);
        });
        b.compare("a_vs_b", "a", "b").unwrap();
        b.metric("bytes_ratio", 0.5);
        let j = b.to_json("unit");
        assert_eq!(j.get("suite").and_then(crate::json::Json::as_str), Some("unit"));
        assert_eq!(j.get("results").and_then(crate::json::Json::as_arr).unwrap().len(), 2);
        let comps = j.get("comparisons").and_then(crate::json::Json::as_arr).unwrap();
        assert_eq!(comps.len(), 1);
        assert!(comps[0].req_f64("speedup").unwrap() > 0.0);
        let metrics = j.get("metrics").and_then(crate::json::Json::as_arr).unwrap();
        assert_eq!(metrics.len(), 1);
        assert_eq!(metrics[0].req_f64("value").unwrap(), 0.5);
        // Round-trips through the parser (what CI consumers do).
        let parsed = crate::json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("schema").and_then(crate::json::Json::as_str), Some("oats-bench-v1"));
    }

    #[test]
    fn write_json_emits_bench_file() {
        // Explicit-directory variant: no process-global env mutation (tests
        // run concurrently in this process).
        let dir = std::env::temp_dir().join(format!("oats_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut b = Bench::quick();
        b.run("x", || {
            black_box(1);
        });
        let path = b.write_json_to("unittest", &dir).unwrap();
        assert!(path.ends_with("BENCH_unittest.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::json::parse(&text).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn record_sample_supports_comparisons() {
        let mut b = Bench::quick();
        b.record_sample("ext/base", 0.2, Some(100.0));
        b.record_sample("ext/fast", 0.1, Some(100.0));
        assert_eq!(b.results.len(), 2);
        assert!((b.results[0].throughput().unwrap() - 500.0).abs() < 1e-9);
        let s = b.compare("ext", "ext/base", "ext/fast").unwrap();
        assert!((s - 2.0).abs() < 1e-9);
        let j = b.to_json("ext");
        let results = j.get("results").and_then(crate::json::Json::as_arr).unwrap();
        assert!(results[0].req_f64("p99_s").is_ok(), "tail percentile emitted");
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::quick();
        b.run_with_units("unitful", Some(100.0), || {
            black_box(std::time::Duration::from_micros(1));
        });
        assert!(b.results[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn speedup_lookup() {
        let mut b = Bench::quick();
        b.run("slow", || std::thread::sleep(std::time::Duration::from_micros(200)));
        b.run("fast", || std::thread::sleep(std::time::Duration::from_micros(10)));
        let s = b.speedup("slow", "fast").unwrap();
        assert!(s > 1.0, "speedup={s}");
        assert!(b.speedup("nope", "fast").is_none());
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
