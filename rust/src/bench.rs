//! A criterion-style micro/macro benchmark harness (criterion itself is not
//! in the vendored dependency set). Provides warmup, repeated sampling,
//! summary statistics, and a uniform report format shared by all
//! `rust/benches/*` targets and the §Perf iteration logs.

use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark's collected timings.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub summary: Summary,
    /// Optional throughput unit count per iteration (e.g. tokens).
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// units/second at the mean time, if a unit count was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.summary.mean)
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(tp) if tp >= 100.0 => format!("  {:>12.1} units/s", tp),
            Some(tp) => format!("  {:>12.3} units/s", tp),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10} p50 {:>10} p95 {:>10} (n={}){}",
            self.name,
            fmt_secs(self.summary.mean),
            fmt_secs(self.summary.p50),
            fmt_secs(self.summary.p95),
            self.summary.n,
            tp
        )
    }
}

fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner with warmup and a sample budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Hard cap on total sampling time.
    pub max_seconds: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, samples: 20, max_seconds: 30.0, results: Vec::new() }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, samples: 5, max_seconds: 10.0, results: Vec::new() }
    }

    /// Run a benchmark; `f` is one iteration. Returns the recorded result.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.run_with_units(name, None, move || {
            f();
        })
    }

    /// Run with an attached units-per-iteration count for throughput display.
    pub fn run_with_units(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        let budget_start = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.max_seconds {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            units_per_iter,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Speedup of `b` relative to `a` (a.mean / b.mean) by name lookup.
    pub fn speedup(&self, base: &str, other: &str) -> Option<f64> {
        let a = self.results.iter().find(|r| r.name == base)?;
        let b = self.results.iter().find(|r| r.name == other)?;
        Some(a.summary.mean / b.summary.mean)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench { warmup_iters: 1, samples: 5, max_seconds: 5.0, results: vec![] };
        b.run("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].summary.n >= 1);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::quick();
        b.run_with_units("unitful", Some(100.0), || {
            black_box(std::time::Duration::from_micros(1));
        });
        assert!(b.results[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn speedup_lookup() {
        let mut b = Bench::quick();
        b.run("slow", || std::thread::sleep(std::time::Duration::from_micros(200)));
        b.run("fast", || std::thread::sleep(std::time::Duration::from_micros(10)));
        let s = b.speedup("slow", "fast").unwrap();
        assert!(s > 1.0, "speedup={s}");
        assert!(b.speedup("nope", "fast").is_none());
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
