//! `oats` — the leader binary: training, compression, evaluation, serving,
//! and every table/figure regenerator (DESIGN.md §6).
//!
//! ```text
//! oats train        --preset small [--steps N]
//! oats compress     --preset small --method oats --rate 0.5 [--rank-ratio κ]
//!                   [--iters N] [--pattern row|layer|N:M] [--owl] [--out dir]
//!                   [--slice-rate r]            # rotate-and-slice the FFN
//!                                               # pair (0 = rotation only)
//!                   [--slice-max-rel-error e]   # per-layer slice gate
//! oats eval         --model models/small-oats-50
//! oats serve-bench  --preset small [--seq]          # Tables 7 / 14
//! oats serve-load   [--preset tiny] [--requests N] [--gen N] [--slots N]
//!                   [--prefill-chunk N] [--admission fcfs|shortest]
//!                   [--page-size N] [--kv-pages N] [--prefix-cap N]
//!                   [--gen-tokens-mix N,N,...]  # per-request budgets,
//!                                               # assigned round-robin
//!                   [--shared-prefix]    # common-head workload (prefix
//!                                        # KV reuse A/B driver)
//!                   [--no-share-prefix]  # opt every request out of reuse
//!                   [--trace FILE]       # Chrome trace-event JSON
//!                                        # (load in Perfetto / about:tracing)
//!                   [--arrivals closed|poisson:RATE|burst:N:GAP|ramp]
//!                   [--arrival-seed N]   # open-loop storms on the logical
//!                                        # clock, deterministic in the seed
//!                   [--priority-mix interactive,batch,...]  # round-robin tiers
//!                   [--preempt]          # evict low-tier residents for
//!                                        # higher-tier arrivals (bit-exact)
//!                   [--slo-steps N] [--shed-policy off|lowest]
//!                                        # first-token SLO + load shedding
//!                   [--compress] [--quantize] [--quick] [--tag NAME]
//!                   [--slice-rate r]     # with --compress: rotate-and-
//!                                        # slice the FFN pair first
//!                                                   # SERVE_<tag>.json
//! oats bench-table  t2|t3|t4|t5|t6|t8|t9|t10|t11|t12|t13|t15|t16|t17|t20|all
//! oats sweep        rank-ratio|iters|nm|grid        # Figures 1–2, Table 15
//! oats rollout      [--out results/rollout]         # Figures 3–4
//! oats info
//! ```
//!
//! `--quick` shrinks every experiment (CI-sized); default is paper-sized.

use anyhow::Result;
use oats::cli::Args;
use oats::config::{CompressConfig, Method, ModelConfig, SparsityPattern};
use oats::experiments::{speed, sweeps, tables, vision, Ctx};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn root() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn ctx_from(args: &Args) -> Ctx {
    Ctx::new(&root(), args.bool_flag("quick"))
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "train" => cmd_train(args),
        "compress" => cmd_compress(args),
        "eval" => cmd_eval(args),
        "serve-bench" => cmd_serve_bench(args),
        "serve-load" => cmd_serve_load(args),
        "bench-table" => cmd_bench_table(args),
        "sweep" => cmd_sweep(args),
        "rollout" => cmd_rollout(args),
        "probe-outliers" => cmd_probe_outliers(args),
        "info" | "" => cmd_info(),
        other => anyhow::bail!("unknown command '{other}' (try `oats info`)"),
    }
}

fn cmd_info() -> Result<()> {
    println!("OATS — Outlier-Aware Pruning Through Sparse and Low Rank Decomposition");
    println!("Reproduction of Zhang & Papyan (ICLR 2025); see DESIGN.md / EXPERIMENTS.md.");
    println!();
    for p in ["tiny", "small", "base", "large", "alt"] {
        let c = ModelConfig::preset(p)?;
        println!(
            "  preset {:<6} d={:<4} L={:<2} ff={:<5} vocab={:<4} total≈{:.2}M params",
            p,
            c.d_model,
            c.n_layers,
            c.d_ff,
            c.vocab,
            c.total_params() as f64 / 1e6
        );
    }
    println!();
    println!("artifacts: {}", root().join("artifacts").display());
    println!("models:    {}", root().join("models").display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.flag_or("preset", "small");
    let mut ctx = ctx_from(args);
    let steps = args.usize_flag("steps", ctx.train_steps(preset));
    println!("training preset '{preset}' for {steps} steps via PJRT train_step artifact…");
    let corpus = oats::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let model = oats::train::ensure_trained_model(
        &ctx.artifacts,
        &ctx.models,
        preset,
        steps,
        &corpus,
    )?;
    let (eb, ep) = (ctx.eval_batches(), ctx.eval_probes());
    let row = oats::eval::evaluate(&model, &corpus, "trained", eb, ep);
    println!("ppl={:.2} hard={:.1}% easy={:.1}%", row.ppl, row.hard, row.easy);
    Ok(())
}

/// `--slice-rate` is a *presence* flag: absent ⇒ the slice pass is off
/// entirely, `0` ⇒ rotation-only (the exact energy permutation), so a
/// plain default can't express it and it is parsed by hand.
fn parse_slice_rate(args: &Args) -> Result<Option<f64>> {
    match args.flag("slice-rate") {
        Some(s) => {
            let r: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--slice-rate expects a number, got '{s}'"))?;
            anyhow::ensure!((0.0..1.0).contains(&r), "--slice-rate must be in [0, 1), got {r}");
            Ok(Some(r))
        }
        None => Ok(None),
    }
}

fn parse_compress_cfg(args: &Args) -> Result<CompressConfig> {
    Ok(CompressConfig {
        method: Method::parse(args.flag_or("method", "oats"))?,
        rate: args.f64_flag("rate", 0.5),
        rank_ratio: args.f64_flag("rank-ratio", 0.25),
        iters: args.usize_flag("iters", 80),
        pattern: SparsityPattern::parse(args.flag_or("pattern", "row"))?,
        scale_by_d: !args.bool_flag("no-scaling"),
        robust_scaling: args.bool_flag("robust-scaling"),
        threshold_first: args.bool_flag("threshold-first"),
        scale_lowrank_only: args.bool_flag("scale-lowrank-only"),
        owl: args.bool_flag("owl"),
        slice_rate: parse_slice_rate(args)?,
        slice_max_rel_error: args.f64_flag("slice-max-rel-error", 0.75),
        ..Default::default()
    })
}

fn cmd_compress(args: &Args) -> Result<()> {
    let preset = args.flag_or("preset", "small");
    let mut ctx = ctx_from(args);
    let cfg = parse_compress_cfg(args)?;
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    println!(
        "compressing '{preset}' with {} @ ρ={} κ={} N={}…",
        cfg.method.name(),
        cfg.rate,
        cfg.rank_ratio,
        cfg.iters
    );
    let (cm, report) =
        oats::coordinator::pipeline::compress_clone(&model, &calib, &cfg, 6)?;
    println!(
        "achieved compression {:.2}% | mean rel error {:.4} | {:.2}s total",
        cm.achieved_compression() * 100.0,
        report.mean_rel_error(),
        report.total_seconds
    );
    if cfg.slice_rate.is_some() {
        for l in report.layers.iter().filter(|l| l.id.name == "up" || l.id.name == "down") {
            println!(
                "  slice {}: rel_error {:.4} | achieved rate {:.2}",
                l.id, l.rel_error, l.achieved_rate
            );
        }
    }
    let corpus = oats::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let (eb, ep) = (ctx.eval_batches(), ctx.eval_probes());
    let row = oats::eval::evaluate(&cm, &corpus, "compressed", eb, ep);
    println!("ppl={:.2} hard={:.1}% easy={:.1}%", row.ppl, row.hard, row.easy);
    if let Some(out) = args.flag("out") {
        // Structure-preserving format: CSR + low-rank factors on disk.
        oats::model::compressed_io::save(&cm, std::path::Path::new(out))?;
        let sz = oats::model::compressed_io::weights_size(std::path::Path::new(out))?;
        println!("saved compressed model to {out} ({:.2} MiB)", sz as f64 / (1 << 20) as f64);
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = args
        .flag("model")
        .map(std::path::PathBuf::from)
        .or_else(|| args.positional.first().map(std::path::PathBuf::from))
        .ok_or_else(|| anyhow::anyhow!("--model <dir> required"))?;
    let ctx = ctx_from(args);
    // compressed_io::load transparently falls back to the dense format.
    let model = oats::model::compressed_io::load(&dir)?;
    let corpus = oats::data::SyntheticCorpus::new(
        oats::data::CorpusConfig::for_vocab(model.cfg.vocab, 0xC0DE),
    );
    let row = oats::eval::evaluate(&model, &corpus, "eval", ctx.eval_batches(), ctx.eval_probes());
    println!(
        "{}: ppl={:.2} hard={:.1}% easy={:.1}% compression={:.1}%",
        dir.display(),
        row.ppl,
        row.hard,
        row.easy,
        model.achieved_compression() * 100.0
    );
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let preset = args.flag_or("preset", "small");
    let mut ctx = ctx_from(args);
    let table = speed::throughput_table(&mut ctx, preset, args.bool_flag("seq"))?;
    table.print();
    ctx.record(&table.to_json());
    Ok(())
}

/// Closed-loop load run through the continuous-batching serve engine with
/// a mixed-length prompt population, emitting `SERVE_<tag>.json`
/// (`oats-serve-v1`) into `$OATS_BENCH_DIR`. Kernel speed is independent
/// of weight *values*, so the model is randomly initialized (no training
/// artifacts needed — this is what CI's serve-smoke job runs);
/// `--compress` first runs a quick OATS pass so the packed sparse kernels
/// carry the decode. `--gen-tokens-mix 4,8,16` assigns per-request
/// generation budgets round-robin (shrinking short requests' KV page
/// reservations); note a mix containing `0` turns the at-capacity probe
/// prompt into a trivially-complete request, which the CI serve gate's
/// `capacity_stopped ≥ 1` check would reject.
///
/// `--shared-prefix` switches to a workload where every request opens with
/// the same system-prompt head and diverges in its tail — the traffic
/// shape prefix-KV reuse targets. Run it twice, once with
/// `--no-share-prefix`, and the two `SERVE_*.json` files must carry equal
/// `completions_digest` values (the CI shared-prefix gate does exactly
/// this, and additionally requires `prefill_tokens_saved > 0` from the
/// sharing run).
///
/// `--trace FILE` turns on the [`oats::util::trace`] recorder for the load
/// run and writes a Chrome trace-event JSON (`oats-trace-v1`) to FILE; the
/// per-format kernel span totals are folded into the SERVE json's
/// `kernel_time` object. Tracing observes and never reorders, so the
/// `completions_digest` is identical with and without it.
fn cmd_serve_load(args: &Args) -> Result<()> {
    use oats::coordinator::serve::{
        run_load_open, run_load_specs, AdmissionPolicy, ArrivalPlan, LoadSpec, Priority,
        ServeConfig, ShedPolicy,
    };
    use oats::util::trace;
    let preset = args.flag_or("preset", "tiny");
    let quick = args.bool_flag("quick");
    let n_req = args.usize_flag("requests", if quick { 24 } else { 96 });
    let gen_tokens = args.usize_flag("gen", if quick { 8 } else { 24 });
    let cfg = ServeConfig {
        slots: args.usize_flag("slots", 4),
        gen_tokens,
        prefill_chunk: args.usize_flag("prefill-chunk", 8),
        admission: AdmissionPolicy::parse(args.flag_or("admission", "fcfs"))?,
        prepack: true,
        quantize: args.bool_flag("quantize"),
        // 0 = whole-sequence pages (the contiguous degenerate layout).
        page_size: args.usize_flag("page-size", 0),
        kv_pages: args.usize_flag("kv-pages", 0),
        share_prefix: !args.bool_flag("no-share-prefix"),
        // 0 = unbounded prefix index (no capacity eviction).
        prefix_cap: args.usize_flag("prefix-cap", 0),
        preemption: args.bool_flag("preempt"),
        // 0 = no SLO (every first token counts as goodput; shed never fires).
        slo_first_token_steps: args.usize_flag("slo-steps", 0),
        shed_policy: ShedPolicy::parse(args.flag_or("shed-policy", "off"))?,
    };
    let plan = ArrivalPlan::parse(args.flag_or("arrivals", "closed"))?;
    let arrival_seed = args.usize_flag("arrival-seed", 0) as u64;
    let mcfg = ModelConfig::preset(preset)?;
    let mut model = oats::model::TransformerLM::init(&mcfg, 0x5E17E);
    if args.bool_flag("compress") {
        let corpus = oats::data::SyntheticCorpus::new(oats::data::CorpusConfig::for_vocab(
            mcfg.vocab,
            1,
        ));
        let calib = oats::calib::CalibSet::sample(&corpus, 8, 32, 8);
        let cc = CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 3,
            slice_rate: parse_slice_rate(args)?,
            slice_max_rel_error: args.f64_flag("slice-max-rel-error", 0.75),
            ..Default::default()
        };
        let (cm, report) = oats::coordinator::pipeline::compress_clone(&model, &calib, &cc, 6)?;
        if cc.slice_rate.is_some() {
            for l in report.layers.iter().filter(|l| l.id.name == "up" || l.id.name == "down") {
                println!("  slice {}: rel_error {:.4}", l.id, l.rel_error);
            }
        }
        model = cm;
    }
    // Mixed-length prompts (1 … seq_len/2), plus one deliberately oversized
    // prompt (truncation-rejection path) and one exactly-at-capacity prompt
    // (capacity-stopped path) to exercise both non-Complete statuses end to
    // end — the CI gates check their counters. Under `--shared-prefix`
    // every regular prompt instead opens with the same seq_len/4 head (the
    // "system prompt") followed by a per-request tail, so leading pages are
    // publishable and later arrivals join them.
    let shared_head: Option<Vec<usize>> = args.bool_flag("shared-prefix").then(|| {
        (0..(mcfg.seq_len / 4).max(1)).map(|j| (j * 13 + 7) % mcfg.vocab).collect()
    });
    let mut prompts: Vec<Vec<usize>> = (0..n_req)
        .map(|i| match &shared_head {
            Some(head) => {
                let tail = 1 + (i * 7) % (mcfg.seq_len / 4).max(1);
                let mut p = head.clone();
                p.extend((0..tail).map(|j| (i * 11 + j) % mcfg.vocab));
                p
            }
            None => {
                let len = 1 + (i * 7) % (mcfg.seq_len / 2).max(1);
                (0..len).map(|j| (i * 11 + j) % mcfg.vocab).collect()
            }
        })
        .collect();
    if let Some(p) = prompts.last_mut() {
        *p = vec![1; mcfg.seq_len + 1];
    }
    if n_req >= 2 {
        prompts[n_req - 2] = (0..mcfg.seq_len).map(|j| (j * 3) % mcfg.vocab).collect();
    }
    // Per-request budgets, assigned round-robin from `--gen-tokens-mix`
    // (None ⇒ the server-wide `--gen` default for every request). Parsed
    // strictly: a malformed entry aborts instead of silently changing the
    // requested mix.
    let mix: Option<Vec<usize>> = match args.flag("gen-tokens-mix") {
        Some(s) => {
            let parsed: Result<Vec<usize>, _> =
                s.split(',').map(|t| t.trim().parse::<usize>()).collect();
            let v = parsed.map_err(|_| {
                anyhow::anyhow!("--gen-tokens-mix expects comma-separated integers, got '{s}'")
            })?;
            if v.is_empty() {
                anyhow::bail!("--gen-tokens-mix needs at least one budget");
            }
            Some(v)
        }
        None => None,
    };
    // Priority tiers, assigned round-robin from `--priority-mix` (e.g.
    // `interactive,batch,background`; None ⇒ every request is Batch, the
    // pre-priority behavior). Parsed as strictly as the budget mix.
    let tiers: Option<Vec<Priority>> = match args.flag("priority-mix") {
        Some(s) => {
            let parsed: Result<Vec<Priority>, _> =
                s.split(',').map(|t| Priority::parse(t.trim())).collect();
            Some(parsed?)
        }
        None => None,
    };
    let mut requests: Vec<LoadSpec> = prompts
        .into_iter()
        .enumerate()
        .map(|(i, p)| LoadSpec {
            prompt: p,
            gen_tokens: mix.as_ref().map(|m| m[i % m.len()]),
            priority: tiers.as_ref().map_or(Priority::Batch, |t| t[i % t.len()]),
        })
        .collect();
    // The truncation/capacity probes must reach admission even when the
    // shedder is dropping low tiers — the CI gates count both statuses in
    // every run — so under a mixed-priority workload they ride interactive.
    if tiers.is_some() {
        for spec in requests.iter_mut().rev().take(2) {
            spec.priority = Priority::Interactive;
        }
    }
    println!(
        "serve-load: {} requests (gen {}, mix {:?}), {} slots, chunk {}, admission {}, \
         arrivals {}…",
        requests.len(),
        cfg.gen_tokens,
        mix,
        cfg.slots,
        cfg.prefill_chunk,
        cfg.admission.name(),
        plan.label(),
    );
    // Enabled only around the load run so `kernel_time` and the exported
    // trace cover the serve stack, not the optional compression pass.
    let trace_path = args.flag("trace");
    if trace_path.is_some() {
        trace::set_enabled(true);
    }
    // The closed plan keeps the threaded server path (every request queued
    // up front); timed plans replay arrivals on the engine's logical clock
    // so storms are deterministic in (plan, seed).
    let model = std::sync::Arc::new(model);
    let mut stats = match plan {
        ArrivalPlan::Closed => run_load_specs(model, cfg, requests),
        ref timed => run_load_open(model, cfg, requests, timed, arrival_seed),
    };
    if let Some(path) = trace_path {
        trace::set_enabled(false);
        let events = trace::drain();
        let mut kernel: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
        for e in &events {
            if let (Some(fmt), trace::EventKind::Span { dur_ns }) =
                (e.name.strip_prefix("kernel_"), &e.kind)
            {
                *kernel.entry(fmt).or_insert(0.0) += *dur_ns as f64 / 1e9;
            }
        }
        stats.kernel_time = kernel.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        trace::write_chrome_trace(std::path::Path::new(path), &events)?;
        println!("trace: {} events → {path} ({} dropped)", events.len(), trace::dropped_events());
    }
    println!(
        "served {} requests | {} tokens | {:.1} tok/s | p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
        stats.n_requests,
        stats.tokens_generated,
        stats.tokens_per_second(),
        stats.latency.p50 * 1e3,
        stats.latency.p95 * 1e3,
        stats.latency.p99 * 1e3,
    );
    println!(
        "occupancy mean {:.2} | joins {} leaves {} truncated {} capacity-stopped {} | {} steps",
        stats.slot_occupancy.mean,
        stats.joins,
        stats.leaves,
        stats.truncated,
        stats.capacity_stopped,
        stats.steps,
    );
    println!(
        "kv arena {:.2} MiB | {} pages × {} positions | page occupancy mean {:.2} | leaked {}",
        stats.kv_bytes as f64 / (1 << 20) as f64,
        stats.kv_pages,
        stats.page_size,
        stats.page_occupancy.mean,
        stats.pages_in_use_at_drain,
    );
    println!(
        "prefix reuse: {} prefill tokens saved | {} shared pages | {} cow forks | digest {:016x}",
        stats.prefill_tokens_saved,
        stats.shared_pages,
        stats.cow_forks,
        stats.completions_digest,
    );
    println!(
        "overload: {} preemptions ({} recompute tokens) | {} shed | goodput {:.2} under SLO",
        stats.preemptions,
        stats.victim_recompute_tokens,
        stats.shed,
        stats.goodput_under_slo,
    );
    let tag = args.flag_or("tag", preset);
    stats.write_json(tag)?;
    Ok(())
}

fn cmd_bench_table(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: oats bench-table <t2|…|all>"))?;
    let mut ctx = ctx_from(args);
    let presets_default = if ctx.quick { vec!["tiny"] } else { vec!["tiny", "small"] };
    let grid_methods = [Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats];
    let rates = [0.3, 0.4, 0.5];

    let run_grid_tables = |ctx: &mut Ctx| -> Result<Vec<oats::report::Table>> {
        let results = tables::run_grid(ctx, &presets_default, &rates, &grid_methods)?;
        Ok(vec![
            tables::table2(&results),
            tables::table3(&results),
            tables::table4(&results),
            tables::table16(&results),
        ])
    };

    let mut out: Vec<oats::report::Table> = Vec::new();
    match which {
        "grid" => out.extend(run_grid_tables(&mut ctx)?),
        "t2" | "t3" | "t4" | "t16" => {
            let all = run_grid_tables(&mut ctx)?;
            let idx = match which {
                "t2" => 0,
                "t3" => 1,
                "t4" => 2,
                _ => 3,
            };
            out.push(all.into_iter().nth(idx).unwrap());
        }
        "t5" => out.push(tables::table5(&mut ctx, &presets_default)?),
        "t6" | "t11" | "t12" | "t13" => {
            let all = tables::ablation_tables(&mut ctx, "tiny")?;
            let idx = match which {
                "t6" => 0,
                "t11" => 1,
                "t12" => 2,
                _ => 3,
            };
            out.push(all.into_iter().nth(idx).unwrap());
        }
        "t8" => out.push(vision::table8(&mut ctx)?),
        "t9" => out.push(speed::walltime_table(ctx.quick)?),
        "t10" => {
            let preset = if ctx.quick { "tiny" } else { "small" };
            out.push(tables::table10(&mut ctx, preset)?);
        }
        "t15" => out.push(sweeps::hyper_grid(&mut ctx, "tiny")?),
        "t17" => out.push(tables::table17(&mut ctx)?),
        "t20" => out.push(tables::table20(&mut ctx, "tiny")?),
        "all" => {
            out.extend(run_grid_tables(&mut ctx)?);
            out.push(tables::table5(&mut ctx, &presets_default)?);
            out.extend(tables::ablation_tables(&mut ctx, "tiny")?);
            out.push(vision::table8(&mut ctx)?);
            out.push(speed::walltime_table(ctx.quick)?);
            let t10_preset = if ctx.quick { "tiny" } else { "small" };
            out.push(tables::table10(&mut ctx, t10_preset)?);
            out.push(sweeps::hyper_grid(&mut ctx, "tiny")?);
            out.push(tables::table17(&mut ctx)?);
            out.push(tables::table20(&mut ctx, "tiny")?);
            out.push(speed::throughput_table(&mut ctx, "tiny", false)?);
            out.push(speed::throughput_table(&mut ctx, "tiny", true)?);
        }
        other => anyhow::bail!("unknown table '{other}'"),
    }
    for t in &out {
        t.print();
        println!();
        ctx.record(&t.to_json());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: oats sweep <rank-ratio|iters|nm|grid>"))?;
    let mut ctx = ctx_from(args);
    let default_preset = if ctx.quick { "tiny" } else { "small" };
    let preset = args.flag_or("preset", default_preset);
    let rate = args.f64_flag("rate", 0.5);
    let t = match which {
        "rank-ratio" => sweeps::rank_ratio_sweep(&mut ctx, preset, rate)?,
        "iters" => sweeps::iteration_sweep(&mut ctx, preset, rate)?,
        "nm" => sweeps::nm_sweep(&mut ctx, preset)?,
        "grid" => sweeps::hyper_grid(&mut ctx, preset)?,
        other => anyhow::bail!("unknown sweep '{other}'"),
    };
    t.print();
    ctx.record(&t.to_json());
    Ok(())
}

/// Verify the paper's outlier-feature premise on a trained model: per-layer
/// excess kurtosis of linear-layer inputs (≫0 = heavy-tailed outliers).
fn cmd_probe_outliers(args: &Args) -> Result<()> {
    let preset = args.flag_or("preset", "tiny");
    let mut ctx = ctx_from(args);
    let model = ctx.model(preset)?;
    let corpus = oats::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let probes = oats::eval::activation_kurtosis(&model, &corpus, 8);
    let mut t = oats::report::Table::new(
        &format!("Outlier probe — excess kurtosis of layer inputs ({preset})"),
        &["Layer", "Excess kurtosis"],
    );
    for (id, k) in &probes {
        t.row(vec![id.to_string(), format!("{k:.2}")]);
    }
    t.print();
    let max = probes.iter().map(|(_, k)| *k).fold(f64::MIN, f64::max);
    println!(
        "\nmax excess kurtosis {max:.2} — {} (Gaussian ≈ 0; the paper's §2.3\n\
         outlier phenomenon motivates the D-scaling)",
        if max > 1.0 { "heavy-tailed outlier features present" } else { "weak outlier structure" }
    );
    Ok(())
}

fn cmd_rollout(args: &Args) -> Result<()> {
    let mut ctx = ctx_from(args);
    let out = root().join(args.flag_or("out", "results/rollout"));
    let t = vision::rollout_analysis(&mut ctx, &out)?;
    t.print();
    ctx.record(&t.to_json());
    println!("heatmaps written to {}", out.display());
    Ok(())
}
