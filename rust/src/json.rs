//! Minimal JSON parser and writer (stands in for `serde_json`, which is not
//! in the vendored dependency set). Used for experiment configs, artifact
//! manifests, weight-file headers, and result records.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64; object keys are ordered (BTreeMap)
/// so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), v);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required typed accessors with contextual errors.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => {
                let c = other.map(|c| c as char);
                anyhow::bail!("unexpected {c:?} at byte {}", self.pos)
            }
        }
    }

    fn literal(&mut self, text: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let numeric = |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if numeric(c)) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

/// Convenience constructors.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("  -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"oats","iters":80,"rates":[0.3,0.4,0.5],"owl":false}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn roundtrip_prop() {
        check("json roundtrip", 50, |g| {
            // Build a random small value tree.
            fn gen_val(g: &mut crate::util::prop::Gen, depth: usize) -> Json {
                if depth == 0 {
                    return match g.usize_range(0, 4) {
                        0 => Json::Null,
                        1 => Json::Bool(g.bool()),
                        2 => Json::Num((g.f32_range(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
                        _ => Json::Str(format!("s{}", g.usize_range(0, 1000))),
                    };
                }
                match g.usize_range(0, 2) {
                    0 => {
                        let n = g.usize_range(0, 4);
                        Json::Arr((0..n).map(|_| gen_val(g, depth - 1)).collect())
                    }
                    _ => {
                        let n = g.usize_range(0, 4);
                        let mut m = BTreeMap::new();
                        for i in 0..n {
                            m.insert(format!("k{i}"), gen_val(g, depth - 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            let v = gen_val(g, 3);
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        });
    }

    #[test]
    fn escaped_strings_roundtrip() {
        let v = Json::Str("line\nquote\"back\\slash\ttab".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("x", num(1.0)).set("y", s("z"));
        assert_eq!(o.req_usize("x").unwrap(), 1);
        assert_eq!(o.req_str("y").unwrap(), "z");
        assert!(o.req_f64("missing").is_err());
    }
}
