//! Calibration pipeline — paper Algorithm 2's outer loop.
//!
//! Calibration batches are embedded once, then propagated block by block.
//! At each block the inputs to its six linear layers are captured (these
//! inputs have already passed through all previously *compressed* blocks,
//! exactly as the paper specifies), the per-linear [`CalibStats`] are
//! accumulated, the block's layers are compressed, and the block output is
//! recomputed with the compressed weights before moving on.

use crate::compress::CalibStats;
use crate::data::{Batch, SyntheticCorpus};
use crate::model::{ForwardCapture, TransformerLM, LINEAR_NAMES};
use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Calibration activations: a fixed token set reused across methods so all
/// pruners see identical data (paper §3.1).
pub struct CalibSet {
    pub batches: Vec<Batch>,
    pub seq_len: usize,
}

impl CalibSet {
    /// Sample `n_sequences` of `seq_len` tokens from the corpus calibration
    /// stream, grouped into batches of `batch_size`.
    pub fn sample(
        corpus: &SyntheticCorpus,
        n_sequences: usize,
        seq_len: usize,
        batch_size: usize,
    ) -> CalibSet {
        let mut rng: Rng = corpus.stream(0xCA11B);
        let mut batches = Vec::new();
        let mut remaining = n_sequences;
        while remaining > 0 {
            let b = batch_size.min(remaining);
            batches.push(corpus.batch(b, seq_len, &mut rng));
            remaining -= b;
        }
        CalibSet { batches, seq_len }
    }

    pub fn n_sequences(&self) -> usize {
        self.batches.iter().map(|b| b.inputs.len()).sum()
    }
}

/// Per-block capture: the hidden states of every calibration batch at the
/// current block boundary.
pub struct BlockPropagator<'m> {
    pub model: &'m TransformerLM,
    /// hidden[i] is batch i's hidden state [B·S × d].
    pub hidden: Vec<Matrix>,
    pub batch_sizes: Vec<usize>,
    pub seq_len: usize,
    pub block: usize,
}

impl<'m> BlockPropagator<'m> {
    /// Embed the calibration set; positions the propagator before block 0.
    pub fn new(model: &'m TransformerLM, calib: &CalibSet) -> BlockPropagator<'m> {
        let hidden: Vec<Matrix> =
            calib.batches.iter().map(|b| model.embed(&b.inputs)).collect();
        let batch_sizes = calib.batches.iter().map(|b| b.inputs.len()).collect();
        BlockPropagator { model, hidden, batch_sizes, seq_len: calib.seq_len, block: 0 }
    }

    /// Capture the input statistics of every linear in the current block
    /// (using the block's *current* weights for the within-block forward).
    pub fn capture_stats(&self) -> std::collections::HashMap<&'static str, CalibStats> {
        let mut stats: std::collections::HashMap<&'static str, CalibStats> =
            std::collections::HashMap::new();
        for (h, &bsz) in self.hidden.iter().zip(&self.batch_sizes) {
            let mut cap = ForwardCapture::default();
            let _ = self.model.block_forward(
                self.block,
                h,
                bsz,
                self.seq_len,
                Some(&mut cap),
                None,
            );
            for name in LINEAR_NAMES {
                let x = &cap.inputs[name];
                stats
                    .entry(name)
                    .or_insert_with(|| CalibStats::new(x.cols))
                    .update(x, 128);
            }
        }
        for s in stats.values_mut() {
            s.finalize();
        }
        stats
    }

    /// Recompute the current block's outputs (with whatever weights the
    /// model now holds — i.e. compressed) and advance to the next block.
    pub fn advance(&mut self) {
        for (h, &bsz) in self.hidden.iter_mut().zip(&self.batch_sizes) {
            *h = self
                .model
                .block_forward(self.block, h, bsz, self.seq_len, None, None);
        }
        self.block += 1;
    }

    pub fn done(&self) -> bool {
        self.block >= self.model.blocks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::CorpusConfig;

    fn setup() -> (TransformerLM, SyntheticCorpus, CalibSet) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 3);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 9));
        let calib = CalibSet::sample(&corpus, 8, 16, 4);
        (model, corpus, calib)
    }

    #[test]
    fn calib_set_counts() {
        let (_, _, calib) = setup();
        assert_eq!(calib.n_sequences(), 8);
        assert_eq!(calib.batches.len(), 2);
    }

    #[test]
    fn propagation_matches_plain_forward() {
        // With no compression applied, propagating through all blocks must
        // equal the model's own forward pass.
        let (model, _, calib) = setup();
        let mut prop = BlockPropagator::new(&model, &calib);
        while !prop.done() {
            prop.advance();
        }
        let logits_prop = model.project_logits(prop.hidden[0].clone());
        let logits_direct = model.forward(&calib.batches[0].inputs);
        assert!(logits_prop.fro_dist(&logits_direct) < 1e-4);
    }

    #[test]
    fn stats_have_right_dims() {
        let (model, _, calib) = setup();
        let prop = BlockPropagator::new(&model, &calib);
        let stats = prop.capture_stats();
        assert_eq!(stats["q"].gram.cols, model.cfg.d_model);
        assert_eq!(stats["down"].gram.cols, model.cfg.d_ff);
        let rows = 8 * 16; // all sequences × positions
        assert_eq!(stats["q"].n_samples, rows);
    }

    #[test]
    fn qkv_share_input_stats() {
        let (model, _, calib) = setup();
        let prop = BlockPropagator::new(&model, &calib);
        let stats = prop.capture_stats();
        assert!(stats["q"].gram.fro_dist(&stats["k"].gram) < 1e-6);
        assert!(stats["q"].gram.fro_dist(&stats["v"].gram) < 1e-6);
    }
}
