//! Sparse and structured weight formats for the compressed serving engine:
//! CSR matrices, N:M semi-structured patterns, low-rank factor pairs, and
//! the `SparsePlusLowRank` composite that OATS produces.
//!
//! This module is the DeepSparse substitute (DESIGN.md §3): Table 7's CPU
//! speedups are reproduced by executing compressed layers through these
//! kernels instead of dense GEMM.

use crate::tensor::{matmul, Matrix};
use crate::util::threadpool::parallel_for;

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,   // rows+1
    pub indices: Vec<u32>,  // nnz column ids
    pub values: Vec<f32>,   // nnz
}

impl Csr {
    /// Convert from dense, dropping exact zeros.
    pub fn from_dense(m: &Matrix) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                m.data[r * self.cols + self.indices[i] as usize] = self.values[i];
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// y = A·x (sparse matvec).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let mut acc = 0.0f32;
            for i in lo..hi {
                acc += self.values[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
    }

    /// C = X · Aᵀ for activations X [b × cols]: each output row c_i gets the
    /// sparse dot of A's rows against x_i. This is the layout linear layers
    /// use (W stored out×in, activations row-major), so A-row values stream
    /// sequentially while X rows stay cache-resident.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols, "csr matmul_xt dim mismatch");
        let mut out = Matrix::zeros(x.rows, self.rows);
        let threads = if x.rows * self.nnz() >= (1 << 20) {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            1
        };
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let n_out = self.rows;
        parallel_for(threads, x.rows, |b| {
            let xrow = x.row(b);
            let op = out_ptr;
            // SAFETY: each b writes a disjoint output row.
            let orow = unsafe { std::slice::from_raw_parts_mut(op.0.add(b * n_out), n_out) };
            for r in 0..n_out {
                let lo = self.indptr[r] as usize;
                let hi = self.indptr[r + 1] as usize;
                let mut acc = 0.0f32;
                let idx = &self.indices[lo..hi];
                let val = &self.values[lo..hi];
                for (&c, &v) in idx.iter().zip(val) {
                    acc += v * xrow[c as usize];
                }
                orow[r] = acc;
            }
        });
        out
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// N:M sparsity pattern descriptor: at most `n` nonzeros per group of `m`
/// consecutive entries along each row (NVIDIA sparse-tensor-core layout;
/// paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

impl NmPattern {
    pub const TWO_FOUR: NmPattern = NmPattern { n: 2, m: 4 };
    pub const TWO_EIGHT: NmPattern = NmPattern { n: 2, m: 8 };

    /// Check that a dense matrix satisfies the pattern (trailing partial
    /// groups are allowed up to ceil(n * len/m) nonzeros).
    pub fn validates(&self, w: &Matrix) -> bool {
        for r in 0..w.rows {
            let row = w.row(r);
            for g in (0..row.len()).step_by(self.m) {
                let end = (g + self.m).min(row.len());
                let nnz = row[g..end].iter().filter(|&&v| v != 0.0).count();
                let cap = if end - g == self.m {
                    self.n
                } else {
                    // partial trailing group: proportional cap, rounded up
                    (self.n * (end - g)).div_ceil(self.m)
                };
                if nnz > cap {
                    return false;
                }
            }
        }
        true
    }

    /// Implied sparsity (fraction zero) of a full pattern.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.n as f64 / self.m as f64
    }
}

/// Low-rank factor pair L = U · Vt (U: out×r, Vt: r×in). The paper stores L
/// exactly this way to cut memory (Section 2.1).
#[derive(Clone, Debug, PartialEq)]
pub struct LowRank {
    pub u: Matrix,  // out × r
    pub vt: Matrix, // r × in
}

impl LowRank {
    pub fn rank(&self) -> usize {
        self.u.cols
    }

    pub fn to_dense(&self) -> Matrix {
        matmul(&self.u, &self.vt)
    }

    /// Parameter count of the factorization.
    pub fn params(&self) -> usize {
        self.u.rows * self.u.cols + self.vt.rows * self.vt.cols
    }

    /// y += U (Vt x): two skinny matvecs, O((out+in)·r).
    pub fn apply_accumulate(&self, x: &[f32], y: &mut [f32]) {
        let r = self.rank();
        let mut t = vec![0.0f32; r];
        for i in 0..r {
            let vrow = self.vt.row(i);
            let mut acc = 0.0f32;
            for (a, b) in vrow.iter().zip(x) {
                acc += a * b;
            }
            t[i] = acc;
        }
        for (row, yv) in y.iter_mut().enumerate() {
            let urow = self.u.row(row);
            let mut acc = 0.0f32;
            for (a, b) in urow.iter().zip(&t) {
                acc += a * b;
            }
            *yv += acc;
        }
    }

    /// C += X·(U Vt)ᵀ = (X·Vtᵀ)·Uᵀ — batched form, two dense skinny GEMMs.
    pub fn apply_batch_accumulate(&self, x: &Matrix, out: &mut Matrix) {
        // t = X · Vtᵀ : [b × r]
        let t = crate::tensor::matmul_bt(x, &self.vt);
        // out += t · Uᵀ : [b × out]
        let contrib = crate::tensor::matmul_bt(&t, &self.u);
        out.axpy(1.0, &contrib);
    }
}

/// The OATS compressed layer: W ≈ S + L with S sparse (CSR) and L low-rank.
#[derive(Clone, Debug)]
pub struct SparsePlusLowRank {
    pub sparse: Csr,
    pub low_rank: Option<LowRank>,
}

impl SparsePlusLowRank {
    /// Dense reconstruction S + U·Vt.
    pub fn to_dense(&self) -> Matrix {
        let mut d = self.sparse.to_dense();
        if let Some(lr) = &self.low_rank {
            d.axpy(1.0, &lr.to_dense());
        }
        d
    }

    /// Nonzero-parameter count (paper's compression accounting, Eq. ρ):
    /// k + r(dout + din).
    pub fn param_count(&self) -> usize {
        self.sparse.nnz() + self.low_rank.as_ref().map_or(0, |lr| lr.params())
    }

    /// Achieved compression rate vs the dense layer.
    pub fn compression_rate(&self) -> f64 {
        1.0 - self.param_count() as f64 / (self.sparse.rows * self.sparse.cols) as f64
    }

    /// y = (S + UVt) x — the fused serving kernel.
    pub fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.sparse.matvec(x, y);
        if let Some(lr) = &self.low_rank {
            lr.apply_accumulate(x, y);
        }
    }

    /// C = X (S + UVt)ᵀ — batched serving kernel.
    pub fn apply_batch(&self, x: &Matrix) -> Matrix {
        let mut out = self.sparse.matmul_xt(x);
        if let Some(lr) = &self.low_rank {
            lr.apply_batch_accumulate(x, &mut out);
        }
        out
    }
}

/// Cost model used for the N:M / acceleration analyses (Figure 2, DESIGN.md
/// §5): effective FLOPs + bytes moved for one application of the layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerCost {
    pub flops: f64,
    pub bytes: f64,
}

/// Dense layer cost for a single token.
pub fn dense_cost(dout: usize, din: usize) -> LayerCost {
    LayerCost { flops: 2.0 * dout as f64 * din as f64, bytes: 4.0 * (dout * din) as f64 }
}

/// Sparse+low-rank cost for a single token: CSR nnz MACs (with index
/// overhead) plus two dense skinny products.
pub fn spl_cost(nnz: usize, dout: usize, din: usize, rank: usize) -> LayerCost {
    let lr_flops = 2.0 * rank as f64 * (dout + din) as f64;
    LayerCost {
        flops: 2.0 * nnz as f64 + lr_flops,
        bytes: 8.0 * nnz as f64 + 4.0 * (rank * (dout + din)) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    fn random_sparse(rows: usize, cols: usize, keep: f64, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::randn(rows, cols, 1.0, rng);
        for v in &mut m.data {
            if rng.f64() > keep {
                *v = 0.0;
            }
        }
        m
    }

    #[test]
    fn csr_roundtrip_prop() {
        check("csr dense roundtrip", 30, |g| {
            let rows = g.usize_range(1, 30);
            let cols = g.usize_range(1, 30);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.3, &mut rng);
            let csr = Csr::from_dense(&m);
            assert_eq!(csr.to_dense(), m);
            assert_eq!(csr.nnz(), m.nnz());
        });
    }

    #[test]
    fn csr_matvec_matches_dense() {
        check("csr matvec == dense", 30, |g| {
            let rows = g.usize_range(1, 40);
            let cols = g.usize_range(1, 40);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.4, &mut rng);
            let x = g.vec_normal(cols, 1.0);
            let csr = Csr::from_dense(&m);
            let mut y = vec![0.0; rows];
            csr.matvec(&x, &mut y);
            let yd = crate::tensor::matvec(&m, &x);
            for (a, b) in y.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn csr_matmul_xt_matches_dense() {
        let mut rng = Rng::new(2);
        let w = random_sparse(17, 23, 0.3, &mut rng);
        let x = Matrix::randn(5, 23, 1.0, &mut rng);
        let csr = Csr::from_dense(&w);
        let got = csr.matmul_xt(&x);
        let want = crate::tensor::matmul_bt(&x, &w);
        assert!(got.fro_dist(&want) < 1e-4);
    }

    #[test]
    fn nm_pattern_validation() {
        // 2:4-valid row
        let ok = Matrix::from_vec(1, 8, vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
        assert!(NmPattern::TWO_FOUR.validates(&ok));
        // violating group
        let bad = Matrix::from_vec(1, 8, vec![1.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(!NmPattern::TWO_FOUR.validates(&bad));
    }

    #[test]
    fn nm_pattern_partial_group() {
        // 6 cols with 2:4: trailing group of 2 may hold ceil(2*2/4)=1 nonzero.
        let ok = Matrix::from_vec(1, 6, vec![1.0, 2.0, 0.0, 0.0, 5.0, 0.0]);
        assert!(NmPattern::TWO_FOUR.validates(&ok));
        let bad = Matrix::from_vec(1, 6, vec![1.0, 2.0, 0.0, 0.0, 5.0, 6.0]);
        assert!(!NmPattern::TWO_FOUR.validates(&bad));
    }

    #[test]
    fn nm_sparsity_values() {
        assert!((NmPattern::TWO_FOUR.sparsity() - 0.5).abs() < 1e-12);
        assert!((NmPattern::TWO_EIGHT.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lowrank_apply_matches_dense() {
        let mut rng = Rng::new(3);
        let lr = LowRank {
            u: Matrix::randn(12, 3, 1.0, &mut rng),
            vt: Matrix::randn(3, 9, 1.0, &mut rng),
        };
        let x: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0; 12];
        lr.apply_accumulate(&x, &mut y);
        let dense = lr.to_dense();
        let want = crate::tensor::matvec(&dense, &x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lowrank_batch_matches_single() {
        let mut rng = Rng::new(4);
        let lr = LowRank {
            u: Matrix::randn(8, 2, 1.0, &mut rng),
            vt: Matrix::randn(2, 6, 1.0, &mut rng),
        };
        let x = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut batch = Matrix::zeros(4, 8);
        lr.apply_batch_accumulate(&x, &mut batch);
        for b in 0..4 {
            let mut y = vec![0.0; 8];
            lr.apply_accumulate(x.row(b), &mut y);
            for (a, &bv) in y.iter().zip(batch.row(b)) {
                assert!((a - bv).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn spl_apply_matches_dense_reconstruction_prop() {
        check("spl apply == dense(S+L)·x", 20, |g| {
            let rows = g.usize_range(2, 24);
            let cols = g.usize_range(2, 24);
            let r = g.usize_range(1, cols.min(rows).min(4) + 1);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let s = random_sparse(rows, cols, 0.2, &mut rng);
            let spl = SparsePlusLowRank {
                sparse: Csr::from_dense(&s),
                low_rank: Some(LowRank {
                    u: Matrix::randn(rows, r, 1.0, &mut rng),
                    vt: Matrix::randn(r, cols, 1.0, &mut rng),
                }),
            };
            let x = g.vec_normal(cols, 1.0);
            let mut y = vec![0.0; rows];
            spl.apply(&x, &mut y);
            let want = crate::tensor::matvec(&spl.to_dense(), &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn spl_param_count_and_rate() {
        let mut rng = Rng::new(5);
        let s = random_sparse(10, 10, 0.1, &mut rng);
        let nnz = s.nnz();
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(10, 2, 1.0, &mut rng),
                vt: Matrix::randn(2, 10, 1.0, &mut rng),
            }),
        };
        assert_eq!(spl.param_count(), nnz + 2 * 20);
        let rate = spl.compression_rate();
        assert!((rate - (1.0 - (nnz as f64 + 40.0) / 100.0)).abs() < 1e-12);
    }

    #[test]
    fn cost_model_orders_correctly() {
        // At 50% unstructured sparsity vs 25% sparse + rank putting same params,
        // the low-rank variant should do fewer raw bytes per useful FLOP... we
        // just sanity check monotonicity here.
        let d = dense_cost(1024, 1024);
        let s = spl_cost(524_288, 1024, 1024, 0);
        assert!(s.flops < d.flops);
        let s2 = spl_cost(262_144, 1024, 1024, 128);
        assert!(s2.flops < d.flops);
    }
}
