//! Dense row-major f32 tensors and the neural-net primitives the serving
//! engine and the native model implementations are built from.
//!
//! This is deliberately a small, predictable substrate: 2-D matrices with an
//! explicit (rows, cols) shape, blocked + multithreaded GEMM on the hot path,
//! and the handful of pointwise ops a transformer needs. Higher-rank data
//! (batch, seq, dim) is handled by the callers as `rows = batch*seq`.

use crate::util::prng::Rng;
use crate::util::threadpool::{available_threads, parallel_for, SendPtr};

/// Row-major 2-D matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// i.i.d. N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// [`Matrix::transpose`] into a caller-provided output (the
    /// workspace-reuse path): every element of `out` is overwritten,
    /// shape must be `[cols × rows]`.
    pub fn transpose_into(&self, out: &mut Matrix) {
        assert_eq!((out.rows, out.cols), (self.cols, self.rows), "transpose_into output shape");
        // Tiled transpose for cache friendliness on large matrices.
        const T: usize = 32;
        for rb in (0..self.rows).step_by(T) {
            for cb in (0..self.cols).step_by(T) {
                for r in rb..(rb + T).min(self.rows) {
                    for c in cb..(cb + T).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// ‖self − other‖_F.
    pub fn fro_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    /// Number of non-zero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale column `c` by `s` (used for the D / D⁻¹ diagonal transforms).
    pub fn scale_column(&mut self, c: usize, s: f32) {
        for r in 0..self.rows {
            self.data[r * self.cols + c] *= s;
        }
    }

    /// Return a copy with each column j multiplied by `d[j]`.
    pub fn mul_columns(&self, d: &[f32]) -> Matrix {
        assert_eq!(d.len(), self.cols);
        let mut out = self.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (v, &s) in row.iter_mut().zip(d) {
                *v *= s;
            }
        }
        out
    }
}

/// Threshold above which GEMM fans out across threads.
const PAR_GEMM_MIN_FLOPS: usize = 1 << 22;

/// C = A · B, blocked and multithreaded over row stripes of A.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// [`matmul`] into a caller-provided output (the workspace-reuse path):
/// `c` is overwritten, shape must be `[a.rows × b.cols]`.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (ar, ac) = (a.rows, a.cols);
    assert_eq!(a.cols, b.rows, "matmul inner-dim mismatch: {ar}x{ac} · {}x{}", b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_into output shape");
    c.data.fill(0.0);
    let flops = a.rows * a.cols * b.cols;
    let threads = if flops >= PAR_GEMM_MIN_FLOPS { available_threads() } else { 1 };
    let n = a.rows;
    let bc = b.cols;
    let kk = a.cols;
    // Row-stripe decomposition; each worker owns disjoint rows of C.
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    let stripe = n.div_ceil(threads.max(1)).max(1);
    let stripes = n.div_ceil(stripe);
    parallel_for(threads, stripes, |s| {
        let r0 = s * stripe;
        let r1 = ((s + 1) * stripe).min(n);
        let cp = c_ptr;
        // SAFETY: each stripe writes a disjoint row range of C.
        let c_rows = unsafe { std::slice::from_raw_parts_mut(cp.0.add(r0 * bc), (r1 - r0) * bc) };
        gemm_stripe(&a.data[r0 * kk..r1 * kk], &b.data, c_rows, r1 - r0, kk, bc);
    });
}

/// Inner kernel: C[m×n] += A[m×k] · B[k×n] with k-panel blocking and an
/// unrolled 4-wide accumulation over B rows (i-k-j loop order keeps B
/// accesses sequential and autovectorizable).
fn gemm_stripe(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    const KB: usize = 256;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut p = kb;
            // Unroll 4 over the k-panel.
            while p + 4 <= kend {
                let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for j in 0..n {
                    crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                p += 4;
            }
            while p < kend {
                let av = arow[p];
                if av != 0.0 {
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
                p += 1;
            }
        }
    }
}

/// y = A · x for a dense matrix and vector.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    for r in 0..a.rows {
        let row = a.row(r);
        let mut acc = 0.0f32;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y[r] = acc;
    }
    y
}

/// C = A · Bᵀ (common for x·Wᵀ linear layers with W stored out×in).
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_bt_into(a, b, &mut c);
    c
}

/// [`matmul_bt`] into a caller-provided output (the workspace-reuse path):
/// every element of `c` is overwritten, shape must be `[a.rows × b.rows]`.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_bt inner-dim mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_bt_into output shape");
    let flops = a.rows * a.cols * b.rows;
    let threads = if flops >= PAR_GEMM_MIN_FLOPS { available_threads() } else { 1 };
    let (m, n, k) = (a.rows, b.rows, a.cols);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    let stripe = m.div_ceil(threads.max(1)).max(1);
    let stripes = m.div_ceil(stripe);
    parallel_for(threads, stripes, |s| {
        let r0 = s * stripe;
        let r1 = ((s + 1) * stripe).min(m);
        let cp = c_ptr;
        // SAFETY: each stripe writes a disjoint row range of C, and
        // `parallel_for` joins every worker before C is read again.
        let cdat = unsafe { std::slice::from_raw_parts_mut(cp.0.add(r0 * n), (r1 - r0) * n) };
        for i in r0..r1 {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut cdat[(i - r0) * n..(i - r0 + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                crow[j] = dot(arow, brow);
            }
        }
    });
}

/// Dot product with 4-wide manual unroll.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let mut i = 0;
    while i + 4 <= n {
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    while i < n {
        acc += a[i] * b[i];
        i += 1;
    }
    acc
}

/// In-place softmax over the last axis (each row).
pub fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        softmax_inplace(m.row_mut(r));
    }
}

/// Numerically-stable in-place softmax of a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// GELU (tanh approximation, matching jax.nn.gelu's default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = gelu(*v);
    }
}

/// LayerNorm over the last axis with learned gain/bias.
pub fn layernorm_rows(m: &mut Matrix, gain: &[f32], bias: &[f32], eps: f32) {
    assert_eq!(gain.len(), m.cols);
    assert_eq!(bias.len(), m.cols);
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let n = row.len() as f32;
        let mean = row.iter().sum::<f32>() / n;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + eps).sqrt();
        for (v, (&g, &b)) in row.iter_mut().zip(gain.iter().zip(bias)) {
            *v = (*v - mean) * inv * g + b;
        }
    }
}

/// Cross-entropy of logits rows against integer targets; returns mean nats.
pub fn cross_entropy(logits: &Matrix, targets: &[usize]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0f64;
    for (r, &t) in targets.iter().enumerate() {
        let row = logits.row(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
        total += (lse - row[t]) as f64;
    }
    total / targets.len() as f64
}

/// Index of the max element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest values by |magnitude| (unordered).
/// Uses select_nth_unstable — O(n) average, the hot path of hard-thresholding.
pub fn top_k_abs_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    if k == 0 {
        return Vec::new();
    }
    if k == xs.len() {
        return (0..xs.len()).collect();
    }
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let kth = k - 1;
    idx.select_nth_unstable_by(kth, |&a, &b| {
        xs[b].abs().partial_cmp(&xs[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 13, 1.0, &mut rng);
        let c = matmul(&a, &Matrix::eye(13));
        assert!(a.fro_dist(&c) < 1e-5);
    }

    #[test]
    fn matmul_matches_naive_prop() {
        check("blocked gemm == naive", 30, |g| {
            let m = g.usize_range(1, 20);
            let k = g.usize_range(1, 20);
            let n = g.usize_range(1, 20);
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k, 1.0));
            let b = Matrix::from_vec(k, n, g.vec_normal(k * n, 1.0));
            let c = matmul(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for p in 0..k {
                        acc += a.at(i, p) * b.at(p, j);
                    }
                    let got = c.at(i, j);
                    assert!((got - acc).abs() < 1e-3, "({i},{j}): {got} vs {acc}");
                }
            }
        });
    }

    #[test]
    fn matmul_parallel_path_matches_serial() {
        // Force a matrix big enough to trip the threaded path.
        let mut rng = Rng::new(5);
        let a = Matrix::randn(257, 129, 1.0, &mut rng);
        let b = Matrix::randn(129, 255, 1.0, &mut rng);
        let c = matmul(&a, &b);
        // Spot-check a handful of entries against naive dot products.
        for &(i, j) in &[(0, 0), (256, 254), (128, 100), (13, 77)] {
            let mut acc = 0.0f32;
            for p in 0..129 {
                acc += a.at(i, p) * b.at(p, j);
            }
            assert!((c.at(i, j) - acc).abs() < 1e-2);
        }
    }

    #[test]
    fn matmul_into_variants_match_allocating_and_overwrite() {
        let mut rng = Rng::new(17);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let b = Matrix::randn(14, 6, 1.0, &mut rng);
        let bt = Matrix::randn(6, 14, 1.0, &mut rng);
        // Stale contents in the destination must not leak through.
        let mut c = Matrix::filled(9, 6, 7.5);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c, matmul(&a, &b));
        let mut d = Matrix::filled(9, 6, -3.25);
        matmul_bt_into(&a, &bt, &mut d);
        assert_eq!(d, matmul_bt(&a, &bt));
    }

    #[test]
    fn matmul_bt_matches_matmul_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(5, 8, 1.0, &mut rng);
        let b = Matrix::randn(6, 8, 1.0, &mut rng);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.fro_dist(&c2) < 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(37, 53, 1.0, &mut rng);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(4);
        let mut m = Matrix::randn(10, 32, 5.0, &mut rng);
        softmax_rows(&mut m);
        for r in 0..m.rows {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut xs = vec![1000.0, 1000.0];
        softmax_inplace(&mut xs);
        assert!((xs[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn layernorm_normalizes() {
        let mut m = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let gain = vec![1.0; 4];
        let bias = vec![0.0; 4];
        layernorm_rows(&mut m, &gain, &bias, 1e-5);
        let mean: f32 = m.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = m.row(0).iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-4);
    }

    #[test]
    fn cross_entropy_perfect_prediction_small() {
        let mut logits = Matrix::zeros(1, 4);
        logits.data[2] = 100.0;
        let ce = cross_entropy(&logits, &[2]);
        assert!(ce < 1e-6);
    }

    #[test]
    fn cross_entropy_uniform_is_log_n() {
        let logits = Matrix::zeros(3, 8);
        let ce = cross_entropy(&logits, &[0, 3, 7]);
        assert!((ce - (8f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn top_k_abs_selects_largest() {
        let xs = vec![0.1, -5.0, 3.0, 0.0, -0.2, 4.0];
        let mut idx = top_k_abs_indices(&xs, 3);
        idx.sort_unstable();
        assert_eq!(idx, vec![1, 2, 5]);
    }

    #[test]
    fn top_k_abs_edge_cases() {
        assert!(top_k_abs_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_abs_indices(&[1.0, 2.0], 5).len(), 2);
    }

    #[test]
    fn top_k_abs_prop_exact_k_and_dominance() {
        check("top-k dominance", 40, |g| {
            let n = g.usize_range(1, 200);
            let k = g.usize_range(0, n + 1);
            let xs = g.vec_normal(n, 3.0);
            let idx = top_k_abs_indices(&xs, k);
            assert_eq!(idx.len(), k.min(n));
            if k > 0 && k < n {
                let min_kept = idx.iter().map(|&i| xs[i].abs()).fold(f32::INFINITY, f32::min);
                let sel: std::collections::HashSet<usize> = idx.iter().copied().collect();
                for (i, &x) in xs.iter().enumerate() {
                    if !sel.contains(&i) {
                        assert!(x.abs() <= min_kept + 1e-6);
                    }
                }
            }
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(9, 11, 1.0, &mut rng);
        let x: Vec<f32> = (0..11).map(|i| i as f32 * 0.1).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(11, 1, x);
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn mul_columns_scales() {
        let a = Matrix::from_vec(2, 3, vec![1.0; 6]);
        let d = vec![1.0, 2.0, 3.0];
        let b = a.mul_columns(&d);
        assert_eq!(b.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn nnz_counts() {
        let m = Matrix::from_vec(1, 4, vec![0.0, 1.0, 0.0, -2.0]);
        assert_eq!(m.nnz(), 2);
    }
}
