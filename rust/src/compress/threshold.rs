//! Hard-thresholding operators (paper §2.1–2.2): keep the k
//! largest-magnitude entries layer-wise, row-wise, or under an N:M pattern.
//!
//! All operators take a *score* matrix deciding which entries survive and a
//! *value* matrix supplying the surviving values — the two differ whenever a
//! scaled score (e.g. Wanda's `|W|·‖x‖`) selects entries of the raw weights,
//! and in the A.5 ablation where OATS selects on unscaled magnitudes.

use crate::config::SparsityPattern;
use crate::tensor::{top_k_abs_indices, Matrix};

/// Boolean keep-mask with exactly the pattern's nonzero budget.
#[derive(Clone, Debug)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub keep: Vec<bool>,
}

impl Mask {
    pub fn nnz(&self) -> usize {
        self.keep.iter().filter(|&&b| b).count()
    }

    /// Apply to values: out[i] = if keep[i] { values[i] } else { 0 }.
    pub fn apply(&self, values: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (values.rows, values.cols));
        let mut out = values.clone();
        for (v, &k) in out.data.iter_mut().zip(&self.keep) {
            if !k {
                *v = 0.0;
            }
        }
        out
    }
}

/// Build the keep-mask for `k` total nonzeros from `scores`, under `pattern`.
///
/// * `LayerWise` — global top-k by |score| (paper Algorithm 1).
/// * `RowWise` — top-⌊k/rows⌋ per row (paper §2.2; Sun et al. 2024b show
///   this comparison group performs better).
/// * `Nm` — keep the n largest per group of m along each row; `k` is ignored
///   (the pattern fixes the budget).
pub fn mask_top_k(scores: &Matrix, k: usize, pattern: SparsityPattern) -> Mask {
    let mut keep = vec![false; scores.rows * scores.cols];
    match pattern {
        SparsityPattern::LayerWise => {
            for i in top_k_abs_indices(&scores.data, k) {
                keep[i] = true;
            }
        }
        SparsityPattern::RowWise => {
            let per_row = k / scores.rows.max(1);
            for r in 0..scores.rows {
                for c in top_k_abs_indices(scores.row(r), per_row) {
                    keep[r * scores.cols + c] = true;
                }
            }
        }
        SparsityPattern::Nm { n, m } => {
            for r in 0..scores.rows {
                let row = scores.row(r);
                for g in (0..row.len()).step_by(m) {
                    let end = (g + m).min(row.len());
                    let budget = if end - g == m {
                        n
                    } else {
                        (n * (end - g)).div_ceil(m)
                    };
                    for c in top_k_abs_indices(&row[g..end], budget) {
                        keep[r * scores.cols + g + c] = true;
                    }
                }
            }
        }
    }
    Mask { rows: scores.rows, cols: scores.cols, keep }
}

/// HARDTHRESHOLD(A, k): mask selected on `scores`, values taken from
/// `values` (paper Algorithm 1 uses scores == values; Wanda and the A.5
/// ablation use different scores).
pub fn hard_threshold(
    values: &Matrix,
    scores: &Matrix,
    k: usize,
    pattern: SparsityPattern,
) -> Matrix {
    mask_top_k(scores, k, pattern).apply(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::NmPattern;
    use crate::util::prop::check;

    #[test]
    fn layerwise_keeps_exactly_k() {
        check("layerwise exact k", 50, |g| {
            let rows = g.usize_range(1, 20);
            let cols = g.usize_range(1, 20);
            let k = g.usize_range(0, rows * cols + 1);
            let scores = Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 1.0));
            let m = mask_top_k(&scores, k, SparsityPattern::LayerWise);
            assert_eq!(m.nnz(), k.min(rows * cols));
        });
    }

    #[test]
    fn rowwise_keeps_floor_k_over_rows_per_row() {
        check("rowwise per-row budget", 50, |g| {
            let rows = g.usize_range(1, 16);
            let cols = g.usize_range(1, 32);
            let k = g.usize_range(0, rows * cols + 1);
            let scores = Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 1.0));
            let m = mask_top_k(&scores, k, SparsityPattern::RowWise);
            let per_row = (k / rows).min(cols);
            for r in 0..rows {
                let nnz = (0..cols).filter(|&c| m.keep[r * cols + c]).count();
                assert_eq!(nnz, per_row, "row {r}");
            }
        });
    }

    #[test]
    fn nm_masks_validate_pattern() {
        check("N:M masks valid", 50, |g| {
            let rows = g.usize_range(1, 12);
            let mfac = *g.choose(&[4usize, 8]);
            let n = g.usize_range(1, mfac.min(4));
            let cols = g.usize_range(1, 6) * mfac + g.usize_range(0, mfac);
            let scores = Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 1.0));
            let mask = mask_top_k(&scores, 0, SparsityPattern::Nm { n, m: mfac });
            let vals = mask.apply(&scores);
            assert!(
                NmPattern { n, m: mfac }.validates(&vals),
                "rows={rows} cols={cols} n={n} m={mfac}"
            );
        });
    }

    #[test]
    fn threshold_selects_largest_magnitudes() {
        let v = Matrix::from_vec(1, 5, vec![5.0, -1.0, 3.0, -4.0, 0.5]);
        let out = hard_threshold(&v, &v, 2, SparsityPattern::LayerWise);
        assert_eq!(out.data, vec![5.0, 0.0, 0.0, -4.0, 0.0]);
    }

    #[test]
    fn scores_differ_from_values() {
        // Select on scores, keep raw values.
        let values = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let scores = Matrix::from_vec(1, 3, vec![9.0, 0.1, 0.2]);
        let out = hard_threshold(&values, &scores, 1, SparsityPattern::LayerWise);
        assert_eq!(out.data, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn nm_full_pattern_sparsity() {
        // With cols divisible by m, 2:4 yields exactly 50% nnz.
        let mut g = crate::util::prop::Gen::new(1);
        let scores = Matrix::from_vec(8, 16, g.vec_normal(128, 1.0));
        let m = mask_top_k(&scores, 0, SparsityPattern::Nm { n: 2, m: 4 });
        assert_eq!(m.nnz(), 64);
    }
}
