//! Wanda (Sun et al., 2024b): prune by the score `|W_ij| · ‖x_j‖₂`, with a
//! per-output-row comparison group. Equivalent to OATS at κ=0 (paper §6).

use super::{params, threshold, CalibStats, CompressedLayer};
use crate::config::CompressConfig;
use crate::sparse::Csr;
use crate::tensor::Matrix;
use anyhow::Result;

/// Wanda score matrix S_ij = |W_ij| · ‖x_j‖₂.
pub fn scores(w: &Matrix, stats: &CalibStats) -> Matrix {
    let norms = stats.col_norms();
    let mut s = w.clone();
    for v in &mut s.data {
        *v = v.abs();
    }
    s.mul_columns(&norms)
}

pub fn compress(w: &Matrix, stats: &CalibStats, cfg: &CompressConfig) -> Result<CompressedLayer> {
    anyhow::ensure!(w.cols == stats.gram.cols, "stats dim mismatch");
    let k = params::solve(w.rows, w.cols, cfg.rate, 0.0).nonzeros;
    let sc = scores(w, stats);
    let pruned = threshold::hard_threshold(w, &sc, k, cfg.pattern);
    Ok(CompressedLayer::Sparse(Csr::from_dense(&pruned)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, SparsityPattern};
    use crate::util::prng::Rng;

    #[test]
    fn outlier_columns_protected() {
        // Column 0 has huge activation norm; even small weights there beat
        // large weights in dead columns.
        let w = Matrix::from_vec(1, 3, vec![0.1, 0.5, 0.9]);
        let x = Matrix::from_vec(4, 3, vec![
            100.0, 0.1, 0.1,
            100.0, 0.1, 0.1,
            100.0, 0.1, 0.1,
            100.0, 0.1, 0.1,
        ]);
        let stats = CalibStats::from_activations(&x);
        let cfg = CompressConfig {
            method: Method::Wanda,
            rate: 0.66,
            pattern: SparsityPattern::RowWise,
            ..Default::default()
        };
        let out = compress(&w, &stats, &cfg).unwrap().to_dense();
        assert!(out.data[0] != 0.0, "outlier-column weight must survive: {:?}", out.data);
        assert_eq!(out.nnz(), 1);
    }

    #[test]
    fn magnitude_recovered_with_uniform_activations() {
        // If all columns have equal norms, Wanda == magnitude pruning.
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let x = Matrix::filled(10, 16, 1.0);
        let stats = CalibStats::from_activations(&x);
        let cfg = CompressConfig {
            method: Method::Wanda,
            rate: 0.5,
            pattern: SparsityPattern::RowWise,
            ..Default::default()
        };
        let wanda = compress(&w, &stats, &cfg).unwrap().to_dense();
        let magnitude = super::super::magnitude::compress(&w, &cfg).unwrap().to_dense();
        assert!(wanda.fro_dist(&magnitude) < 1e-6);
    }

    #[test]
    fn achieves_rate() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(24, 24, 1.0, &mut rng);
        let x = Matrix::randn(32, 24, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        for rate in [0.3, 0.5, 0.7] {
            let cfg = CompressConfig {
                method: Method::Wanda,
                rate,
                pattern: SparsityPattern::RowWise,
                ..Default::default()
            };
            let out = compress(&w, &stats, &cfg).unwrap();
            assert!((out.compression_rate((w.rows, w.cols)) - rate).abs() < 0.06, "rate {rate}");
        }
    }
}
