//! The (ρ, κ) → (r, k) parameter solver — paper Equation 2.
//!
//! Given a compression rate ρ and a rank ratio κ, splits the kept parameter
//! budget `(1−ρ)·dout·din` between the low-rank term (`r(dout+din)` params)
//! and the sparse term (`k` nonzeros).

/// Resolved per-layer compression parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OatsParams {
    /// Rank of the low-rank term L.
    pub rank: usize,
    /// Number of nonzeros in the sparse term S.
    pub nonzeros: usize,
}

/// Paper Eq. 2:
/// `r = ⌈κ·(1−ρ)·dout·din/(dout+din)⌉`, `k = ⌊(1−κ)·(1−ρ)·dout·din⌋`.
pub fn solve(dout: usize, din: usize, rate: f64, rank_ratio: f64) -> OatsParams {
    assert!((0.0..1.0).contains(&rate), "rate must be in [0,1): {rate}");
    assert!((0.0..=1.0).contains(&rank_ratio), "rank ratio must be in [0,1]: {rank_ratio}");
    let dd = (dout * din) as f64;
    let keep = (1.0 - rate) * dd;
    let rank = (rank_ratio * keep / (dout + din) as f64).ceil() as usize;
    let nonzeros = ((1.0 - rank_ratio) * keep).floor() as usize;
    OatsParams { rank, nonzeros: nonzeros.min(dout * din) }
}

/// Achieved compression rate for a resolved parameter pair — the ρ identity
/// from §2.4 used to verify the solver.
pub fn achieved_rate(dout: usize, din: usize, p: OatsParams) -> f64 {
    1.0 - (p.nonzeros + p.rank * (dout + din)) as f64 / (dout * din) as f64
}

/// Achieved rank ratio for a resolved pair.
pub fn achieved_rank_ratio(dout: usize, din: usize, p: OatsParams) -> f64 {
    let lr = (p.rank * (dout + din)) as f64;
    let total = lr + p.nonzeros as f64;
    if total == 0.0 {
        0.0
    } else {
        lr / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn kappa_zero_is_pure_sparsity() {
        let p = solve(100, 200, 0.5, 0.0);
        assert_eq!(p.rank, 0);
        assert_eq!(p.nonzeros, 10_000); // (1-0.5)*100*200
    }

    #[test]
    fn paper_defaults_sane() {
        // base-preset attention projection, ρ=0.5, κ=0.25.
        let p = solve(256, 256, 0.5, 0.25);
        assert!(p.rank >= 1);
        let rho = achieved_rate(256, 256, p);
        assert!((rho - 0.5).abs() < 0.02, "achieved ρ = {rho}");
        let kap = achieved_rank_ratio(256, 256, p);
        assert!((kap - 0.25).abs() < 0.05, "achieved κ = {kap}");
    }

    #[test]
    fn identity_holds_prop() {
        check("ρ,κ identity within rounding", 200, |g| {
            let dout = g.usize_range(8, 512);
            let din = g.usize_range(8, 512);
            let rate = g.f64_unit() * 0.8 + 0.1;
            let kappa = g.f64_unit() * 0.6;
            let p = solve(dout, din, rate, kappa);
            let rho = achieved_rate(dout, din, p);
            // Rounding error bounded by (dout+din)/(dout·din) for the ceil
            // on r plus 1/(dout·din) for the floor on k.
            let tol = (dout + din) as f64 / (dout * din) as f64 + 1e-9;
            assert!(
                (rho - rate).abs() <= tol,
                "ρ target {rate} achieved {rho} tol {tol} (dout={dout} din={din} κ={kappa})"
            );
        });
    }

    #[test]
    fn nonzeros_never_exceed_matrix() {
        let p = solve(4, 4, 0.0, 0.0);
        assert!(p.nonzeros <= 16);
    }

    #[test]
    #[should_panic]
    fn rejects_rate_one() {
        solve(10, 10, 1.0, 0.2);
    }
}
