//! OWL — Outlier-Weighed Layerwise sparsity ratios (Yin et al., 2024b).
//!
//! Layers with more activation-outlier mass get *lower* sparsity. The
//! layerwise outlier distribution is measured as the fraction of entries of
//! the Wanda-style score matrix `|W|·‖x‖` exceeding `M ×` the layer's mean
//! score; ratios are mapped linearly to per-layer rates clipped to
//! `rate ± λ` and renormalized so the global rate is preserved.

use super::{wanda, CalibStats};
use crate::tensor::Matrix;

/// Outlier fraction of one layer: share of score entries > m·mean(score).
pub fn outlier_fraction(w: &Matrix, stats: &CalibStats, m: f64) -> f64 {
    let s = wanda::scores(w, stats);
    let mean = crate::util::stats::mean_f32(&s.data);
    if mean <= 0.0 {
        return 0.0;
    }
    let thresh = (m * mean) as f32;
    s.data.iter().filter(|&&v| v > thresh).count() as f64 / s.data.len() as f64
}

/// Map per-layer outlier fractions to per-layer compression rates.
///
/// Higher outlier fraction ⇒ lower rate (keep more). Rates are confined to
/// `[rate−λ, rate+λ]` and shifted so that the parameter-weighted mean equals
/// the global target (paper: "OWL ratios", used at ρ=0.6, Table 5).
pub fn layerwise_rates(
    outlier_fracs: &[f64],
    layer_params: &[usize],
    global_rate: f64,
    lambda: f64,
) -> Vec<f64> {
    assert_eq!(outlier_fracs.len(), layer_params.len());
    let n = outlier_fracs.len();
    if n == 0 {
        return vec![];
    }
    let max_f = outlier_fracs.iter().cloned().fold(f64::MIN, f64::max);
    let min_f = outlier_fracs.iter().cloned().fold(f64::MAX, f64::min);
    let span = (max_f - min_f).max(1e-12);
    // Linear map: most-outlier layer → rate−λ, least → rate+λ.
    let mut rates: Vec<f64> = outlier_fracs
        .iter()
        .map(|&f| {
            let t = (f - min_f) / span; // 0..1
            global_rate + lambda * (1.0 - 2.0 * t)
        })
        .collect();
    // Renormalize (parameter-weighted) to hit the global target exactly,
    // then re-clip; one round of each is sufficient for our λ values.
    let total: f64 = layer_params.iter().map(|&p| p as f64).sum();
    let achieved: f64 = rates
        .iter()
        .zip(layer_params)
        .map(|(&r, &p)| r * p as f64)
        .sum::<f64>()
        / total;
    let shift = global_rate - achieved;
    for r in &mut rates {
        *r = (*r + shift).clamp(0.05, 0.95);
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn outlier_fraction_detects_outliers() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 32, 1.0, &mut rng);
        let mut x = Matrix::randn(64, 32, 1.0, &mut rng);
        let flat = CalibStats::from_activations(&x);
        let f_flat = outlier_fraction(&w, &flat, 5.0);
        for r in 0..x.rows {
            *x.at_mut(r, 0) *= 50.0;
            *x.at_mut(r, 1) *= 50.0;
        }
        let spiky = CalibStats::from_activations(&x);
        let f_spiky = outlier_fraction(&w, &spiky, 5.0);
        assert!(f_spiky > f_flat, "{f_spiky} !> {f_flat}");
    }

    #[test]
    fn rates_weighted_mean_preserved_prop() {
        check("OWL preserves global rate", 100, |g| {
            let n = g.usize_range(1, 12);
            let fracs: Vec<f64> = (0..n).map(|_| g.f64_unit() * 0.2).collect();
            let params: Vec<usize> = (0..n).map(|_| g.usize_range(1000, 100_000)).collect();
            let rate = 0.3 + g.f64_unit() * 0.4;
            let lambda = 0.08;
            let rates = layerwise_rates(&fracs, &params, rate, lambda);
            let total: f64 = params.iter().map(|&p| p as f64).sum();
            let achieved: f64 =
                rates.iter().zip(&params).map(|(&r, &p)| r * p as f64).sum::<f64>() / total;
            assert!((achieved - rate).abs() < 0.02, "achieved {achieved} target {rate}");
            // Individual rates stay within 2λ of the target (λ map plus the
            // parameter-weighted renormalization shift, each bounded by λ).
            let lo = rate - 2.0 * lambda - 1e-9;
            let hi = rate + 2.0 * lambda + 1e-9;
            for &r in &rates {
                assert!(r >= lo && r <= hi, "r={r} target {rate}");
            }
        });
    }

    #[test]
    fn outlier_layers_get_lower_rates() {
        let fracs = [0.2, 0.01, 0.01, 0.01];
        let params = [100usize, 100, 100, 100];
        let rates = layerwise_rates(&fracs, &params, 0.6, 0.08);
        assert!(rates[0] < rates[1], "{rates:?}");
        assert!(rates[0] < 0.6);
    }

    #[test]
    fn uniform_fracs_give_uniform_rates() {
        let fracs = [0.05, 0.05, 0.05];
        let params = [10usize, 10, 10];
        let rates = layerwise_rates(&fracs, &params, 0.5, 0.08);
        // span collapses → all layers land on the same (clipped) rate
        let achieved: f64 = rates.iter().sum::<f64>() / 3.0;
        assert!((achieved - 0.5).abs() < 0.02, "{rates:?}");
    }
}
