//! OATS — the paper's algorithm (Algorithms 1 and 2).
//!
//! `compress` scales the weight by `D = sqrt(diag(XᵀX))`, runs N iterations
//! of alternating thresholding (truncated SVD ↔ hard thresholding) on `WD`,
//! and returns `S·D⁻¹` as CSR plus `L·D⁻¹` as a low-rank factor pair.
//!
//! The ablation switches of §3.3 / Appendix A.3–A.5 are all supported:
//! no-scaling, robust (median) scaling, hard-threshold-first order, and
//! magnitude-based (unscaled) selection for the sparse component.

use super::params;
use super::threshold::{self, Mask};
use super::{CalibStats, CompressedLayer};
use crate::config::{CompressConfig, SparsityPattern};
use crate::linalg::{randomized_svd, TruncatedSvd};
use crate::sparse::{Csr, LowRank, SparsePlusLowRank};
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use anyhow::Result;

/// Oversampling and power iterations for the randomized truncated SVD.
/// Two power iterations suffice here because alternating thresholding
/// re-solves L every iteration (errors wash out across iterations).
const SVD_OVERSAMPLE: usize = 8;
const SVD_POWER_ITERS: usize = 2;

/// Result of the raw decomposition (scaled space) — exposed for tests and
/// for the runtime cross-validation against the JAX `oats_step` artifact.
#[derive(Clone, Debug)]
pub struct Decomposition {
    pub sparse: Matrix,
    pub svd: TruncatedSvd,
    /// ‖WD − S − L‖_F after the final iteration.
    pub residual: f64,
}

/// ALTERNATINGTHRESHOLDING (paper Algorithm 1), with the A.4/A.5 ablation
/// switches. Operates entirely in the scaled space (input `wd`).
///
/// * `select_scores`: optional alternative score matrix for the sparse-term
///   selection (A.5 passes |(WD−L)·D⁻¹|; `None` means select on `WD−L`).
pub fn alternating_thresholding(
    wd: &Matrix,
    iters: usize,
    rank: usize,
    nonzeros: usize,
    pattern: SparsityPattern,
    threshold_first: bool,
    inv_d_for_selection: Option<&[f32]>,
    rng: &mut Rng,
) -> Decomposition {
    let mut s = Matrix::zeros(wd.rows, wd.cols);
    let mut svd = TruncatedSvd {
        u: Matrix::zeros(wd.rows, rank.max(1)),
        s: vec![0.0; rank.max(1)],
        vt: Matrix::zeros(rank.max(1), wd.cols),
    };
    let mut low = Matrix::zeros(wd.rows, wd.cols);

    let ht = |resid: &Matrix, rng_mask: Option<&[f32]>| -> Matrix {
        match rng_mask {
            Some(inv_d) => {
                // A.5: select on the *unscaled* residual magnitudes but keep
                // scaled values, so S stays in the scaled space.
                let scores = resid.mul_columns(inv_d);
                threshold::hard_threshold(resid, &scores, nonzeros, pattern)
            }
            None => threshold::hard_threshold(resid, resid, nonzeros, pattern),
        }
    };

    for it in 0..iters.max(1) {
        if threshold_first && it == 0 {
            // A.4 order ablation: hard-threshold before the first SVT.
            let resid = wd.clone();
            s = ht(&resid, inv_d_for_selection);
        }
        // L = TRUNCATEDSVD(WD − S, r)
        if rank > 0 {
            let mut resid = wd.clone();
            resid.axpy(-1.0, &s);
            svd = randomized_svd(&resid, rank, SVD_OVERSAMPLE, SVD_POWER_ITERS, rng);
            low = svd.reconstruct();
        }
        // S = HARDTHRESHOLD(WD − L, k)
        let mut resid = wd.clone();
        resid.axpy(-1.0, &low);
        s = ht(&resid, inv_d_for_selection);
    }

    let mut err = wd.clone();
    err.axpy(-1.0, &s);
    err.axpy(-1.0, &low);
    Decomposition { sparse: s, svd, residual: err.fro_norm() }
}

/// OATS (paper Algorithm 2) on one layer.
pub fn compress(w: &Matrix, stats: &CalibStats, cfg: &CompressConfig) -> Result<CompressedLayer> {
    let (dout, din) = (w.rows, w.cols);
    anyhow::ensure!(din == stats.gram.cols, "stats dim {} != layer din {din}", stats.gram.cols);
    let p = params::solve(dout, din, cfg.rate, cfg.rank_ratio);
    let mut rng = Rng::new(cfg.seed ^ ((dout as u64) << 32 | din as u64));

    // D (or its ablation variants).
    let d: Vec<f32> = if !cfg.scale_by_d {
        vec![1.0; din]
    } else if cfg.robust_scaling {
        stats.robust_scale()
    } else {
        stats.scale_d()
    };
    let inv_d: Vec<f32> = d.iter().map(|&x| 1.0 / x).collect();

    let wd = w.mul_columns(&d);
    let dec = alternating_thresholding(
        &wd,
        cfg.iters,
        p.rank,
        p.nonzeros,
        cfg.pattern,
        cfg.threshold_first,
        if cfg.scale_lowrank_only { Some(&inv_d) } else { None },
        &mut rng,
    );

    // Undo the scaling: S·D⁻¹ stays sparse; L·D⁻¹ folds into Vt.
    let s_unscaled = dec.sparse.mul_columns(&inv_d);
    let low_rank = if p.rank > 0 {
        // U keeps the singular values (U·Σ), Vt gets D⁻¹.
        let mut u = dec.svd.u.clone();
        for (j, &sv) in dec.svd.s.iter().enumerate() {
            u.scale_column(j, sv);
        }
        Some(LowRank { u, vt: dec.svd.vt.mul_columns(&inv_d) })
    } else {
        None
    };

    let spl = SparsePlusLowRank { sparse: Csr::from_dense(&s_unscaled), low_rank };
    Ok(CompressedLayer::Spl(spl))
}

/// Wanda-equivalence check helper (paper §6): OATS at κ=0, N=1 is exactly
/// one hard-threshold of WD mapped back through D⁻¹.
pub fn single_threshold_reference(
    w: &Matrix,
    d: &[f32],
    k: usize,
    pattern: SparsityPattern,
) -> Matrix {
    let wd = w.mul_columns(d);
    let thr = threshold::hard_threshold(&wd, &wd, k, pattern);
    let inv: Vec<f32> = d.iter().map(|&x| 1.0 / x).collect();
    thr.mul_columns(&inv)
}

/// Expose the mask of a compressed sparse term (testing/DSNoT interop).
pub fn mask_of(m: &Matrix) -> Mask {
    Mask {
        rows: m.rows,
        cols: m.cols,
        keep: m.data.iter().map(|&v| v != 0.0).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::util::prop::check;

    fn outlier_stats(din: usize, seed: u64) -> CalibStats {
        let mut g = crate::util::prop::Gen::new(seed);
        let x = Matrix::from_vec(64, din, g.outlier_matrix(64, din, 0.06));
        CalibStats::from_activations(&x)
    }

    fn default_cfg() -> CompressConfig {
        CompressConfig { method: Method::Oats, iters: 20, ..Default::default() }
    }

    #[test]
    fn residual_decreases_over_iterations() {
        let mut g = crate::util::prop::Gen::new(7);
        let w = Matrix::from_vec(24, 32, g.vec_normal(24 * 32, 1.0));
        let mut rng = Rng::new(1);
        let d1 = alternating_thresholding(
            &w, 1, 4, 200, SparsityPattern::RowWise, false, None, &mut rng,
        );
        let mut rng = Rng::new(1);
        let d20 = alternating_thresholding(
            &w, 20, 4, 200, SparsityPattern::RowWise, false, None, &mut rng,
        );
        assert!(
            d20.residual <= d1.residual + 1e-6,
            "N=20 residual {} vs N=1 {}",
            d20.residual,
            d1.residual
        );
    }

    #[test]
    fn compression_rate_hits_target_prop() {
        check("OATS achieves ρ within rounding", 10, |g| {
            let dout = g.usize_range(16, 48);
            let din = g.usize_range(16, 48);
            let rate = *g.choose(&[0.3, 0.4, 0.5]);
            let kappa = *g.choose(&[0.0, 0.2, 0.3]);
            let w = Matrix::from_vec(dout, din, g.vec_normal(dout * din, 1.0));
            let stats = outlier_stats(din, 99);
            let cfg = CompressConfig {
                rate,
                rank_ratio: kappa,
                iters: 3,
                ..default_cfg()
            };
            let out = compress(&w, &stats, &cfg).unwrap();
            let achieved = out.compression_rate((dout, din));
            // Row-wise flooring + rank ceil ⇒ achieved ≥ target − small slack.
            let tol = (dout + din) as f64 / (dout * din) as f64 + 1.0 / din as f64;
            assert!(
                achieved >= rate - tol,
                "target ρ={rate} κ={kappa} achieved={achieved} (dout={dout} din={din})"
            );
        });
    }

    #[test]
    fn kappa_zero_reduces_to_wanda_selection() {
        // §6: OATS with κ=0, N=1 == Wanda's scaled hard-threshold.
        let mut g = crate::util::prop::Gen::new(3);
        let w = Matrix::from_vec(16, 24, g.vec_normal(16 * 24, 1.0));
        let stats = outlier_stats(24, 5);
        let cfg = CompressConfig {
            rank_ratio: 0.0,
            iters: 1,
            rate: 0.5,
            pattern: SparsityPattern::RowWise,
            ..default_cfg()
        };
        let out = compress(&w, &stats, &cfg).unwrap();
        let d = stats.scale_d();
        let k = params::solve(16, 24, 0.5, 0.0).nonzeros;
        let want = single_threshold_reference(&w, &d, k, SparsityPattern::RowWise);
        assert!(out.to_dense().fro_dist(&want) < 1e-4);
    }

    #[test]
    fn exact_sparse_plus_lowrank_recovered() {
        // Plant W = S* + L* with r=2 and sparse k; OATS should reach a
        // near-zero residual (Robust PCA exact-recovery regime).
        let mut rng = Rng::new(11);
        let u = Matrix::randn(30, 2, 1.0, &mut rng);
        let v = Matrix::randn(2, 40, 1.0, &mut rng);
        let mut w = crate::tensor::matmul(&u, &v);
        // plant 40 sparse spikes
        for _ in 0..40 {
            let r = rng.below(30);
            let c = rng.below(40);
            w.data[r * 40 + c] += 10.0 * (rng.f32() - 0.5).signum();
        }
        let mut rng2 = Rng::new(1);
        let dec = alternating_thresholding(
            &w, 30, 2, 60, SparsityPattern::LayerWise, false, None, &mut rng2,
        );
        assert!(
            dec.residual / w.fro_norm() < 0.05,
            "relative residual {}",
            dec.residual / w.fro_norm()
        );
    }

    #[test]
    fn scaling_preserves_outlier_columns_better() {
        // With heavy outlier columns, scaled OATS must reconstruct the
        // outlier-weighted error better than unscaled.
        let mut g = crate::util::prop::Gen::new(13);
        let w = Matrix::from_vec(32, 48, g.vec_normal(32 * 48, 1.0));
        let stats = outlier_stats(48, 21);
        let d = stats.scale_d();

        let scaled_cfg = CompressConfig { rate: 0.5, iters: 10, ..default_cfg() };
        let unscaled_cfg = CompressConfig { scale_by_d: false, ..scaled_cfg.clone() };
        let ws = compress(&w, &stats, &scaled_cfg).unwrap().to_dense();
        let wu = compress(&w, &stats, &unscaled_cfg).unwrap().to_dense();

        // Error in the D-weighted metric (what the loss sees to first order).
        let err = |wc: &Matrix| -> f64 {
            let mut e = w.clone();
            e.axpy(-1.0, wc);
            e.mul_columns(&d).fro_norm()
        };
        assert!(
            err(&ws) < err(&wu),
            "scaled {} !< unscaled {}",
            err(&ws),
            err(&wu)
        );
    }

    #[test]
    fn nm_pattern_respected_end_to_end() {
        let mut g = crate::util::prop::Gen::new(17);
        let w = Matrix::from_vec(16, 32, g.vec_normal(16 * 32, 1.0));
        let stats = outlier_stats(32, 23);
        let cfg = CompressConfig {
            rate: 0.5,
            rank_ratio: 0.3,
            iters: 5,
            pattern: SparsityPattern::Nm { n: 2, m: 8 },
            ..default_cfg()
        };
        let out = compress(&w, &stats, &cfg).unwrap();
        if let CompressedLayer::Spl(spl) = &out {
            let dense_s = spl.sparse.to_dense();
            assert!(crate::sparse::NmPattern { n: 2, m: 8 }.validates(&dense_s));
            assert!(spl.low_rank.is_some());
        } else {
            panic!("expected Spl");
        }
    }

    #[test]
    fn ablation_flags_run() {
        let mut g = crate::util::prop::Gen::new(19);
        let w = Matrix::from_vec(12, 16, g.vec_normal(12 * 16, 1.0));
        let stats = outlier_stats(16, 29);
        for (robust, first, lronly) in
            [(true, false, false), (false, true, false), (false, false, true)]
        {
            let cfg = CompressConfig {
                rate: 0.4,
                rank_ratio: 0.2,
                iters: 4,
                robust_scaling: robust,
                threshold_first: first,
                scale_lowrank_only: lronly,
                ..default_cfg()
            };
            let out = compress(&w, &stats, &cfg).unwrap();
            assert!(out.compression_rate((w.rows, w.cols)) > 0.3);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut g = crate::util::prop::Gen::new(23);
        let w = Matrix::from_vec(10, 12, g.vec_normal(120, 1.0));
        let stats = outlier_stats(12, 31);
        let cfg = CompressConfig { iters: 5, ..default_cfg() };
        let a = compress(&w, &stats, &cfg).unwrap().to_dense();
        let b = compress(&w, &stats, &cfg).unwrap().to_dense();
        assert_eq!(a.data, b.data);
    }
}
