//! DSNoT — Dynamic Sparse No Training (Zhang et al., 2024b): training-free
//! mask refinement on top of an initial pruning mask.
//!
//! Per output row, DSNoT tracks the expected reconstruction error
//! `ε_i = Σ_{j pruned} W_ij·E[x_j]` and iteratively swaps a pruned weight
//! back in (the revive whose expected contribution best cancels ε_i) for a
//! kept weight pruned out (the one whose removal moves ε_i the same
//! direction while sacrificing the least Wanda saliency). The paper runs 50
//! cycles with an update threshold of 0.1 (§A.14.2); we mirror both and
//! report the best of Wanda- and SparseGPT-initialized masks upstream,
//! matching how the paper's tables quote DSNoT.

use super::{wanda, CalibStats, CompressedLayer};
use crate::config::{CompressConfig, Method, SparsityPattern};
use crate::sparse::Csr;
use crate::tensor::Matrix;
use anyhow::Result;

/// Maximum revive/prune cycles per row (paper: 50).
const MAX_CYCLES: usize = 50;
/// Update threshold on |ε| (paper: 0.1), relative to the row's input scale.
const UPDATE_THRESHOLD: f32 = 0.1;

/// Refine an initial pruned weight matrix in-place. Exposed for tests.
pub fn refine(
    w: &Matrix,            // original dense weights
    initial: &Matrix,      // pruned weights (zeros = pruned)
    stats: &CalibStats,
    pattern: SparsityPattern,
) -> Matrix {
    let col_mean = &stats.col_mean;
    let sal = wanda::scores(w, stats);
    let mut out = initial.clone();

    // Row-wise refinement only makes sense for unstructured/row patterns;
    // N:M masks are left as-is (swaps would break the pattern).
    if matches!(pattern, SparsityPattern::Nm { .. }) {
        return out;
    }

    for row in 0..w.rows {
        // ε = Σ_{pruned j} W_ij μ_j  (expected output lost by pruning)
        let mut eps: f32 = (0..w.cols)
            .filter(|&j| out.at(row, j) == 0.0)
            .map(|j| w.at(row, j) * col_mean[j])
            .sum();
        let scale: f32 = col_mean.iter().map(|m| m.abs()).sum::<f32>() / w.cols as f32;
        let thresh = UPDATE_THRESHOLD * scale.max(1e-6);

        for _ in 0..MAX_CYCLES {
            if eps.abs() <= thresh {
                break;
            }
            // Revive candidate: pruned j whose contribution W_ij·μ_j has the
            // same sign as ε (adding it back cancels error), max saliency.
            let mut revive: Option<(usize, f32)> = None;
            for j in 0..w.cols {
                if out.at(row, j) != 0.0 {
                    continue;
                }
                let contrib = w.at(row, j) * col_mean[j];
                if contrib * eps > 0.0 {
                    let s = sal.at(row, j);
                    if revive.map(|(_, bs)| s > bs).unwrap_or(true) {
                        revive = Some((j, s));
                    }
                }
            }
            // Prune candidate: kept j whose removal moves ε the opposite
            // way (its contribution has sign opposite ε) with min saliency.
            let mut prune: Option<(usize, f32)> = None;
            for j in 0..w.cols {
                if out.at(row, j) == 0.0 {
                    continue;
                }
                let contrib = out.at(row, j) * col_mean[j];
                if contrib * eps <= 0.0 {
                    let s = sal.at(row, j);
                    if prune.map(|(_, bs)| s < bs).unwrap_or(true) {
                        prune = Some((j, s));
                    }
                }
            }
            let (Some((rj, _)), Some((pj, _))) = (revive, prune) else {
                break;
            };
            if rj == pj {
                break;
            }
            // Swap: revive rj, prune pj; sparsity is preserved exactly.
            eps -= w.at(row, rj) * col_mean[rj];
            *out.at_mut(row, rj) = w.at(row, rj);
            eps += out.at(row, pj) * col_mean[pj];
            *out.at_mut(row, pj) = 0.0;
        }
    }
    out
}

pub fn compress(w: &Matrix, stats: &CalibStats, cfg: &CompressConfig) -> Result<CompressedLayer> {
    anyhow::ensure!(w.cols == stats.gram.cols, "stats dim mismatch");
    // Initialize from both Wanda and SparseGPT masks; keep the refinement
    // with the lower weighted reconstruction error (the paper reports the
    // better of the two per benchmark, §A.14).
    let wanda_cfg = CompressConfig { method: Method::Wanda, ..cfg.clone() };
    let wanda_init = wanda::compress(w, stats, &wanda_cfg)?.to_dense();
    let sgpt_init = super::sparsegpt::compress(
        w,
        stats,
        &CompressConfig { method: Method::SparseGpt, ..cfg.clone() },
    )?
    .to_dense();

    let d = stats.scale_d();
    let err = |wc: &Matrix| -> f64 {
        let mut e = w.clone();
        e.axpy(-1.0, wc);
        e.mul_columns(&d).fro_norm()
    };

    let r1 = refine(w, &wanda_init, stats, cfg.pattern);
    let r2 = refine(w, &sgpt_init, stats, cfg.pattern);
    let best = if err(&r1) <= err(&r2) { r1 } else { r2 };
    Ok(CompressedLayer::Sparse(Csr::from_dense(&best)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn stats_with_bias(din: usize, seed: u64) -> (Matrix, CalibStats) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::randn(128, din, 1.0, &mut rng);
        // Nonzero feature means so ε is informative.
        for r in 0..x.rows {
            for j in 0..din {
                *x.at_mut(r, j) += (j % 5) as f32 * 0.5;
            }
        }
        let s = CalibStats::from_activations(&x);
        (x, s)
    }

    #[test]
    fn preserves_sparsity_budget() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(12, 32, 1.0, &mut rng);
        let (_, stats) = stats_with_bias(32, 2);
        let cfg = CompressConfig { method: Method::DsNoT, rate: 0.5, ..Default::default() };
        let init = wanda::compress(&w, &stats, &cfg).unwrap().to_dense();
        let refined = refine(&w, &init, &stats, cfg.pattern);
        assert_eq!(refined.nnz(), init.nnz(), "swaps must preserve nnz");
    }

    #[test]
    fn refinement_reduces_expected_error() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(16, 48, 1.0, &mut rng);
        let (_, stats) = stats_with_bias(48, 4);
        let cfg = CompressConfig { method: Method::DsNoT, rate: 0.6, ..Default::default() };
        let init = wanda::compress(&w, &stats, &cfg).unwrap().to_dense();
        let refined = refine(&w, &init, &stats, cfg.pattern);
        let eps = |m: &Matrix| -> f64 {
            let mut total = 0.0;
            for row in 0..w.rows {
                let e: f32 = (0..w.cols)
                    .filter(|&j| m.at(row, j) == 0.0)
                    .map(|j| w.at(row, j) * stats.col_mean[j])
                    .sum();
                total += (e as f64).abs();
            }
            total
        };
        assert!(eps(&refined) <= eps(&init) + 1e-6, "{} > {}", eps(&refined), eps(&init));
    }

    #[test]
    fn end_to_end_rate() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(16, 32, 1.0, &mut rng);
        let (_, stats) = stats_with_bias(32, 6);
        let cfg = CompressConfig { method: Method::DsNoT, rate: 0.5, ..Default::default() };
        let out = compress(&w, &stats, &cfg).unwrap();
        assert!((out.compression_rate((16, 32)) - 0.5).abs() < 0.06);
    }

    #[test]
    fn nm_masks_left_untouched() {
        let mut rng = Rng::new(7);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let (_, stats) = stats_with_bias(16, 8);
        let pattern = SparsityPattern::Nm { n: 2, m: 4 };
        let k = crate::compress::params::solve(8, 16, 0.5, 0.0).nonzeros;
        let init = super::super::threshold::hard_threshold(&w, &w, k, pattern);
        let refined = refine(&w, &init, &stats, pattern);
        assert_eq!(refined.data, init.data);
    }
}
