//! SparseGPT (Frantar & Alistarh, 2023): one-shot pruning with second-order
//! (OBS) weight updates.
//!
//! Per layer: H = XᵀX + λI; R = chol(H⁻¹)ᵀ (upper). Columns are processed
//! left-to-right in blocks of `BLOCK`: inside a block, each pruned weight's
//! error `w_j / R_jj` is propagated into the not-yet-processed columns via
//! the corresponding row of R, exactly as in the reference implementation
//! (paper §A.14.1: blocksize 128, dampening 1% of mean diag, escalating to
//! 10% on Cholesky failure).

use super::{params, CalibStats, CompressedLayer};
use crate::config::{CompressConfig, SparsityPattern};
use crate::linalg;
use crate::sparse::Csr;
use crate::tensor::Matrix;
use anyhow::Result;

const BLOCK: usize = 128;

pub fn compress(w: &Matrix, stats: &CalibStats, cfg: &CompressConfig) -> Result<CompressedLayer> {
    anyhow::ensure!(w.cols == stats.gram.cols, "stats dim mismatch");
    let din = w.cols;
    let dout = w.rows;

    // Dampened Hessian, with dead columns pinned (their weights are pruned
    // unconditionally, matching the reference implementation).
    let mut h = stats.gram.clone();
    let mut dead = vec![false; din];
    for j in 0..din {
        if h.at(j, j) <= 0.0 {
            dead[j] = true;
            *h.at_mut(j, j) = 1.0;
        }
    }
    let mean_diag: f32 = (0..din).map(|j| h.at(j, j)).sum::<f32>() / din as f32;
    // Paper A.14.1: λ = 0.01·mean, escalate to 0.1 on Cholesky failure.
    let mut hinv_r = None;
    for damp in [0.01f32, 0.1] {
        let mut hd = h.clone();
        for j in 0..din {
            *hd.at_mut(j, j) += damp * mean_diag;
        }
        if let Some(r) = linalg::upper_cholesky_of_inverse(&hd) {
            hinv_r = Some(r);
            break;
        }
    }
    let r = hinv_r.ok_or_else(|| anyhow::anyhow!("Hessian not PD even at 10% dampening"))?;

    let mut wk = w.clone();
    for (j, &is_dead) in dead.iter().enumerate() {
        if is_dead {
            wk.scale_column(j, 0.0);
        }
    }

    let target_sparsity = cfg.rate; // κ=0 accounting: k = (1−ρ)·dout·din
    let _ = params::solve(dout, din, cfg.rate, 0.0);

    // Per-row pruned masks are chosen per block from the OBS saliency
    // s_j = w_j² / R_jj².
    for b0 in (0..din).step_by(BLOCK) {
        let b1 = (b0 + BLOCK).min(din);
        let bw = b1 - b0;

        // Saliency scores for this block.
        let mut mask_prune = vec![false; dout * bw]; // true = prune
        match cfg.pattern {
            SparsityPattern::Nm { n, m } => {
                for row in 0..dout {
                    for g in (b0..b1).step_by(m) {
                        let gend = (g + m).min(b1);
                        let mut scored: Vec<(f32, usize)> = (g..gend)
                            .map(|j| {
                                let rjj = r.at(j, j);
                                let s = (wk.at(row, j) / rjj).powi(2);
                                (s, j)
                            })
                            .collect();
                        // total_cmp: a 0/0 saliency (dead column) is NaN and
                        // must sort deterministically, not panic mid-sweep.
                        scored.sort_by(|a, b| b.0.total_cmp(&a.0));
                        let keep = if gend - g == m {
                            n
                        } else {
                            (n * (gend - g)).div_ceil(m)
                        };
                        for &(_, j) in scored.iter().skip(keep) {
                            mask_prune[row * bw + (j - b0)] = true;
                        }
                    }
                }
            }
            _ => {
                // Unstructured: per-row threshold within the block at the
                // target sparsity (reference implementation's behaviour).
                let n_prune = ((bw as f64) * target_sparsity).round() as usize;
                for row in 0..dout {
                    let mut scored: Vec<(f32, usize)> = (b0..b1)
                        .map(|j| {
                            let rjj = r.at(j, j);
                            ((wk.at(row, j) / rjj).powi(2), j)
                        })
                        .collect();
                    scored.sort_by(|a, b| a.0.total_cmp(&b.0));
                    for &(_, j) in scored.iter().take(n_prune) {
                        mask_prune[row * bw + (j - b0)] = true;
                    }
                }
            }
        }

        // OBS sweep within the block: zero pruned weights, propagate errors.
        // err_row accumulates per-row error vectors for the trailing update.
        let mut errs = Matrix::zeros(dout, bw);
        for j in b0..b1 {
            let rjj = r.at(j, j);
            for row in 0..dout {
                let wv = wk.at(row, j);
                let e = if mask_prune[row * bw + (j - b0)] {
                    // err = w_j / R_jj ; w_j ← 0
                    let e = wv / rjj;
                    *wk.at_mut(row, j) = 0.0;
                    e
                } else {
                    0.0
                };
                errs.data[row * bw + (j - b0)] = e;
                if e != 0.0 {
                    // In-block compensation: w[:, j+1..b1] -= e · R[j, j+1..b1]
                    for jj in (j + 1)..b1 {
                        *wk.at_mut(row, jj) -= e * r.at(j, jj);
                    }
                }
            }
        }
        // Trailing update for columns beyond the block:
        // W[:, b1..] -= errs · R[b0..b1, b1..]
        if b1 < din {
            for row in 0..dout {
                for j in b0..b1 {
                    let e = errs.data[row * bw + (j - b0)];
                    if e != 0.0 {
                        for jj in b1..din {
                            *wk.at_mut(row, jj) -= e * r.at(j, jj);
                        }
                    }
                }
            }
        }
    }

    Ok(CompressedLayer::Sparse(Csr::from_dense(&wk)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::util::prng::Rng;

    fn cfg(rate: f64, pattern: SparsityPattern) -> CompressConfig {
        CompressConfig { method: Method::SparseGpt, rate, pattern, ..Default::default() }
    }

    #[test]
    fn achieves_sparsity() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 64, 1.0, &mut rng);
        let x = Matrix::randn(128, 64, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        let out = compress(&w, &stats, &cfg(0.5, SparsityPattern::RowWise)).unwrap();
        let rate = out.compression_rate((16, 64));
        assert!((rate - 0.5).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn reconstruction_better_than_magnitude() {
        // SparseGPT's OBS update should beat plain magnitude pruning on the
        // calibration objective ‖(W − Ŵ)X‖.
        let mut rng = Rng::new(2);
        let w = Matrix::randn(24, 48, 1.0, &mut rng);
        let mut x = Matrix::randn(256, 48, 1.0, &mut rng);
        // correlated + outlier columns make the Hessian non-trivial
        for r in 0..x.rows {
            let v = x.at(r, 0);
            *x.at_mut(r, 1) = 0.9 * v + 0.1 * x.at(r, 1);
            *x.at_mut(r, 2) *= 8.0;
        }
        let stats = CalibStats::from_activations(&x);
        let c = cfg(0.6, SparsityPattern::RowWise);
        let sg = compress(&w, &stats, &c).unwrap().to_dense();
        let mag = super::super::magnitude::compress(&w, &c).unwrap().to_dense();
        let err = |wc: &Matrix| {
            let mut d = w.clone();
            d.axpy(-1.0, wc);
            crate::tensor::matmul_bt(&x, &d).fro_norm()
        };
        assert!(err(&sg) < err(&mag), "sparsegpt {} !< magnitude {}", err(&sg), err(&mag));
    }

    #[test]
    fn nm_pattern_valid() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 32, 1.0, &mut rng);
        let x = Matrix::randn(64, 32, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        let out =
            compress(&w, &stats, &cfg(0.5, SparsityPattern::Nm { n: 2, m: 4 })).unwrap();
        assert!(crate::sparse::NmPattern::TWO_FOUR.validates(&out.to_dense()));
    }

    #[test]
    fn nan_saliency_scores_do_not_panic_the_sort() {
        // Regression: the per-block saliency sorts used
        // `partial_cmp(..).unwrap()`, so one NaN weight (or 0/0 score)
        // panicked the whole compression pass. With `total_cmp`, NaN
        // scores sort deterministically (to the always-keep end for the
        // descending N:M sort, to the always-prune end ascending) and the
        // sweep completes for both patterns.
        let mut rng = Rng::new(5);
        let mut w = Matrix::randn(4, 16, 1.0, &mut rng);
        *w.at_mut(1, 3) = f32::NAN;
        let x = Matrix::randn(64, 16, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        for pattern in [SparsityPattern::RowWise, SparsityPattern::Nm { n: 2, m: 4 }] {
            let out = compress(&w, &stats, &cfg(0.5, pattern)).unwrap();
            let _ = out.to_dense(); // must complete without panicking
        }
    }

    #[test]
    fn dead_columns_pruned() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let mut x = Matrix::randn(32, 8, 1.0, &mut rng);
        for r in 0..x.rows {
            *x.at_mut(r, 3) = 0.0; // dead input feature
        }
        let stats = CalibStats::from_activations(&x);
        let out = compress(&w, &stats, &cfg(0.25, SparsityPattern::RowWise)).unwrap();
        let d = out.to_dense();
        for row in 0..4 {
            assert_eq!(d.at(row, 3), 0.0);
        }
    }
}
