//! Magnitude pruning — the classical baseline: keep the largest-|W| entries.

use super::{params, threshold, CompressedLayer};
use crate::config::CompressConfig;
use crate::sparse::Csr;
use crate::tensor::Matrix;
use anyhow::Result;

pub fn compress(w: &Matrix, cfg: &CompressConfig) -> Result<CompressedLayer> {
    let k = params::solve(w.rows, w.cols, cfg.rate, 0.0).nonzeros;
    let pruned = threshold::hard_threshold(w, w, k, cfg.pattern);
    Ok(CompressedLayer::Sparse(Csr::from_dense(&pruned)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, SparsityPattern};

    #[test]
    fn keeps_largest() {
        let w = Matrix::from_vec(2, 2, vec![1.0, -9.0, 0.5, 4.0]);
        let cfg = CompressConfig {
            method: Method::Magnitude,
            rate: 0.5,
            pattern: SparsityPattern::LayerWise,
            ..Default::default()
        };
        let out = compress(&w, &cfg).unwrap();
        assert_eq!(out.to_dense().data, vec![0.0, -9.0, 0.0, 4.0]);
    }

    #[test]
    fn rate_achieved() {
        let mut g = crate::util::prop::Gen::new(1);
        let w = Matrix::from_vec(32, 32, g.vec_normal(1024, 1.0));
        let cfg = CompressConfig {
            method: Method::Magnitude,
            rate: 0.6,
            pattern: SparsityPattern::RowWise,
            ..Default::default()
        };
        let out = compress(&w, &cfg).unwrap();
        assert!((out.compression_rate((32, 32)) - 0.6).abs() < 0.05);
    }
}
