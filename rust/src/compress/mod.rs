//! The compression algorithms: OATS (the paper's contribution) and every
//! baseline it is benchmarked against (magnitude, Wanda, SparseGPT, DSNoT),
//! plus OWL non-uniform layerwise rates.
//!
//! All compressors share one entry point, [`compress_layer`], which takes the
//! dense weight `W` (out×in), the layer's calibration statistics, and a
//! [`CompressConfig`], and returns a [`CompressedLayer`].

pub mod dsnot;
pub mod magnitude;
pub mod oats;
pub mod owl;
pub mod params;
pub mod slice;
pub mod sparsegpt;
pub mod threshold;
pub mod wanda;

use crate::config::{CompressConfig, Method};
use crate::sparse::{Csr, SparsePlusLowRank};
use crate::tensor::Matrix;
use crate::util::prng::Rng;
use anyhow::Result;

/// Per-layer activation statistics gathered by the calibration pipeline
/// (Algorithm 2's `Xᵀ X` plus the extras the baselines/ablations need).
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// Gram matrix XᵀX, din×din (SparseGPT Hessian; its diagonal feeds
    /// OATS/Wanda scaling).
    pub gram: Matrix,
    /// Column means E[x_j] (DSNoT's reconstruction-error criterion).
    pub col_mean: Vec<f32>,
    /// A row subsample of X for the robust (median) scaling ablation (A.3):
    /// a deterministic reservoir over ALL observed rows, so late-batch
    /// activations are represented, not just the first batch.
    pub sample_rows: Matrix,
    /// Number of rows (batch·seq) accumulated.
    pub n_samples: usize,
    /// Deterministic stream driving the sample-row reservoir.
    reservoir_rng: Rng,
}

impl CalibStats {
    pub fn new(din: usize) -> CalibStats {
        CalibStats {
            gram: Matrix::zeros(din, din),
            col_mean: vec![0.0; din],
            sample_rows: Matrix::zeros(0, din),
            n_samples: 0,
            reservoir_rng: Rng::new(0xCA11B ^ din as u64),
        }
    }

    /// Accumulate a batch of activations X [b × din].
    pub fn update(&mut self, x: &Matrix, keep_samples: usize) {
        assert_eq!(x.cols, self.gram.cols);
        // gram += XᵀX (rank-b update)
        for r in 0..x.rows {
            let row = x.row(r);
            for i in 0..x.cols {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                let g = &mut self.gram.data[i * x.cols..(i + 1) * x.cols];
                for (gv, &xj) in g.iter_mut().zip(row) {
                    *gv += xi * xj;
                }
            }
        }
        for r in 0..x.rows {
            for (m, &v) in self.col_mean.iter_mut().zip(x.row(r)) {
                *m += v;
            }
        }
        // Reservoir-sample `keep_samples` rows (Algorithm R, deterministic
        // stream) over every row ever observed. Keeping only the FIRST
        // `keep_samples` rows biased the robust-scaling median toward the
        // first calibration batch; the reservoir gives every row an equal
        // chance regardless of arrival order.
        for r in 0..x.rows {
            if self.sample_rows.rows < keep_samples {
                self.sample_rows.data.extend_from_slice(x.row(r));
                self.sample_rows.rows += 1;
            } else if self.sample_rows.rows > 0 {
                let seen = self.n_samples + r;
                let j = self.reservoir_rng.below(seen + 1);
                if j < self.sample_rows.rows {
                    self.sample_rows.row_mut(j).copy_from_slice(x.row(r));
                }
            }
        }
        self.n_samples += x.rows;
    }

    /// Finalized mean (update() accumulates sums).
    pub fn finalize(&mut self) {
        if self.n_samples > 0 {
            let inv = 1.0 / self.n_samples as f32;
            for m in &mut self.col_mean {
                *m *= inv;
            }
        }
    }

    /// D = sqrt(diag(XᵀX)) — the paper's outlier scaling (§2.3). Zero
    /// columns get scale 1 so D stays invertible.
    pub fn scale_d(&self) -> Vec<f32> {
        (0..self.gram.cols)
            .map(|i| {
                let d = self.gram.at(i, i).max(0.0).sqrt();
                if d > 1e-12 {
                    d
                } else {
                    1.0
                }
            })
            .collect()
    }

    /// ‖x_j‖₂ per column (Wanda's score scale — identical to `scale_d`).
    pub fn col_norms(&self) -> Vec<f32> {
        self.scale_d()
    }

    /// D_robust = median(|X|) per column (Appendix A.3). Falls back to
    /// `scale_d` if no samples were retained.
    pub fn robust_scale(&self) -> Vec<f32> {
        if self.sample_rows.rows == 0 {
            return self.scale_d();
        }
        let n = self.sample_rows.rows;
        (0..self.sample_rows.cols)
            .map(|j| {
                let mut col: Vec<f32> =
                    (0..n).map(|r| self.sample_rows.at(r, j).abs()).collect();
                // total_cmp: NaN activations (upstream 0/0) sort above every
                // finite |x| instead of panicking the calibration pass.
                col.sort_by(|a, b| a.total_cmp(b));
                let med = col[n / 2];
                if med > 1e-12 {
                    med
                } else {
                    1.0
                }
            })
            .collect()
    }

    /// Convenience for tests: stats equivalent to observing X directly.
    pub fn from_activations(x: &Matrix) -> CalibStats {
        let mut s = CalibStats::new(x.cols);
        s.update(x, x.rows.min(256));
        s.finalize();
        s
    }
}

/// Result of compressing one linear layer.
#[derive(Clone, Debug)]
pub enum CompressedLayer {
    /// Untouched dense weight (method = Dense or excluded layer).
    Dense(Matrix),
    /// Sparse-only result stored in CSR (magnitude/Wanda/SparseGPT/DSNoT,
    /// or OATS with κ=0).
    Sparse(Csr),
    /// OATS' sparse + low-rank decomposition.
    Spl(SparsePlusLowRank),
    /// Rotate-and-slice result: a dense weight in the SLICED shape plus the
    /// index maps back into the original dense dimensions. `shape()` reports
    /// the sliced dims (what the forward path sees); rate accounting uses
    /// the maps' `full` sizes.
    SlicedDense {
        w: Matrix,
        in_map: slice::SliceMap,
        out_map: slice::SliceMap,
    },
}

impl CompressedLayer {
    /// Dense reconstruction IN THE LAYER'S OWN SHAPE, for evaluation paths
    /// that want plain GEMM. For `SlicedDense` this is the sliced weight;
    /// use [`CompressedLayer::to_original_dense`] for the pre-slice shape.
    pub fn to_dense(&self) -> Matrix {
        match self {
            CompressedLayer::Dense(w) => w.clone(),
            CompressedLayer::Sparse(s) => s.to_dense(),
            CompressedLayer::Spl(spl) => spl.to_dense(),
            CompressedLayer::SlicedDense { w, .. } => w.clone(),
        }
    }

    /// Dense reconstruction in the ORIGINAL dense shape (sliced channels
    /// scattered back to their source indices, deleted channels zero).
    pub fn to_original_dense(&self) -> Matrix {
        match self {
            CompressedLayer::SlicedDense { w, in_map, out_map } => {
                slice::scatter_to_original(w, out_map, in_map)
            }
            other => other.to_dense(),
        }
    }

    /// Nonzero parameters, per the paper's compression-rate accounting.
    pub fn param_count(&self) -> usize {
        match self {
            CompressedLayer::Dense(w) => w.rows * w.cols,
            CompressedLayer::Sparse(s) => s.nnz(),
            CompressedLayer::Spl(spl) => spl.param_count(),
            CompressedLayer::SlicedDense { w, .. } => w.rows * w.cols,
        }
    }

    /// The shape the forward path consumes (sliced dims for `SlicedDense`).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            CompressedLayer::Dense(w) => (w.rows, w.cols),
            CompressedLayer::Sparse(s) => (s.rows, s.cols),
            CompressedLayer::Spl(spl) => (spl.sparse.rows, spl.sparse.cols),
            CompressedLayer::SlicedDense { w, .. } => (w.rows, w.cols),
        }
    }

    /// The pre-compression dense shape — the correct rate denominator.
    /// Identical to `shape()` for every variant except `SlicedDense`.
    pub fn original_shape(&self) -> (usize, usize) {
        match self {
            CompressedLayer::SlicedDense { in_map, out_map, .. } => {
                (out_map.full, in_map.full)
            }
            other => other.shape(),
        }
    }

    /// Achieved compression rate 1 − params/original. The original dense
    /// shape is an explicit argument: deriving the denominator from
    /// `shape()` over-reports the rate for any shape-changing variant
    /// (a sliced layer's own shape is already smaller than the weight it
    /// replaced).
    pub fn compression_rate(&self, original: (usize, usize)) -> f64 {
        let (r, c) = original;
        assert!(r > 0 && c > 0, "degenerate original shape {original:?}");
        1.0 - self.param_count() as f64 / (r * c) as f64
    }
}

/// Compress one layer with the configured method. `cfg.rate` is the target
/// for THIS layer (the coordinator applies OWL adjustments before calling).
pub fn compress_layer(
    w: &Matrix,
    stats: &CalibStats,
    cfg: &CompressConfig,
) -> Result<CompressedLayer> {
    match cfg.method {
        Method::Dense => Ok(CompressedLayer::Dense(w.clone())),
        Method::Magnitude => magnitude::compress(w, cfg),
        Method::Wanda => wanda::compress(w, stats, cfg),
        Method::SparseGpt => sparsegpt::compress(w, stats, cfg),
        Method::DsNoT => dsnot::compress(w, stats, cfg),
        Method::Oats => oats::compress(w, stats, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn calib_stats_gram_matches_direct() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(50, 8, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        let direct = crate::tensor::matmul(&x.transpose(), &x);
        assert!(stats.gram.fro_dist(&direct) < 1e-2);
        assert_eq!(stats.n_samples, 50);
    }

    #[test]
    fn calib_stats_incremental_equals_batch() {
        let mut rng = Rng::new(2);
        let x = Matrix::randn(40, 6, 1.0, &mut rng);
        let full = CalibStats::from_activations(&x);
        let mut inc = CalibStats::new(6);
        let half1 = Matrix::from_vec(20, 6, x.data[..120].to_vec());
        let half2 = Matrix::from_vec(20, 6, x.data[120..].to_vec());
        inc.update(&half1, 256);
        inc.update(&half2, 256);
        inc.finalize();
        assert!(inc.gram.fro_dist(&full.gram) < 1e-3);
        for (a, b) in inc.col_mean.iter().zip(&full.col_mean) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn scale_d_handles_zero_columns() {
        let x = Matrix::from_vec(3, 2, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let stats = CalibStats::from_activations(&x);
        let d = stats.scale_d();
        assert!((d[0] - (14.0f32).sqrt()).abs() < 1e-4);
        assert_eq!(d[1], 1.0); // dead column → safe scale
    }

    #[test]
    fn robust_scale_is_median() {
        let x = Matrix::from_vec(3, 1, vec![-1.0, 10.0, 2.0]);
        let stats = CalibStats::from_activations(&x);
        let d = stats.robust_scale();
        assert!((d[0] - 2.0).abs() < 1e-6); // median(1,10,2)=2
    }

    #[test]
    fn reservoir_keeps_all_rows_when_under_capacity() {
        // Streams shorter than the reservoir keep every row, in order —
        // the first-fill path is unchanged.
        let mut rng = Rng::new(6);
        let x = Matrix::randn(10, 3, 1.0, &mut rng);
        let mut s = CalibStats::new(3);
        s.update(&x, 64);
        s.finalize();
        assert_eq!(s.sample_rows, x);
    }

    #[test]
    fn reservoir_sees_late_batch_outliers() {
        // The old behavior kept only the FIRST `keep_samples` rows, so a
        // late outlier regime could never move the robust scale. With 8
        // early rows at |x| = 1 and 1024 late rows at |x| = 100 through a
        // reservoir of 8, the deterministic reservoir is dominated by late
        // rows and the median sits at the late scale.
        let mut s = CalibStats::new(2);
        s.update(&Matrix::filled(8, 2, 1.0), 8);
        for _ in 0..16 {
            s.update(&Matrix::filled(64, 2, 100.0), 8);
        }
        s.finalize();
        assert_eq!(s.sample_rows.rows, 8, "reservoir never exceeds capacity");
        assert_eq!(s.n_samples, 8 + 16 * 64);
        let d = s.robust_scale();
        assert!(
            (d[0] - 100.0).abs() < 1e-6,
            "median must reflect the late batches, got {}",
            d[0]
        );
    }

    #[test]
    fn robust_scale_survives_nan_samples() {
        // Regression: the median sort used `partial_cmp(..).unwrap()` and
        // panicked on the first NaN activation (e.g. an upstream 0/0).
        // With `total_cmp`, NaN sorts above every finite |x| and the
        // median of the mostly-finite column stays finite.
        let x = Matrix::from_vec(5, 1, vec![1.0, f32::NAN, 2.0, 3.0, 4.0]);
        let stats = CalibStats::from_activations(&x);
        let d = stats.robust_scale();
        assert!(d[0].is_finite(), "NaN sample must not poison the median: {}", d[0]);
        assert!((d[0] - 3.0).abs() < 1e-6, "median(|1,NaN,2,3,4|) keeps NaN last");
    }

    #[test]
    fn dense_method_is_identity() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&Matrix::randn(16, 8, 1.0, &mut rng));
        let cfg = CompressConfig { method: Method::Dense, ..Default::default() };
        let out = compress_layer(&w, &stats, &cfg).unwrap();
        assert!(out.to_dense().fro_dist(&w) < 1e-9);
        assert_eq!(out.compression_rate((8, 8)), 0.0);
    }

    #[test]
    fn compression_rate_accounts_against_original_shape() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let x = Matrix::randn(32, 8, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&x);
        let orig = (8, 8);

        // Sparse at rate 0.5: shape is unchanged, so the explicit original
        // shape agrees with the layer's own shape.
        let cfg = CompressConfig { method: Method::Wanda, rate: 0.5, ..Default::default() };
        let sparse = compress_layer(&w, &stats, &cfg).unwrap();
        assert_eq!(sparse.shape(), sparse.original_shape());
        assert!((sparse.compression_rate(orig) - 0.5).abs() < 0.05);

        // SPL: same invariant, budget split across S and L.
        let cfg =
            CompressConfig { method: Method::Oats, rate: 0.5, iters: 5, ..Default::default() };
        let spl = compress_layer(&w, &stats, &cfg).unwrap();
        assert_eq!(spl.shape(), spl.original_shape());
        assert!((spl.compression_rate(orig) - 0.5).abs() < 0.05);

        // Sliced: keeping half the output rows of an 8×8 halves the params.
        // The latent bug: a shape()-based denominator (4·8) would report
        // rate 0 here; the original-shape denominator reports 0.5.
        let sliced = CompressedLayer::SlicedDense {
            w: Matrix::randn(4, 8, 1.0, &mut rng),
            in_map: slice::SliceMap::identity(8),
            out_map: slice::SliceMap { kept: vec![0, 2, 4, 6], full: 8 },
        };
        assert_eq!(sliced.shape(), (4, 8));
        assert_eq!(sliced.original_shape(), (8, 8));
        let wrong_denominator = {
            let (r, c) = sliced.shape();
            1.0 - sliced.param_count() as f64 / (r * c) as f64
        };
        assert_eq!(wrong_denominator, 0.0);
        assert_eq!(sliced.compression_rate(orig), 0.5);
    }

    #[test]
    fn sliced_to_original_dense_scatters_back() {
        let w = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let sliced = CompressedLayer::SlicedDense {
            w,
            in_map: slice::SliceMap { kept: vec![2, 0], full: 3 },
            out_map: slice::SliceMap { kept: vec![1, 3], full: 4 },
        };
        let full = sliced.to_original_dense();
        assert_eq!((full.rows, full.cols), (4, 3));
        assert_eq!(full.at(1, 2), 1.0);
        assert_eq!(full.at(1, 0), 2.0);
        assert_eq!(full.at(3, 2), 3.0);
        assert_eq!(full.at(3, 0), 4.0);
        assert_eq!(full.nnz(), 4);
    }
}
