//! SliceGPT-style rotate-and-slice structured compression (Ashkboos et al.,
//! see PAPERS.md), specialized to the FFN pair.
//!
//! The general recipe rotates a hidden dimension into the eigenbasis of its
//! calibration gram, folds the rotation into the adjacent weights, and
//! deletes the lowest-energy trailing columns. Between `up` and `down` sits
//! an elementwise GELU, which does NOT commute with an arbitrary rotation —
//! but it commutes with any *permutation*, and permutations are orthogonal.
//! So the rotation Q used here is the energy-ranked permutation of the d_ff
//! channels: channel energies come from the eigendecomposition of the
//! post-GELU gram (the `linalg.rs` eigen path), channels are reordered
//! energy-descending, and slicing keeps the leading (highest-energy) block.
//! Folding Q into the weights is then exact row/column selection:
//! `up`'s output rows and `down`'s input columns, one shared kept set per
//! block, with no runtime rotation matmul surviving.

use crate::compress::CalibStats;
use crate::linalg::jacobi_eigh;
use crate::tensor::Matrix;

/// Index map from a sliced dimension back into the original dense dimension.
///
/// `kept[i]` is the original channel index occupying sliced position `i`.
/// Entries are ordered energy-descending, so at slice rate 0 the map is a
/// genuine permutation of `0..full` (not necessarily the identity).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SliceMap {
    /// Kept original indices, energy-descending.
    pub kept: Vec<u32>,
    /// Size of the original dense dimension.
    pub full: usize,
}

impl SliceMap {
    /// The trivial map for an unsliced dimension.
    pub fn identity(full: usize) -> SliceMap {
        SliceMap { kept: (0..full as u32).collect(), full }
    }

    /// Sliced dimension size.
    pub fn len(&self) -> usize {
        self.kept.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kept.is_empty()
    }

    /// True iff this map neither reorders nor deletes channels.
    pub fn is_identity(&self) -> bool {
        self.kept.len() == self.full
            && self.kept.iter().enumerate().all(|(i, &k)| k as usize == i)
    }

    /// Internal consistency: indices in range and pairwise distinct.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.kept.len() <= self.full, "more kept than full");
        let mut seen = vec![false; self.full];
        for &k in &self.kept {
            let k = k as usize;
            anyhow::ensure!(k < self.full, "kept index {k} out of range {}", self.full);
            anyhow::ensure!(!seen[k], "duplicate kept index {k}");
            seen[k] = true;
        }
        Ok(())
    }
}

/// Per-channel second-moment energies E[x_j²]·n from the eigendecomposition
/// of the calibration gram: energy_j = Σ_k λ_k v_jk². (Algebraically the
/// gram diagonal, reconstructed through the eigen path so the ranking is
/// exactly the one the rotation basis induces.)
pub fn channel_energies(stats: &CalibStats) -> Vec<f64> {
    let n = stats.gram.rows;
    let (vals, vecs) = jacobi_eigh(&stats.gram);
    (0..n)
        .map(|j| {
            let mut e = 0.0f64;
            for (k, &lam) in vals.iter().enumerate() {
                let v = vecs.at(j, k) as f64;
                e += lam * v * v;
            }
            e.max(0.0)
        })
        .collect()
}

/// Rank channels energy-descending and keep the top `1 − slice_rate`
/// fraction. Ties break by original index so the map is deterministic.
/// At least one channel is always kept.
pub fn select_channels(energies: &[f64], slice_rate: f64) -> SliceMap {
    let full = energies.len();
    assert!(full > 0, "cannot slice an empty dimension");
    assert!((0.0..1.0).contains(&slice_rate), "slice_rate must be in [0,1)");
    let drop = (full as f64 * slice_rate).floor() as usize;
    let keep = full.saturating_sub(drop).max(1);
    let mut order: Vec<u32> = (0..full as u32).collect();
    // total_cmp: a NaN energy (degenerate gram) sorts below every finite
    // energy instead of panicking, and the ordering stays total.
    order.sort_by(|&a, &b| {
        energies[b as usize]
            .total_cmp(&energies[a as usize])
            .then(a.cmp(&b))
    });
    order.truncate(keep);
    SliceMap { kept: order, full }
}

/// Row-select `w` (out×in) down to the kept output channels, in map order.
pub fn select_rows(w: &Matrix, kept: &[u32]) -> Matrix {
    let mut out = Matrix::zeros(kept.len(), w.cols);
    for (ri, &ro) in kept.iter().enumerate() {
        out.row_mut(ri).copy_from_slice(w.row(ro as usize));
    }
    out
}

/// Column-select `w` (out×in) down to the kept input channels, in map order.
pub fn select_cols(w: &Matrix, kept: &[u32]) -> Matrix {
    let mut out = Matrix::zeros(w.rows, kept.len());
    for r in 0..w.rows {
        let src = w.row(r);
        let dst = out.row_mut(r);
        for (ci, &co) in kept.iter().enumerate() {
            dst[ci] = src[co as usize];
        }
    }
    out
}

/// Scatter a sliced weight back to the ORIGINAL dense shape: kept entries
/// return to their source indices, deleted channels stay zero. Used for
/// weight-space error accounting and for dense evaluation paths.
pub fn scatter_to_original(w: &Matrix, out_map: &SliceMap, in_map: &SliceMap) -> Matrix {
    assert_eq!(w.rows, out_map.len());
    assert_eq!(w.cols, in_map.len());
    let mut full = Matrix::zeros(out_map.full, in_map.full);
    for (ri, &ro) in out_map.kept.iter().enumerate() {
        let src = w.row(ri);
        let dst = full.row_mut(ro as usize);
        for (ci, &co) in in_map.kept.iter().enumerate() {
            dst[co as usize] = src[ci];
        }
    }
    full
}

/// One block's FFN pair after rotate-and-slice: `up` row-selected to
/// keep×d_model, `down` column-selected to d_model×keep, sharing `map`
/// over the d_ff dimension.
#[derive(Clone, Debug)]
pub struct SlicedPair {
    pub up: Matrix,
    pub down: Matrix,
    pub map: SliceMap,
}

/// Rotate-and-slice a block's FFN pair. `stats_down` is the calibration
/// gram of `down`'s INPUT (the post-GELU activations, d_ff wide) — the
/// dimension both weights share and the only contract-free dimension in
/// the block (attention and residual stream stay at d_model).
pub fn slice_ffn_pair(
    w_up: &Matrix,
    w_down: &Matrix,
    stats_down: &CalibStats,
    slice_rate: f64,
) -> SlicedPair {
    let d_ff = w_up.rows;
    assert_eq!(w_down.cols, d_ff, "FFN pair dims disagree");
    assert_eq!(stats_down.gram.rows, d_ff, "stats are not d_ff wide");
    let energies = channel_energies(stats_down);
    let map = select_channels(&energies, slice_rate);
    SlicedPair {
        up: select_rows(w_up, &map.kept),
        down: select_cols(w_down, &map.kept),
        map,
    }
}

/// Per-layer arbitration gate for the slice pass, mirroring `QuantGate`:
/// weight-space relative reconstruction error ‖W − scatter(Ŵ)‖_F / ‖W‖_F
/// against a configured bound. The pipeline keeps the sliced pair only when
/// BOTH layers accept.
#[derive(Clone, Copy, Debug)]
pub struct SliceGate {
    pub rel_error: f64,
    pub bound: f64,
}

impl SliceGate {
    /// Evaluate the gate for one layer: `orig` is the pre-slice dense
    /// weight, `scattered` its sliced reconstruction in the original shape.
    pub fn evaluate(orig: &Matrix, scattered: &Matrix, bound: f64) -> SliceGate {
        let denom = orig.fro_norm().max(1e-12);
        SliceGate { rel_error: orig.fro_dist(scattered) / denom, bound }
    }

    pub fn accept(&self) -> bool {
        self.rel_error <= self.bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn stats_with_channel_scales(scales: &[f32]) -> CalibStats {
        let mut rng = Rng::new(0x51C3);
        let mut x = Matrix::randn(64, scales.len(), 1.0, &mut rng);
        for (j, &s) in scales.iter().enumerate() {
            x.scale_column(j, s);
        }
        CalibStats::from_activations(&x)
    }

    #[test]
    fn energies_rank_by_activation_scale() {
        let stats = stats_with_channel_scales(&[1.0, 10.0, 0.1, 3.0]);
        let e = channel_energies(&stats);
        assert_eq!(e.len(), 4);
        assert!(e[1] > e[3] && e[3] > e[0] && e[0] > e[2], "{e:?}");
        // The eigen-path reconstruction must agree with the gram diagonal.
        for (j, &ej) in e.iter().enumerate() {
            let g = stats.gram.at(j, j) as f64;
            assert!((ej - g).abs() < 1e-2 * g.abs().max(1.0), "{j}: {ej} vs {g}");
        }
    }

    #[test]
    fn select_channels_rate_zero_is_full_permutation() {
        let stats = stats_with_channel_scales(&[1.0, 10.0, 0.1, 3.0]);
        let map = select_channels(&channel_energies(&stats), 0.0);
        assert_eq!(map.len(), 4);
        map.validate().unwrap();
        assert_eq!(map.kept, vec![1, 3, 0, 2], "energy-descending order");
        assert!(!map.is_identity());
    }

    #[test]
    fn select_channels_drops_lowest_energy() {
        let stats = stats_with_channel_scales(&[1.0, 10.0, 0.1, 3.0]);
        let map = select_channels(&channel_energies(&stats), 0.5);
        assert_eq!(map.kept, vec![1, 3], "the two weakest channels go");
        assert_eq!(map.full, 4);
    }

    #[test]
    fn select_channels_keeps_at_least_one_and_is_deterministic() {
        let e = vec![1.0; 8];
        let a = select_channels(&e, 0.99);
        assert_eq!(a.len(), 1);
        let b = select_channels(&e, 0.99);
        assert_eq!(a, b);
        // Uniform energies tie-break by index → leading channels survive.
        let half = select_channels(&e, 0.5);
        assert_eq!(half.kept, vec![0, 1, 2, 3]);
    }

    #[test]
    fn rate_zero_pair_scatter_is_bit_exact() {
        let mut rng = Rng::new(7);
        let w_up = Matrix::randn(16, 8, 1.0, &mut rng);
        let w_down = Matrix::randn(8, 16, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&Matrix::randn(32, 16, 1.0, &mut rng));
        let pair = slice_ffn_pair(&w_up, &w_down, &stats, 0.0);
        assert_eq!(pair.up.rows, 16);
        assert_eq!(pair.down.cols, 16);
        let up_back = scatter_to_original(
            &pair.up,
            &pair.map,
            &SliceMap::identity(8),
        );
        let down_back = scatter_to_original(
            &pair.down,
            &SliceMap::identity(8),
            &pair.map,
        );
        // Pure permutation: scatter-back restores the weights exactly.
        assert_eq!(up_back, w_up);
        assert_eq!(down_back, w_down);
        let g = SliceGate::evaluate(&w_up, &up_back, 0.75);
        assert_eq!(g.rel_error, 0.0);
        assert!(g.accept());
    }

    #[test]
    fn nonzero_rate_shrinks_and_gate_sees_error() {
        let mut rng = Rng::new(8);
        let w_up = Matrix::randn(16, 8, 1.0, &mut rng);
        let w_down = Matrix::randn(8, 16, 1.0, &mut rng);
        let stats = CalibStats::from_activations(&Matrix::randn(32, 16, 1.0, &mut rng));
        let pair = slice_ffn_pair(&w_up, &w_down, &stats, 0.25);
        assert_eq!(pair.up.rows, 12);
        assert_eq!(pair.down.cols, 12);
        assert_eq!(pair.up.cols, 8, "d_model untouched");
        assert_eq!(pair.down.rows, 8, "d_model untouched");
        let back = scatter_to_original(&pair.up, &pair.map, &SliceMap::identity(8));
        let g = SliceGate::evaluate(&w_up, &back, 1e-6);
        assert!(g.rel_error > 0.0, "dropped rows must register as error");
        assert!(!g.accept());
    }

    #[test]
    fn slice_map_validate_rejects_garbage() {
        assert!(SliceMap { kept: vec![0, 0], full: 4 }.validate().is_err());
        assert!(SliceMap { kept: vec![9], full: 4 }.validate().is_err());
        assert!(SliceMap { kept: vec![3, 1], full: 4 }.validate().is_ok());
        assert!(SliceMap::identity(4).is_identity());
    }
}
