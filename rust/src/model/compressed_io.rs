//! Persistence for *compressed* models, preserving the CSR + low-rank
//! structure on disk (the deployable artifact a serving fleet would ship —
//! `model::io::save` densifies, which defeats the compression).
//!
//! Format: `manifest.json` describing each layer's representation plus one
//! `weights.bin` blob. Dense tensors are raw f32; CSR stores
//! indptr (u32) / indices (u32) / values (f32); low-rank stores U and Vt.

use super::io;
use super::lm::{LinearOp, TransformerLM, LINEAR_NAMES};
use crate::compress::slice::SliceMap;
use crate::compress::CompressedLayer;
use crate::config::ModelConfig;
use crate::json::{self, Json};
use crate::sparse::{Csr, LowRank, PackOptions, PackedLinear, PackedSparse, SparsePlusLowRank};
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

struct Blob {
    bytes: Vec<u8>,
}

impl Blob {
    fn new() -> Blob {
        Blob { bytes: Vec::new() }
    }

    fn push_f32(&mut self, xs: &[f32]) -> (usize, usize) {
        let off = self.bytes.len();
        for &x in xs {
            self.bytes.extend_from_slice(&x.to_le_bytes());
        }
        (off, xs.len())
    }

    fn push_u32(&mut self, xs: &[u32]) -> (usize, usize) {
        let off = self.bytes.len();
        for &x in xs {
            self.bytes.extend_from_slice(&x.to_le_bytes());
        }
        (off, xs.len())
    }
}

fn read_f32(bytes: &[u8], off: usize, n: usize) -> Result<Vec<f32>> {
    let slice = bytes.get(off..off + 4 * n).context("blob too short (f32)")?;
    Ok(slice.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn read_u32(bytes: &[u8], off: usize, n: usize) -> Result<Vec<u32>> {
    let slice = bytes.get(off..off + 4 * n).context("blob too short (u32)")?;
    Ok(slice.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn tensor_entry(blob: &mut Blob, m: &Matrix) -> Json {
    let (off, n) = blob.push_f32(&m.data);
    let mut e = Json::obj();
    e.set("rows", json::num(m.rows as f64))
        .set("cols", json::num(m.cols as f64))
        .set("offset", json::num(off as f64))
        .set("len", json::num(n as f64));
    e
}

fn read_tensor(entry: &Json, bytes: &[u8]) -> Result<Matrix> {
    let rows = entry.req_usize("rows")?;
    let cols = entry.req_usize("cols")?;
    let off = entry.req_usize("offset")?;
    Ok(Matrix::from_vec(rows, cols, read_f32(bytes, off, rows * cols)?))
}

/// Recover the portable (dense/CSR/SPL/sliced) structure of a packed layer:
/// the on-disk format is pack-agnostic; `load_packed` re-derives kernel
/// plans.
fn unpacked_layer(p: &PackedLinear) -> CompressedLayer {
    // Slice metadata first: a sliced layer stores a dense block, and the
    // density heuristics below would otherwise drop its index maps.
    if let Some(meta) = p.slice() {
        if let PackedSparse::Dense(w) = p.sparse() {
            return CompressedLayer::SlicedDense {
                w: w.clone(),
                in_map: meta.in_map.clone(),
                out_map: meta.out_map.clone(),
            };
        }
    }
    let csr = match p.sparse() {
        PackedSparse::Dense(w) => {
            // A Dense *plan* can still hold a sparse weight (density above
            // the GEMM cutoff); keep the sparse structure on disk so the
            // round-trip preserves compression accounting.
            if p.low_rank().is_none() {
                if w.nnz() == w.rows * w.cols {
                    return CompressedLayer::Dense(w.clone());
                }
                return CompressedLayer::Sparse(Csr::from_dense(w));
            }
            Csr::from_dense(w)
        }
        PackedSparse::Csr(c) => c.clone(),
        PackedSparse::Bcsr(b) => b.to_csr(),
        // i8 tiles dequantize for the portable format: the on-disk
        // checkpoint never stores quantized values (quantization is a
        // pack-time decision, re-made on the next load).
        PackedSparse::QBcsr(q) => q.to_csr(),
        PackedSparse::Nm(nm) => nm.to_csr(),
    };
    match p.low_rank() {
        Some(lr) => CompressedLayer::Spl(SparsePlusLowRank {
            sparse: csr,
            low_rank: Some(lr.clone()),
        }),
        None => CompressedLayer::Sparse(csr),
    }
}

fn compressed_entry(blob: &mut Blob, layer: &CompressedLayer) -> Json {
    let mut e = Json::obj();
    match layer {
        CompressedLayer::Dense(w) => {
            e.set("kind", json::s("dense"));
            e.set("tensor", tensor_entry(blob, w));
        }
        CompressedLayer::Sparse(csr) => {
            e.set("kind", json::s("csr"));
            e.set("csr", csr_entry(blob, csr));
        }
        CompressedLayer::Spl(spl) => {
            e.set("kind", json::s("spl"));
            e.set("csr", csr_entry(blob, &spl.sparse));
            if let Some(lr) = &spl.low_rank {
                e.set("u", tensor_entry(blob, &lr.u));
                e.set("vt", tensor_entry(blob, &lr.vt));
            }
        }
        CompressedLayer::SlicedDense { w, in_map, out_map } => {
            // Versioned entry: the sliced format is newer than
            // oats-compressed-v1, so readers check the version explicitly
            // instead of relying on the manifest-wide format tag. Old
            // checkpoints never contain this kind and load unchanged.
            e.set("kind", json::s("sliced"));
            e.set("version", json::num(SLICED_ENTRY_VERSION as f64));
            e.set("tensor", tensor_entry(blob, w));
            e.set("in_map", slice_map_entry(blob, in_map));
            e.set("out_map", slice_map_entry(blob, out_map));
        }
    }
    e
}

/// Current version of the `"sliced"` manifest entry.
const SLICED_ENTRY_VERSION: usize = 1;

fn slice_map_entry(blob: &mut Blob, map: &SliceMap) -> Json {
    let (off, n) = blob.push_u32(&map.kept);
    let mut e = Json::obj();
    e.set("full", json::num(map.full as f64))
        .set("kept_off", json::num(off as f64))
        .set("kept_len", json::num(n as f64));
    e
}

fn read_slice_map(entry: &Json, bytes: &[u8]) -> Result<SliceMap> {
    let full = entry.req_usize("full")?;
    let n = entry.req_usize("kept_len")?;
    let map = SliceMap { kept: read_u32(bytes, entry.req_usize("kept_off")?, n)?, full };
    map.validate()?;
    Ok(map)
}

fn linear_entry(blob: &mut Blob, op: &LinearOp) -> Json {
    match op {
        LinearOp::Dense(w) => {
            let mut e = Json::obj();
            e.set("kind", json::s("dense"));
            e.set("tensor", tensor_entry(blob, w));
            e
        }
        LinearOp::Compressed(c) => compressed_entry(blob, c),
        LinearOp::Packed(p) => compressed_entry(blob, &unpacked_layer(p)),
    }
}

fn csr_entry(blob: &mut Blob, csr: &Csr) -> Json {
    let (off_p, n_p) = blob.push_u32(&csr.indptr);
    let (off_i, n_i) = blob.push_u32(&csr.indices);
    let (off_v, _) = blob.push_f32(&csr.values);
    let mut e = Json::obj();
    e.set("rows", json::num(csr.rows as f64))
        .set("cols", json::num(csr.cols as f64))
        .set("indptr_off", json::num(off_p as f64))
        .set("indptr_len", json::num(n_p as f64))
        .set("indices_off", json::num(off_i as f64))
        .set("nnz", json::num(n_i as f64))
        .set("values_off", json::num(off_v as f64));
    e
}

fn read_csr(entry: &Json, bytes: &[u8]) -> Result<Csr> {
    let rows = entry.req_usize("rows")?;
    let cols = entry.req_usize("cols")?;
    let nnz = entry.req_usize("nnz")?;
    Ok(Csr {
        rows,
        cols,
        indptr: read_u32(bytes, entry.req_usize("indptr_off")?, entry.req_usize("indptr_len")?)?,
        indices: read_u32(bytes, entry.req_usize("indices_off")?, nnz)?,
        values: read_f32(bytes, entry.req_usize("values_off")?, nnz)?,
    })
}

fn read_linear(entry: &Json, bytes: &[u8]) -> Result<LinearOp> {
    match entry.req_str("kind")? {
        "dense" => Ok(LinearOp::Dense(read_tensor(
            entry.get("tensor").context("dense missing tensor")?,
            bytes,
        )?)),
        "csr" => Ok(LinearOp::Compressed(CompressedLayer::Sparse(read_csr(
            entry.get("csr").context("csr missing")?,
            bytes,
        )?))),
        "spl" => {
            let sparse = read_csr(entry.get("csr").context("spl missing csr")?, bytes)?;
            let low_rank = match (entry.get("u"), entry.get("vt")) {
                (Some(u), Some(vt)) => Some(LowRank {
                    u: read_tensor(u, bytes)?,
                    vt: read_tensor(vt, bytes)?,
                }),
                _ => None,
            };
            Ok(LinearOp::Compressed(CompressedLayer::Spl(SparsePlusLowRank {
                sparse,
                low_rank,
            })))
        }
        "sliced" => {
            let version = entry.req_usize("version")?;
            anyhow::ensure!(
                version <= SLICED_ENTRY_VERSION,
                "sliced entry version {version} is newer than this reader"
            );
            let w = read_tensor(entry.get("tensor").context("sliced missing tensor")?, bytes)?;
            let in_map = read_slice_map(entry.get("in_map").context("sliced in_map")?, bytes)?;
            let out_map =
                read_slice_map(entry.get("out_map").context("sliced out_map")?, bytes)?;
            anyhow::ensure!(
                w.rows == out_map.len() && w.cols == in_map.len(),
                "sliced tensor {}x{} disagrees with maps {}x{}",
                w.rows,
                w.cols,
                out_map.len(),
                in_map.len()
            );
            Ok(LinearOp::Compressed(CompressedLayer::SlicedDense { w, in_map, out_map }))
        }
        other => anyhow::bail!("unknown linear kind '{other}'"),
    }
}

/// Save a (possibly compressed) model preserving layer structure.
pub fn save(model: &TransformerLM, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut blob = Blob::new();
    let mut manifest = Json::obj();
    manifest.set("format", json::s("oats-compressed-v1"));
    manifest.set("config", model.cfg.to_json());

    // Dense (never-pruned) tensors.
    let mut dense = Json::obj();
    dense.set("tok_emb", tensor_entry(&mut blob, &model.tok_emb));
    dense.set("pos_emb", tensor_entry(&mut blob, &model.pos_emb));
    dense.set("head", tensor_entry(&mut blob, &model.head));
    let vecm = |v: &Vec<f32>| Matrix::from_vec(1, v.len(), v.clone());
    dense.set("lnf_g", tensor_entry(&mut blob, &vecm(&model.lnf_g)));
    dense.set("lnf_b", tensor_entry(&mut blob, &vecm(&model.lnf_b)));
    manifest.set("dense", dense);

    // Blocks.
    let mut blocks = Vec::new();
    for blk in &model.blocks {
        let mut b = Json::obj();
        b.set("ln1_g", tensor_entry(&mut blob, &vecm(&blk.ln1_g)));
        b.set("ln1_b", tensor_entry(&mut blob, &vecm(&blk.ln1_b)));
        b.set("ln2_g", tensor_entry(&mut blob, &vecm(&blk.ln2_g)));
        b.set("ln2_b", tensor_entry(&mut blob, &vecm(&blk.ln2_b)));
        for name in LINEAR_NAMES {
            b.set(name, linear_entry(&mut blob, blk.linear(name)));
        }
        blocks.push(b);
    }
    manifest.set("blocks", Json::Arr(blocks));

    std::fs::write(dir.join("manifest.json"), manifest.to_pretty())?;
    let mut f = std::fs::File::create(dir.join("weights.bin"))?;
    f.write_all(&blob.bytes)?;
    Ok(())
}

/// Load a model saved by [`save`]. Falls back to the dense format
/// (`model::io::load`) if the manifest is not `oats-compressed-v1`.
pub fn load(dir: &Path) -> Result<TransformerLM> {
    let manifest = json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
    if manifest.get("format").and_then(Json::as_str) != Some("oats-compressed-v1") {
        return io::load(dir);
    }
    let cfg = ModelConfig::from_json(manifest.get("config").context("missing config")?)?;
    let mut bytes = Vec::new();
    std::fs::File::open(dir.join("weights.bin"))?.read_to_end(&mut bytes)?;

    let dense = manifest.get("dense").context("missing dense section")?;
    let get_t = |name: &str| -> Result<Matrix> {
        read_tensor(dense.get(name).with_context(|| format!("missing {name}"))?, &bytes)
    };
    let block_entries = manifest
        .get("blocks")
        .and_then(Json::as_arr)
        .context("missing blocks")?;
    anyhow::ensure!(block_entries.len() == cfg.n_layers, "block count mismatch");
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for b in block_entries {
        let vec_of = |name: &str| -> Result<Vec<f32>> {
            Ok(read_tensor(b.get(name).with_context(|| format!("missing {name}"))?, &bytes)?.data)
        };
        blocks.push(super::lm::Block {
            ln1_g: vec_of("ln1_g")?,
            ln1_b: vec_of("ln1_b")?,
            ln2_g: vec_of("ln2_g")?,
            ln2_b: vec_of("ln2_b")?,
            q: read_linear(b.get("q").context("q")?, &bytes)?,
            k: read_linear(b.get("k").context("k")?, &bytes)?,
            v: read_linear(b.get("v").context("v")?, &bytes)?,
            o: read_linear(b.get("o").context("o")?, &bytes)?,
            up: read_linear(b.get("up").context("up")?, &bytes)?,
            down: read_linear(b.get("down").context("down")?, &bytes)?,
        });
    }
    Ok(TransformerLM {
        cfg,
        tok_emb: get_t("tok_emb")?,
        pos_emb: get_t("pos_emb")?,
        blocks,
        lnf_g: get_t("lnf_g")?.data,
        lnf_b: get_t("lnf_b")?.data,
        head: get_t("head")?,
    })
}

/// Load a compressed checkpoint and pre-pack every compressed layer into
/// the serving format its kernel plan selects for `batch_hint` — the
/// deployment path: checkpoints go straight from disk into BCSR/N:M/CSR
/// tiles without materializing dense weights.
pub fn load_packed(dir: &Path, batch_hint: usize) -> Result<TransformerLM> {
    load_packed_with(dir, &PackOptions::for_batch(batch_hint))
}

/// [`load_packed`] with explicit packing options: `opts.quantize` turns on
/// i8 BCSR tiles (per-tile error gate included) at load time. The on-disk
/// format is unchanged — quantization happens while packing.
pub fn load_packed_with(dir: &Path, opts: &PackOptions) -> Result<TransformerLM> {
    let mut model = load(dir)?;
    model.pack_for_serving_with(opts);
    Ok(model)
}

/// On-disk size of the weights blob (bytes) — deployment accounting.
pub fn weights_size(dir: &Path) -> Result<u64> {
    Ok(std::fs::metadata(dir.join("weights.bin"))?.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::CalibSet;
    use crate::config::CompressConfig;
    use crate::coordinator::pipeline::compress_clone;
    use crate::data::{CorpusConfig, SyntheticCorpus};

    fn compressed_model() -> TransformerLM {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 0x10);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 3));
        let calib = CalibSet::sample(&corpus, 4, 16, 4);
        let cc = CompressConfig { rate: 0.5, rank_ratio: 0.25, iters: 3, ..Default::default() };
        compress_clone(&model, &calib, &cc, 2).unwrap().0
    }

    #[test]
    fn compressed_roundtrip_preserves_structure_and_logits() {
        let m = compressed_model();
        let dir = std::env::temp_dir().join(format!("oats_cio_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let m2 = load(&dir).unwrap();
        // Structure preserved: still SPL layers, same param counts.
        assert_eq!(m2.prunable_param_count(), m.prunable_param_count());
        assert!(matches!(
            m2.blocks[0].q,
            LinearOp::Compressed(CompressedLayer::Spl(_))
        ));
        // Numerics identical.
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        assert!(m.forward(&toks).fro_dist(&m2.forward(&toks)) < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compressed_file_smaller_than_dense() {
        let m = compressed_model();
        let dense_dir = std::env::temp_dir().join(format!("oats_cio_d_{}", std::process::id()));
        let comp_dir = std::env::temp_dir().join(format!("oats_cio_c_{}", std::process::id()));
        io::save(&m, &dense_dir).unwrap(); // densifying format
        save(&m, &comp_dir).unwrap();
        let dense_sz = weights_size(&dense_dir).unwrap();
        let comp_sz = weights_size(&comp_dir).unwrap();
        // CSR carries index overhead (8 bytes/nnz), so the win is smaller
        // than the parameter ratio, but must still be a real reduction.
        assert!(
            (comp_sz as f64) < (dense_sz as f64) * 0.95,
            "compressed {comp_sz} !< dense {dense_sz}"
        );
        std::fs::remove_dir_all(&dense_dir).unwrap();
        std::fs::remove_dir_all(&comp_dir).unwrap();
    }

    #[test]
    fn load_packed_preserves_numerics_and_derives_plans() {
        let m = compressed_model();
        let dir = std::env::temp_dir().join(format!("oats_cio_p_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let packed = load_packed(&dir, 8).unwrap();
        // Every compressed linear got a kernel plan at load time.
        assert_eq!(packed.kernel_plans().len(), m.cfg.n_layers * 6);
        assert_eq!(packed.prunable_param_count(), m.prunable_param_count());
        let toks = vec![vec![2usize, 4, 6, 8, 10, 12]];
        let d = m.forward(&toks).fro_dist(&packed.forward(&toks));
        assert!(d < 1e-3, "packed load diverges: {d}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_packed_quantized_gates_per_layer_and_stays_close() {
        let m = compressed_model();
        let dir = std::env::temp_dir().join(format!("oats_cio_q_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let base = load_packed(&dir, 8).unwrap();
        let qm = load_packed_with(&dir, &PackOptions::quantized(8)).unwrap();
        assert_eq!(qm.kernel_plans().len(), m.cfg.n_layers * 6);
        assert_eq!(qm.prunable_param_count(), m.prunable_param_count());
        // The tiny preset's up/down layers (256×64) are BCSR-planned;
        // well-behaved compressed weights pass the error gate and upgrade.
        let n_q = qm
            .kernel_plans()
            .iter()
            .filter(|(_, p)| p.choice == crate::sparse::KernelChoice::QBcsr)
            .count();
        assert!(n_q > 0, "no layer upgraded to qbcsr: {:?}", qm.kernel_plans());
        // Quantization is bounded by the plan gate: outputs stay close to
        // the f32-packed model.
        let toks = vec![vec![2usize, 4, 6, 8, 10, 12]];
        let want = base.forward(&toks);
        let rel = want.fro_dist(&qm.forward(&toks)) / want.fro_norm().max(1e-12);
        assert!(rel < 0.1, "quantized serving drifted: rel {rel}");
        // Saving the quantized-packed model round-trips through the
        // portable f32 structure (same nnz accounting, no i8 on disk).
        let dir2 = std::env::temp_dir().join(format!("oats_cio_q2_{}", std::process::id()));
        save(&qm, &dir2).unwrap();
        let back = load(&dir2).unwrap();
        assert_eq!(back.prunable_param_count(), m.prunable_param_count());
        assert!(qm.forward(&toks).fro_dist(&back.forward(&toks)) < 1e-3);
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&dir2).unwrap();
    }

    #[test]
    fn saving_a_packed_model_keeps_portable_format() {
        let m = compressed_model().packed_for_serving(8);
        let dir = std::env::temp_dir().join(format!("oats_cio_pk_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let m2 = load(&dir).unwrap();
        // Round-trips back to the portable structure with identical numerics.
        assert!(matches!(
            m2.blocks[0].q,
            LinearOp::Compressed(CompressedLayer::Spl(_))
        ));
        assert_eq!(m2.prunable_param_count(), m.prunable_param_count());
        let toks = vec![vec![1usize, 3, 5, 7]];
        assert!(m.forward(&toks).fro_dist(&m2.forward(&toks)) < 1e-3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn sliced_model() -> TransformerLM {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 0x51);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 3));
        let calib = CalibSet::sample(&corpus, 4, 16, 4);
        let cc = CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 3,
            slice_rate: Some(0.4),
            ..Default::default()
        };
        compress_clone(&model, &calib, &cc, 2).unwrap().0
    }

    #[test]
    fn sliced_roundtrip_is_bit_exact() {
        let m = sliced_model();
        let dir = std::env::temp_dir().join(format!("oats_cio_s_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let m2 = load(&dir).unwrap();
        for (blk, blk2) in m.blocks.iter().zip(&m2.blocks) {
            for name in ["up", "down"] {
                let (a, b) = (blk.linear(name), blk2.linear(name));
                match (a, b) {
                    (
                        LinearOp::Compressed(CompressedLayer::SlicedDense {
                            w, in_map, out_map,
                        }),
                        LinearOp::Compressed(CompressedLayer::SlicedDense {
                            w: w2, in_map: i2, out_map: o2,
                        }),
                    ) => {
                        // Bit-exact: raw f32 round-trips via to_le_bytes.
                        assert_eq!(w.data, w2.data, "{name} weight bits");
                        assert_eq!((w.rows, w.cols), (w2.rows, w2.cols));
                        assert_eq!(in_map, i2, "{name} in_map");
                        assert_eq!(out_map, o2, "{name} out_map");
                    }
                    other => panic!("{name} did not round-trip as sliced: {other:?}"),
                }
            }
        }
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        assert_eq!(
            m.forward(&toks).data,
            m2.forward(&toks).data,
            "bit-exact weights must give bit-exact logits"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sliced_load_packed_derives_sliced_plans_and_resaves() {
        let m = sliced_model();
        let dir = std::env::temp_dir().join(format!("oats_cio_sp_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let packed = load_packed(&dir, 8).unwrap();
        let sliced_plans = packed
            .kernel_plans()
            .into_iter()
            .filter(|(_, p)| p.choice == crate::sparse::KernelChoice::SlicedDense)
            .count();
        assert_eq!(sliced_plans, m.cfg.n_layers * 2, "up+down per block");
        let toks = vec![vec![2usize, 4, 6, 8]];
        let d = m.forward(&toks).fro_dist(&packed.forward(&toks));
        assert!(d < 1e-4, "packed sliced load diverges: {d}");
        // Re-saving the packed model keeps the slice metadata (the
        // unpacked_layer path), so a second round trip is still sliced.
        let dir2 = std::env::temp_dir().join(format!("oats_cio_sp2_{}", std::process::id()));
        save(&packed, &dir2).unwrap();
        let back = load(&dir2).unwrap();
        assert!(matches!(
            back.blocks[0].up,
            LinearOp::Compressed(CompressedLayer::SlicedDense { .. })
        ));
        assert_eq!(back.prunable_param_count(), m.prunable_param_count());
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&dir2).unwrap();
    }

    #[test]
    fn load_falls_back_to_dense_format() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let m = TransformerLM::init(&cfg, 0x22);
        let dir = std::env::temp_dir().join(format!("oats_cio_f_{}", std::process::id()));
        io::save(&m, &dir).unwrap();
        let m2 = load(&dir).unwrap(); // dense-format manifest → fallback path
        let toks = vec![vec![1usize, 2, 3]];
        assert!(m.forward(&toks).fro_dist(&m2.forward(&toks)) < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
