//! GPT-style decoder-only LM (pre-LN, learned positions, GELU MLP, untied
//! head). The architecture matches `python/compile/model.py` exactly so the
//! PJRT-executed artifacts and this native implementation agree to f32
//! round-off (verified by integration tests).

use crate::compress::CompressedLayer;
use crate::config::ModelConfig;
use crate::sparse::{KernelPlan, PackOptions, PackedLinear, Workspace};
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

pub const LINEAR_NAMES: [&str; 6] = ["q", "k", "v", "o", "up", "down"];

/// Identifies one prunable linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinearId {
    pub block: usize,
    /// One of `LINEAR_NAMES`.
    pub name: &'static str,
}

impl std::fmt::Display for LinearId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "block{}.{}", self.block, self.name)
    }
}

/// A linear layer in either execution mode. Weights are out×in; the layer
/// computes `y = x Wᵀ`.
#[derive(Clone, Debug)]
pub enum LinearOp {
    Dense(Matrix),
    Compressed(CompressedLayer),
    /// Pre-packed for serving: the sparse term re-tiled into the format a
    /// [`KernelPlan`] selected for this shape/density/batch (BCSR, packed
    /// N:M, CSR, or dense), with the low-rank term fused in.
    Packed(Box<PackedLinear>),
}

impl LinearOp {
    pub fn out_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Compressed(c) => c.shape().0,
            LinearOp::Packed(p) => p.shape().0,
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Compressed(c) => c.shape().1,
            LinearOp::Packed(p) => p.shape().1,
        }
    }

    /// Batched apply: X [b × in] → [b × out].
    pub fn forward(&self, x: &Matrix) -> Matrix {
        match self {
            LinearOp::Dense(w) => tensor::matmul_bt(x, w),
            LinearOp::Compressed(CompressedLayer::Dense(w)) => tensor::matmul_bt(x, w),
            LinearOp::Compressed(CompressedLayer::Sparse(s)) => s.matmul_xt(x),
            LinearOp::Compressed(CompressedLayer::Spl(spl)) => spl.apply_batch(x),
            // Sliced layers are plain GEMM in their own (smaller) shape; the
            // adjacent layers were sliced to match, so no map lookup runs.
            LinearOp::Compressed(CompressedLayer::SlicedDense { w, .. }) => {
                tensor::matmul_bt(x, w)
            }
            LinearOp::Packed(p) => p.forward(x),
        }
    }

    /// [`LinearOp::forward`] against a caller-owned [`Workspace`]: packed
    /// and dense layers take their scratch (Xᵀ panel, rank projection) and
    /// output from the pool — arithmetic is identical to [`forward`]
    /// (same kernels, same operation order), only the storage is recycled.
    /// Unpacked compressed layers keep their reference kernels.
    ///
    /// [`forward`]: LinearOp::forward
    pub fn forward_ws(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        match self {
            LinearOp::Packed(p) => p.forward_ws(x, ws),
            LinearOp::Dense(w)
            | LinearOp::Compressed(
                CompressedLayer::Dense(w) | CompressedLayer::SlicedDense { w, .. },
            ) => {
                // Uninit is safe: matmul_bt_into overwrites every element.
                let mut out = ws.matrix_uninit(x.rows, w.rows);
                tensor::matmul_bt_into(x, w, &mut out);
                out
            }
            other => other.forward(x),
        }
    }

    /// Single-row apply for the decode hot path.
    pub fn forward_vec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            LinearOp::Dense(w) => {
                for (r, out) in y.iter_mut().enumerate() {
                    *out = tensor::dot(w.row(r), x);
                }
            }
            LinearOp::Compressed(CompressedLayer::Dense(w)) => {
                for (r, out) in y.iter_mut().enumerate() {
                    *out = tensor::dot(w.row(r), x);
                }
            }
            LinearOp::Compressed(CompressedLayer::Sparse(s)) => s.matvec(x, y),
            LinearOp::Compressed(CompressedLayer::Spl(spl)) => spl.apply(x, y),
            LinearOp::Compressed(CompressedLayer::SlicedDense { w, .. }) => {
                for (r, out) in y.iter_mut().enumerate() {
                    *out = tensor::dot(w.row(r), x);
                }
            }
            LinearOp::Packed(p) => p.forward_vec(x, y),
        }
    }

    /// Dense view (reconstruction) — used by OWL scoring and tests.
    pub fn dense_view(&self) -> Matrix {
        match self {
            LinearOp::Dense(w) => w.clone(),
            LinearOp::Compressed(c) => c.to_dense(),
            LinearOp::Packed(p) => p.to_dense(),
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows * w.cols,
            LinearOp::Compressed(c) => c.param_count(),
            LinearOp::Packed(p) => p.param_count(),
        }
    }

    /// Pre-pack a compressed layer into its planned serving format; `None`
    /// when there is nothing to pack (dense or already packed).
    pub fn pack(&self, batch_hint: usize) -> Option<LinearOp> {
        self.pack_with(&PackOptions::for_batch(batch_hint))
    }

    /// [`LinearOp::pack`] with explicit packing options (the i8 tile
    /// quantization opt-in).
    pub fn pack_with(&self, opts: &PackOptions) -> Option<LinearOp> {
        match self {
            LinearOp::Compressed(CompressedLayer::Sparse(csr)) => {
                Some(LinearOp::Packed(Box::new(PackedLinear::from_csr_with(csr, opts))))
            }
            LinearOp::Compressed(CompressedLayer::Spl(spl)) => {
                Some(LinearOp::Packed(Box::new(PackedLinear::from_spl_with(spl, opts))))
            }
            LinearOp::Compressed(CompressedLayer::SlicedDense { w, in_map, out_map }) => {
                Some(LinearOp::Packed(Box::new(PackedLinear::from_sliced_with(
                    w,
                    in_map.clone(),
                    out_map.clone(),
                    opts,
                ))))
            }
            _ => None,
        }
    }

    /// The kernel plan, if this layer has been packed.
    pub fn kernel_plan(&self) -> Option<&KernelPlan> {
        match self {
            LinearOp::Packed(p) => Some(&p.plan),
            _ => None,
        }
    }
}

/// One transformer block.
#[derive(Clone, Debug)]
pub struct Block {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub q: LinearOp,
    pub k: LinearOp,
    pub v: LinearOp,
    pub o: LinearOp,
    pub up: LinearOp,
    pub down: LinearOp,
}

impl Block {
    pub fn linear(&self, name: &str) -> &LinearOp {
        match name {
            "q" => &self.q,
            "k" => &self.k,
            "v" => &self.v,
            "o" => &self.o,
            "up" => &self.up,
            "down" => &self.down,
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub fn linear_mut(&mut self, name: &str) -> &mut LinearOp {
        match name {
            "q" => &mut self.q,
            "k" => &mut self.k,
            "v" => &mut self.v,
            "o" => &mut self.o,
            "up" => &mut self.up,
            "down" => &mut self.down,
            other => panic!("unknown linear '{other}'"),
        }
    }
}

/// Per-linear captured inputs from a forward pass (the calibration hook).
#[derive(Default)]
pub struct ForwardCapture {
    /// Input activations per linear layer of ONE block.
    pub inputs: HashMap<&'static str, Matrix>,
}

const LN_EPS: f32 = 1e-5;

/// One fixed-size page of KV storage: `rows` consecutive positions of K
/// and V for every block. Pages are interchangeable: the serving arena
/// ([`KvPool`]) preallocates a pool-wide free list and recycles pages
/// across sequences, so a short sequence holds only the pages its length
/// needs instead of a whole `seq_len`-sized cache.
///
/// [`KvPool`]: crate::coordinator::engine::KvPool
pub struct KvPage {
    /// Per block: [rows × d_model].
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
}

impl KvPage {
    pub fn new(cfg: &ModelConfig, rows: usize) -> KvPage {
        KvPage {
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(rows, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(rows, cfg.d_model)).collect(),
        }
    }

    /// Positions this page stores.
    pub fn rows(&self) -> usize {
        self.k.first().map(|m| m.rows).unwrap_or(0)
    }

    /// Resident size in bytes (both K and V buffers, all blocks).
    pub fn memory_bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|m| m.data.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// One entry of a [`KvCache`] page table: either a page this sequence
/// owns outright (it may write rows and must return the page to the pool
/// at retirement), or a read-only view of a page shared with other
/// sequences through the prefix index ([`Arc`] refcounted — dropping the
/// slot is the release). The attention read walk treats both identically;
/// the write paths ([`KvCache::k_row_mut`] / [`KvCache::v_row_mut`])
/// refuse shared pages, so a copy-on-write fork is forced *before* any
/// mutation can alias another sequence's history.
pub enum PageSlot {
    Owned(KvPage),
    Shared(Arc<KvPage>),
}

impl PageSlot {
    /// Read-only view of the page, whichever way it is held.
    #[inline]
    fn page(&self) -> &KvPage {
        match self {
            PageSlot::Owned(p) => p,
            PageSlot::Shared(p) => p,
        }
    }
}

/// KV cache for incremental decoding: an ordered page table over
/// [`KvPage`]s, where position `p` lives at row `p % page_size` of page
/// `p / page_size`. [`KvCache::new`] attaches one whole-sequence page up
/// front (`page_size == seq_len`), so the scalar decode paths see exactly
/// the old contiguous layout; [`KvCache::paged`] creates an empty shell
/// whose pages the serving arena attaches on demand as the sequence grows.
/// Pages are held through [`PageSlot`]s, so leading pages can be
/// refcounted shared-prefix views instead of private copies.
pub struct KvCache {
    pages: Vec<PageSlot>,
    page_size: usize,
    capacity: usize,
    pub len: usize,
}

impl KvCache {
    /// Contiguous cache: one page sized for the full `seq_len` (the
    /// degenerate `page_size == seq_len` case — scalar `generate` and all
    /// references use this and never touch the page machinery).
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            pages: vec![PageSlot::Owned(KvPage::new(cfg, cfg.seq_len))],
            page_size: cfg.seq_len,
            capacity: cfg.seq_len,
            len: 0,
        }
    }

    /// Empty paged shell: no storage until [`KvCache::push_page`] attaches
    /// pages (the pool's acquire-on-demand path).
    pub fn paged(cfg: &ModelConfig, page_size: usize) -> KvCache {
        KvCache {
            pages: Vec::new(),
            page_size: page_size.clamp(1, cfg.seq_len),
            capacity: cfg.seq_len,
            len: 0,
        }
    }

    /// Max positions this cache can hold (the `seq_len` it was sized for).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions still available for decoding (against the logical
    /// capacity, not the currently attached pages).
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages currently attached.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Positions the attached pages can store.
    pub fn allocated_rows(&self) -> usize {
        self.pages.len() * self.page_size
    }

    /// True when the next written position has no backing page yet: the
    /// engine must attach one (from the pool's free list) before the next
    /// prefill/decode step touches this cache.
    pub fn needs_page(&self) -> bool {
        self.len < self.capacity && self.len >= self.allocated_rows()
    }

    /// Append an owned page to the page table.
    pub fn push_page(&mut self, page: KvPage) {
        assert_eq!(page.rows(), self.page_size, "page geometry mismatch");
        self.pages.push(PageSlot::Owned(page));
    }

    /// Append a shared (read-only) page view to the page table — the
    /// prefix-reuse admission path. Shared pages must form the leading
    /// prefix of the table: a write position can only ever land in the
    /// last page or a fresh one, so interleaving shared pages after owned
    /// ones would let CoW and ownership accounting disagree.
    pub fn push_shared(&mut self, page: Arc<KvPage>) {
        assert_eq!(page.rows(), self.page_size, "page geometry mismatch");
        assert!(
            self.pages.iter().all(|s| matches!(s, PageSlot::Shared(_))),
            "shared pages must precede owned pages"
        );
        self.pages.push(PageSlot::Shared(page));
    }

    /// True when page `i` is a shared (read-only) view.
    pub fn page_is_shared(&self, i: usize) -> bool {
        matches!(self.pages.get(i), Some(PageSlot::Shared(_)))
    }

    /// Shared pages currently mapped.
    pub fn shared_pages_held(&self) -> usize {
        self.pages.iter().filter(|s| matches!(s, PageSlot::Shared(_))).count()
    }

    /// Owned pages currently held (the ones the pool's free list is owed).
    pub fn owned_pages_held(&self) -> usize {
        self.pages.iter().filter(|s| matches!(s, PageSlot::Owned(_))).count()
    }

    /// Convert owned page `i` into a shared view and return the refcounted
    /// handle (for the prefix index). Already-shared pages just hand out
    /// another reference. The page contents are untouched — this is the
    /// publish step after a prefix page fills.
    pub fn share_page(&mut self, i: usize) -> Arc<KvPage> {
        if let PageSlot::Shared(p) = &self.pages[i] {
            return Arc::clone(p);
        }
        let placeholder = PageSlot::Owned(KvPage { k: Vec::new(), v: Vec::new() });
        let PageSlot::Owned(page) = std::mem::replace(&mut self.pages[i], placeholder) else {
            unreachable!("shared case returned above")
        };
        let shared = Arc::new(page);
        self.pages[i] = PageSlot::Shared(Arc::clone(&shared));
        shared
    }

    /// Copy-on-write: replace shared page `i` with `fresh` (a recycled
    /// pool page) carrying a copy of the shared contents, making the slot
    /// owned and writable. The shared reference is dropped (refcount
    /// decrement — the donor and other readers are unaffected).
    pub fn fork_page(&mut self, i: usize, mut fresh: KvPage) {
        assert_eq!(fresh.rows(), self.page_size, "page geometry mismatch");
        let PageSlot::Shared(src) = &self.pages[i] else {
            panic!("fork of a page this cache already owns")
        };
        for (dst, s) in fresh.k.iter_mut().zip(&src.k) {
            dst.data.copy_from_slice(&s.data);
        }
        for (dst, s) in fresh.v.iter_mut().zip(&src.v) {
            dst.data.copy_from_slice(&s.data);
        }
        self.pages[i] = PageSlot::Owned(fresh);
    }

    /// Retirement: detach every owned page (for return to the pool's free
    /// list), drop every shared reference, and reset the cache to empty.
    ///
    /// This is also the preemption teardown path: an evicted sequence's
    /// cache goes through here (via `KvPool::release`), discarding its
    /// computed KV wholesale. Readmission rebuilds it from scratch — shared
    /// prefix pages re-attach via [`KvCache::push_shared`] and everything
    /// past them is re-prefilled — which is exactly why preemption keeps
    /// bit-identity: the rebuilt rows come from the same deterministic
    /// prefill over the same token stream, so greedy decode resumes on
    /// identical state.
    pub fn take_pages(&mut self) -> Vec<KvPage> {
        self.len = 0;
        std::mem::take(&mut self.pages)
            .into_iter()
            .filter_map(|s| match s {
                PageSlot::Owned(p) => Some(p),
                PageSlot::Shared(_) => None,
            })
            .collect()
    }

    /// Recycle this cache for a new sequence while keeping its pages (the
    /// contiguous whole-cache path). Resetting the length is sufficient:
    /// attention only ever reads rows `< len`, and every row is written
    /// (at its decode step) before it is read, so stale K/V values from
    /// the previous occupant are unreachable.
    pub fn reset_for_reuse(&mut self) {
        self.len = 0;
    }

    /// Resident size in bytes — owned pages only. Shared views are billed
    /// once pool-wide (by the arena that backs the prefix index), not per
    /// mapping, so this never double-counts a page.
    pub fn memory_bytes(&self) -> usize {
        self.pages
            .iter()
            .filter_map(|s| match s {
                PageSlot::Owned(p) => Some(p.memory_bytes()),
                PageSlot::Shared(_) => None,
            })
            .sum()
    }

    /// The first `n` K rows of `block`, gathered across the page table in
    /// position order — the attention walk. Shared and owned pages read
    /// identically. Yields fewer than `n` rows only if the page table is
    /// too short (guarded by the decode-entry asserts).
    pub fn k_rows(&self, block: usize, n: usize) -> impl Iterator<Item = &[f32]> + '_ {
        self.pages
            .iter()
            .flat_map(move |p| {
                let m = &p.page().k[block];
                (0..m.rows).map(move |r| m.row(r))
            })
            .take(n)
    }

    /// The first `n` V rows of `block`, gathered across the page table.
    pub fn v_rows(&self, block: usize, n: usize) -> impl Iterator<Item = &[f32]> + '_ {
        self.pages
            .iter()
            .flat_map(move |p| {
                let m = &p.page().v[block];
                (0..m.rows).map(move |r| m.row(r))
            })
            .take(n)
    }

    #[inline]
    pub fn k_row_mut(&mut self, block: usize, pos: usize) -> &mut [f32] {
        match &mut self.pages[pos / self.page_size] {
            PageSlot::Owned(p) => p.k[block].row_mut(pos % self.page_size),
            PageSlot::Shared(_) => panic!("write to shared KV page at position {pos}"),
        }
    }

    #[inline]
    pub fn v_row_mut(&mut self, block: usize, pos: usize) -> &mut [f32] {
        match &mut self.pages[pos / self.page_size] {
            PageSlot::Owned(p) => p.v[block].row_mut(pos % self.page_size),
            PageSlot::Shared(_) => panic!("write to shared KV page at position {pos}"),
        }
    }
}

/// The model.
#[derive(Clone, Debug)]
pub struct TransformerLM {
    pub cfg: ModelConfig,
    pub tok_emb: Matrix, // vocab × d
    pub pos_emb: Matrix, // seq × d
    pub blocks: Vec<Block>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head: Matrix, // vocab × d
}

impl TransformerLM {
    /// Random initialization (same scheme as the JAX model: normal(0, 0.02),
    /// residual projections scaled by 1/sqrt(2·n_layers)).
    pub fn init(cfg: &ModelConfig, seed: u64) -> TransformerLM {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let resid_std = 0.02 / ((2 * cfg.n_layers) as f32).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                q: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                k: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                v: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                o: LinearOp::Dense(Matrix::randn(d, d, resid_std, &mut rng)),
                up: LinearOp::Dense(Matrix::randn(cfg.d_ff, d, 0.02, &mut rng)),
                down: LinearOp::Dense(Matrix::randn(d, cfg.d_ff, resid_std, &mut rng)),
            })
            .collect();
        TransformerLM {
            cfg: cfg.clone(),
            tok_emb: Matrix::randn(cfg.vocab, d, 0.02, &mut rng),
            pos_emb: Matrix::randn(cfg.seq_len, d, 0.01, &mut rng),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            head: Matrix::randn(cfg.vocab, d, 0.02, &mut rng),
        }
    }

    /// Embed a batch of token sequences → hidden states [B·S × d].
    /// All sequences must share one length ≤ cfg.seq_len.
    pub fn embed(&self, tokens: &[Vec<usize>]) -> Matrix {
        let s = tokens[0].len();
        assert!(s <= self.cfg.seq_len, "seq {s} > max {}", self.cfg.seq_len);
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(tokens.len() * s, d);
        for (b, seq) in tokens.iter().enumerate() {
            assert_eq!(seq.len(), s, "ragged batch");
            for (t, &tok) in seq.iter().enumerate() {
                let row = h.row_mut(b * s + t);
                for (x, (&e, &p)) in
                    row.iter_mut().zip(self.tok_emb.row(tok).iter().zip(self.pos_emb.row(t)))
                {
                    *x = e + p;
                }
            }
        }
        h
    }

    /// One block's forward on hidden states `h` [B·S × d] for batch size `bsz`
    /// and per-sequence length `s`. Optionally captures per-linear inputs and
    /// per-head attention probabilities (averaged over heads, per sequence).
    pub fn block_forward(
        &self,
        block_idx: usize,
        h: &Matrix,
        bsz: usize,
        s: usize,
        mut capture: Option<&mut ForwardCapture>,
        mut attn_out_probs: Option<&mut Vec<Matrix>>,
    ) -> Matrix {
        let blk = &self.blocks[block_idx];
        // Dims come from the layers, not the config: compression may have
        // changed per-layer shapes (the residual/attention width is q's
        // input dim — slicing only ever touches the FFN inner dim).
        let d = blk.q.in_dim();
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let scale = 1.0 / (hd as f32).sqrt();

        // ── attention ──
        let mut x = h.clone();
        tensor::layernorm_rows(&mut x, &blk.ln1_g, &blk.ln1_b, LN_EPS);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("q", x.clone());
            c.inputs.insert("k", x.clone());
            c.inputs.insert("v", x.clone());
        }
        let q = blk.q.forward(&x);
        let k = blk.k.forward(&x);
        let v = blk.v.forward(&x);
        let mut ctx = Matrix::zeros(h.rows, d);
        for b in 0..bsz {
            let base = b * s;
            let mut probs_mean = if attn_out_probs.is_some() {
                Some(Matrix::zeros(s, s))
            } else {
                None
            };
            for head in 0..nh {
                let off = head * hd;
                // scores[t, u] for u ≤ t
                for t in 0..s {
                    let qrow = &q.row(base + t)[off..off + hd];
                    let mut scores = vec![f32::NEG_INFINITY; s];
                    for (u, sc) in scores.iter_mut().enumerate().take(t + 1) {
                        let krow = &k.row(base + u)[off..off + hd];
                        *sc = tensor::dot(qrow, krow) * scale;
                    }
                    tensor::softmax_inplace(&mut scores[..t + 1]);
                    let crow = &mut ctx.row_mut(base + t)[off..off + hd];
                    for (u, &p) in scores[..t + 1].iter().enumerate() {
                        if p == 0.0 {
                            continue;
                        }
                        let vrow = &v.row(base + u)[off..off + hd];
                        for (cv, &vv) in crow.iter_mut().zip(vrow) {
                            *cv += p * vv;
                        }
                    }
                    if let Some(pm) = probs_mean.as_mut() {
                        for (u, &p) in scores[..t + 1].iter().enumerate() {
                            *pm.at_mut(t, u) += p / nh as f32;
                        }
                    }
                }
            }
            if let (Some(pm), Some(store)) = (probs_mean, attn_out_probs.as_deref_mut()) {
                store.push(pm);
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("o", ctx.clone());
        }
        let attn = blk.o.forward(&ctx);
        let mut h2 = h.clone();
        h2.axpy(1.0, &attn);

        // ── MLP ──
        let mut x2 = h2.clone();
        tensor::layernorm_rows(&mut x2, &blk.ln2_g, &blk.ln2_b, LN_EPS);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("up", x2.clone());
        }
        let mut u = blk.up.forward(&x2);
        tensor::gelu_inplace(&mut u.data);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("down", u.clone());
        }
        let mlp = blk.down.forward(&u);
        h2.axpy(1.0, &mlp);
        h2
    }

    /// Full forward: token batch → logits [B·S × vocab].
    pub fn forward(&self, tokens: &[Vec<usize>]) -> Matrix {
        let s = tokens[0].len();
        let mut h = self.embed(tokens);
        for i in 0..self.blocks.len() {
            h = self.block_forward(i, &h, tokens.len(), s, None, None);
        }
        self.project_logits(h)
    }

    /// Final LN + head.
    pub fn project_logits(&self, mut h: Matrix) -> Matrix {
        tensor::layernorm_rows(&mut h, &self.lnf_g, &self.lnf_b, LN_EPS);
        tensor::matmul_bt(&h, &self.head)
    }

    /// Mean next-token cross-entropy (nats) on a batch.
    pub fn loss(&self, inputs: &[Vec<usize>], targets: &[Vec<usize>]) -> f64 {
        let logits = self.forward(inputs);
        let flat: Vec<usize> = targets.iter().flatten().copied().collect();
        tensor::cross_entropy(&logits, &flat)
    }

    /// Greedy next-token prediction for each sequence's last position.
    pub fn predict_next(&self, tokens: &[Vec<usize>]) -> Vec<usize> {
        let s = tokens[0].len();
        let logits = self.forward(tokens);
        (0..tokens.len())
            .map(|b| tensor::argmax(logits.row(b * s + s - 1)))
            .collect()
    }

    /// Incremental decode of one token given the cache state. Returns the
    /// logits row for this position. `token` is appended at position
    /// `cache.len`.
    pub fn decode_step(&self, token: usize, cache: &mut KvCache) -> Vec<f32> {
        // Dims come from the weights, not the config: the embedding width is
        // the residual width, and the FFN inner buffer sizes to the largest
        // per-block `up` output (blocks may be sliced to different widths).
        let d = self.tok_emb.cols;
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let scale = 1.0 / (hd as f32).sqrt();
        let t = cache.len;
        assert!(t < self.cfg.seq_len, "cache full");
        assert!(t < cache.allocated_rows(), "no KV page attached for position {t}");

        let mut h: Vec<f32> = self.tok_emb.row(token).to_vec();
        for (x, &p) in h.iter_mut().zip(self.pos_emb.row(t)) {
            *x += p;
        }
        let max_ff = self.blocks.iter().map(|b| b.up.out_dim()).max().unwrap_or(0);
        let mut kbuf = vec![0.0f32; d];
        let mut vbuf = vec![0.0f32; d];
        let mut qbuf = vec![0.0f32; d];
        let mut ctx = vec![0.0f32; d];
        let mut ubuf = vec![0.0f32; max_ff];
        let mut mlp = vec![0.0f32; d];
        for (bi, blk) in self.blocks.iter().enumerate() {
            let x = layernorm_vec(&h, &blk.ln1_g, &blk.ln1_b);
            blk.q.forward_vec(&x, &mut qbuf);
            blk.k.forward_vec(&x, &mut kbuf);
            blk.v.forward_vec(&x, &mut vbuf);
            cache.k_row_mut(bi, t).copy_from_slice(&kbuf);
            cache.v_row_mut(bi, t).copy_from_slice(&vbuf);
            ctx.iter_mut().for_each(|c| *c = 0.0);
            for head in 0..nh {
                let off = head * hd;
                let qh = &qbuf[off..off + hd];
                let mut scores = vec![0.0f32; t + 1];
                // Gather K/V across the sequence's pages ([`KvCache::k_rows`]
                // walks the page table in position order).
                for (sc, krow) in scores.iter_mut().zip(cache.k_rows(bi, t + 1)) {
                    *sc = tensor::dot(qh, &krow[off..off + hd]) * scale;
                }
                tensor::softmax_inplace(&mut scores);
                let ch = &mut ctx[off..off + hd];
                for (&p, vrow) in scores.iter().zip(cache.v_rows(bi, t + 1)) {
                    for (cv, &vv) in ch.iter_mut().zip(&vrow[off..off + hd]) {
                        *cv += p * vv;
                    }
                }
            }
            let mut attn = vec![0.0f32; d];
            blk.o.forward_vec(&ctx, &mut attn);
            for (hv, &a) in h.iter_mut().zip(&attn) {
                *hv += a;
            }
            let x2 = layernorm_vec(&h, &blk.ln2_g, &blk.ln2_b);
            let ubuf = &mut ubuf[..blk.up.out_dim()];
            blk.up.forward_vec(&x2, ubuf);
            for v in ubuf.iter_mut() {
                *v = tensor::gelu(*v);
            }
            blk.down.forward_vec(ubuf, &mut mlp);
            for (hv, &m) in h.iter_mut().zip(&mlp) {
                *hv += m;
            }
        }
        cache.len += 1;
        let hf = layernorm_vec(&h, &self.lnf_g, &self.lnf_b);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        for (r, out) in logits.iter_mut().enumerate() {
            *out = tensor::dot(self.head.row(r), &hf);
        }
        logits
    }

    /// One lockstep decode step for a batch of independent sequences: the
    /// six linears and the head run as [b × d] batched products (where the
    /// packed BCSR/fused kernels pay off), while attention stays
    /// per-sequence over each sequence's own KV cache (positions may be
    /// ragged). Mirrors [`TransformerLM::decode_step`] exactly — for dense
    /// layers the arithmetic is identical operation-for-operation.
    ///
    /// This convenience spins up a throwaway [`Workspace`] per call; the
    /// serve engine keeps one alive across steps via
    /// [`TransformerLM::decode_step_batch_ws`] so decode stops allocating.
    ///
    /// Returns the logits [b × vocab] for each sequence's new position.
    pub fn decode_step_batch(&self, tokens: &[usize], caches: &mut [&mut KvCache]) -> Matrix {
        self.decode_step_batch_ws(tokens, caches, &mut Workspace::new())
    }

    /// [`TransformerLM::decode_step_batch`] against a caller-owned
    /// [`Workspace`]: every per-step temporary — the hidden state, the
    /// layernormed inputs, the six linear outputs, the attention context,
    /// and the returned logits — is backed by pooled storage, and the
    /// batched kernels' Xᵀ panels and outputs come from the same pool, so
    /// a caller that keeps `ws` across steps allocates nothing once shapes
    /// have been seen. The returned logits matrix is pool-backed too:
    /// recycle it via [`Workspace::recycle`] after reading. Arithmetic is
    /// identical to the per-call-workspace path (it is the same code).
    pub fn decode_step_batch_ws(
        &self,
        tokens: &[usize],
        caches: &mut [&mut KvCache],
        ws: &mut Workspace,
    ) -> Matrix {
        let b = tokens.len();
        assert_eq!(b, caches.len(), "one cache per sequence");
        // Residual width from the embedding (the FFN inner dim never appears
        // here: `forward_ws` outputs take their shape from each layer).
        let d = self.tok_emb.cols;
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let scale = 1.0 / (hd as f32).sqrt();

        // Uninit checkouts are safe throughout: `h`, `x`, `x2` are fully
        // written (embed fill / copy_from_slice) and `logits` is fully
        // written by matmul_bt_into; only `ctx` accumulates and stays on
        // the zeroed variant.
        let mut h = ws.matrix_uninit(b, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let t = caches[i].len;
            assert!(t < self.cfg.seq_len, "cache full (seq {i})");
            assert!(t < caches[i].allocated_rows(), "no KV page attached for seq {i} pos {t}");
            let row = h.row_mut(i);
            let emb = self.tok_emb.row(tok).iter().zip(self.pos_emb.row(t));
            for (x, (&e, &p)) in row.iter_mut().zip(emb) {
                *x = e + p;
            }
        }

        for (bi, blk) in self.blocks.iter().enumerate() {
            let mut x = ws.matrix_uninit(b, d);
            x.data.copy_from_slice(&h.data);
            tensor::layernorm_rows(&mut x, &blk.ln1_g, &blk.ln1_b, LN_EPS);
            let q = blk.q.forward_ws(&x, ws);
            let k = blk.k.forward_ws(&x, ws);
            let v = blk.v.forward_ws(&x, ws);
            ws.recycle(x);
            let mut ctx = ws.matrix(b, d);
            for i in 0..b {
                let t = caches[i].len;
                caches[i].k_row_mut(bi, t).copy_from_slice(k.row(i));
                caches[i].v_row_mut(bi, t).copy_from_slice(v.row(i));
                for head in 0..nh {
                    let off = head * hd;
                    let qh = &q.row(i)[off..off + hd];
                    let mut scores = vec![0.0f32; t + 1];
                    // Same paged K/V walk as `decode_step`, over this
                    // sequence's own (possibly ragged) page table.
                    for (sc, krow) in scores.iter_mut().zip(caches[i].k_rows(bi, t + 1)) {
                        *sc = tensor::dot(qh, &krow[off..off + hd]) * scale;
                    }
                    tensor::softmax_inplace(&mut scores);
                    let ch = &mut ctx.row_mut(i)[off..off + hd];
                    for (&p, vrow) in scores.iter().zip(caches[i].v_rows(bi, t + 1)) {
                        for (cv, &vv) in ch.iter_mut().zip(&vrow[off..off + hd]) {
                            *cv += p * vv;
                        }
                    }
                }
            }
            ws.recycle(q);
            ws.recycle(k);
            ws.recycle(v);
            let attn = blk.o.forward_ws(&ctx, ws);
            ws.recycle(ctx);
            h.axpy(1.0, &attn);
            ws.recycle(attn);
            let mut x2 = ws.matrix_uninit(b, d);
            x2.data.copy_from_slice(&h.data);
            tensor::layernorm_rows(&mut x2, &blk.ln2_g, &blk.ln2_b, LN_EPS);
            let mut u = blk.up.forward_ws(&x2, ws);
            ws.recycle(x2);
            tensor::gelu_inplace(&mut u.data);
            let mlp = blk.down.forward_ws(&u, ws);
            ws.recycle(u);
            h.axpy(1.0, &mlp);
            ws.recycle(mlp);
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        tensor::layernorm_rows(&mut h, &self.lnf_g, &self.lnf_b, LN_EPS);
        let mut logits = ws.matrix_uninit(b, self.cfg.vocab);
        tensor::matmul_bt_into(&h, &self.head, &mut logits);
        ws.recycle(h);
        logits
    }

    /// All prunable linear ids in pipeline order.
    pub fn linear_ids(&self) -> Vec<LinearId> {
        (0..self.blocks.len())
            .flat_map(|b| LINEAR_NAMES.iter().map(move |&n| LinearId { block: b, name: n }))
            .collect()
    }

    /// Replace a linear layer (the coordinator's commit step).
    pub fn set_linear(&mut self, id: LinearId, op: LinearOp) {
        *self.blocks[id.block].linear_mut(id.name) = op;
    }

    /// Pre-pack every compressed linear into the serving format its
    /// [`KernelPlan`] selects for `batch_hint` (checkpoint→serve path).
    /// Returns the number of layers packed.
    pub fn pack_for_serving(&mut self, batch_hint: usize) -> usize {
        self.pack_for_serving_with(&PackOptions::for_batch(batch_hint))
    }

    /// [`TransformerLM::pack_for_serving`] with explicit packing options
    /// (the i8 tile quantization opt-in rides through here).
    pub fn pack_for_serving_with(&mut self, opts: &PackOptions) -> usize {
        let mut packed = 0;
        for blk in &mut self.blocks {
            for name in LINEAR_NAMES {
                let op = blk.linear_mut(name);
                if let Some(p) = op.pack_with(opts) {
                    *op = p;
                    packed += 1;
                }
            }
        }
        packed
    }

    /// Clone-and-pack convenience for serving startup (the original model
    /// keeps its portable representation).
    pub fn packed_for_serving(&self, batch_hint: usize) -> TransformerLM {
        self.packed_for_serving_with(&PackOptions::for_batch(batch_hint))
    }

    /// [`TransformerLM::packed_for_serving`] with explicit packing options.
    pub fn packed_for_serving_with(&self, opts: &PackOptions) -> TransformerLM {
        let mut m = self.clone();
        m.pack_for_serving_with(opts);
        m
    }

    /// True if any linear still carries a packable compressed format.
    pub fn needs_packing(&self) -> bool {
        self.blocks.iter().any(|b| {
            LINEAR_NAMES.iter().any(|&n| {
                matches!(
                    b.linear(n),
                    LinearOp::Compressed(
                        CompressedLayer::Sparse(_)
                            | CompressedLayer::Spl(_)
                            | CompressedLayer::SlicedDense { .. }
                    )
                )
            })
        })
    }

    /// Kernel plans of all packed layers, in pipeline order.
    pub fn kernel_plans(&self) -> Vec<(LinearId, KernelPlan)> {
        let mut out = Vec::new();
        for (b, blk) in self.blocks.iter().enumerate() {
            for name in LINEAR_NAMES {
                if let Some(p) = blk.linear(name).kernel_plan() {
                    out.push((LinearId { block: b, name }, p.clone()));
                }
            }
        }
        out
    }

    /// Prunable-parameter count currently stored (tracks compression).
    pub fn prunable_param_count(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| LINEAR_NAMES.iter().map(move |&n| b.linear(n).param_count()))
            .sum()
    }

    /// Achieved compression rate over prunable layers.
    pub fn achieved_compression(&self) -> f64 {
        1.0 - self.prunable_param_count() as f64 / self.cfg.prunable_params() as f64
    }
}

fn layernorm_vec(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    x.iter()
        .zip(g.iter().zip(b))
        .map(|(&v, (&gv, &bv))| (v - mean) * inv * gv + bv)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressedLayer;
    use crate::sparse::Csr;

    fn tiny() -> TransformerLM {
        TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 42)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let tokens = vec![vec![1usize, 2, 3, 4], vec![5, 6, 7, 8]];
        let logits = m.forward(&tokens);
        assert_eq!(logits.rows, 8);
        assert_eq!(logits.cols, m.cfg.vocab);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_future_tokens_do_not_affect_past() {
        let m = tiny();
        let a = vec![vec![1usize, 2, 3, 4]];
        let b = vec![vec![1usize, 2, 3, 9]];
        let la = m.forward(&a);
        let lb = m.forward(&b);
        // logits at positions 0..2 must agree (token 3 differs only at pos 3)
        for t in 0..3 {
            for v in 0..m.cfg.vocab {
                assert!(
                    (la.at(t, v) - lb.at(t, v)).abs() < 1e-5,
                    "pos {t} vocab {v}"
                );
            }
        }
    }

    #[test]
    fn batch_independence() {
        let m = tiny();
        let single = m.forward(&[vec![3usize, 1, 4, 1]]);
        let batch = m.forward(&[vec![9usize, 9, 9, 9], vec![3, 1, 4, 1]]);
        for t in 0..4 {
            for v in 0..m.cfg.vocab {
                assert!((single.at(t, v) - batch.at(4 + t, v)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn decode_matches_full_forward() {
        let m = tiny();
        let seq = vec![7usize, 3, 11, 2, 19];
        let full = m.forward(&[seq.clone()]);
        let mut cache = KvCache::new(&m.cfg);
        let mut last = Vec::new();
        for &tok in &seq {
            last = m.decode_step(tok, &mut cache);
        }
        let want = full.row(seq.len() - 1);
        for (a, b) in last.iter().zip(want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn paged_decode_matches_contiguous_cache() {
        // A cache split into small pages must be arithmetically identical
        // to the one-page contiguous layout: the page walk only changes
        // where rows live, never the order they are read in.
        let m = tiny();
        let seq = [7usize, 3, 11, 2, 19, 4, 8];
        for page_size in [1usize, 2, 3, 5, 64] {
            let mut paged = KvCache::paged(&m.cfg, page_size);
            let mut contiguous = KvCache::new(&m.cfg);
            let mut got = Vec::new();
            let mut want = Vec::new();
            for &t in &seq {
                if paged.needs_page() {
                    paged.push_page(KvPage::new(&m.cfg, paged.page_size()));
                }
                got = m.decode_step(t, &mut paged);
                want = m.decode_step(t, &mut contiguous);
            }
            assert_eq!(got, want, "page_size {page_size} diverged");
            assert_eq!(paged.pages_held(), seq.len().div_ceil(paged.page_size()));
            assert_eq!(paged.len, contiguous.len);
        }
    }

    #[test]
    fn paged_batch_decode_matches_contiguous() {
        let m = tiny();
        let seqs = [vec![7usize, 3, 11, 2], vec![5usize, 1, 9, 14]];
        let mut paged = KvCache::paged(&m.cfg, 3);
        let mut contiguous = KvCache::new(&m.cfg);
        let mut got = Matrix::zeros(0, 0);
        for step in 0..seqs[0].len() {
            if paged.needs_page() {
                paged.push_page(KvPage::new(&m.cfg, 3));
            }
            let tokens = [seqs[0][step], seqs[1][step]];
            let mut caches = [&mut paged, &mut contiguous];
            got = m.decode_step_batch(&tokens, &mut caches);
        }
        // Row 0 decoded seq 0 through a 3-position paged table; compare
        // against the same sequence through a fresh contiguous cache.
        let mut clean = KvCache::new(&m.cfg);
        let mut want = Vec::new();
        for &t in &seqs[0] {
            want = m.decode_step(t, &mut clean);
        }
        for (a, b) in got.row(0).iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn paged_cache_page_accounting() {
        let m = tiny();
        let mut c = KvCache::paged(&m.cfg, 4);
        assert_eq!(c.capacity(), m.cfg.seq_len);
        assert_eq!(c.remaining(), m.cfg.seq_len);
        assert!(c.needs_page(), "empty shell needs its first page");
        assert_eq!(c.memory_bytes(), 0);
        c.push_page(KvPage::new(&m.cfg, 4));
        assert!(!c.needs_page());
        assert_eq!(c.allocated_rows(), 4);
        assert!(c.memory_bytes() > 0);
        c.len = 4;
        assert!(c.needs_page(), "full pages demand the next one");
        let pages = c.take_pages();
        assert_eq!(pages.len(), 1);
        assert_eq!(c.len, 0, "take_pages resets the cache");
        assert_eq!(c.pages_held(), 0);
    }

    #[test]
    #[should_panic(expected = "no KV page attached")]
    fn decode_without_page_panics() {
        let m = tiny();
        let mut c = KvCache::paged(&m.cfg, 4);
        let _ = m.decode_step(1, &mut c);
    }

    #[test]
    fn shared_prefix_pages_decode_identically() {
        // A joiner that maps the donor's filled prefix page read-only and
        // recomputes only the tail must produce the exact logits of a
        // fresh scalar decode of the whole sequence — the bit-identity
        // contract prefix sharing rests on.
        let m = tiny();
        let seq = [7usize, 3, 11, 2, 19, 4];
        let ps = 3usize;
        let mut donor = KvCache::paged(&m.cfg, ps);
        for &t in &seq {
            if donor.needs_page() {
                donor.push_page(KvPage::new(&m.cfg, ps));
            }
            m.decode_step(t, &mut donor);
        }
        let shared = donor.share_page(0);
        assert_eq!(donor.shared_pages_held(), 1);
        assert_eq!(donor.owned_pages_held(), 1);

        let mut joiner = KvCache::paged(&m.cfg, ps);
        joiner.push_shared(Arc::clone(&shared));
        joiner.len = ps; // prefix positions 0..ps come from the shared page
        let mut got = Vec::new();
        for &t in &seq[ps..] {
            if joiner.needs_page() {
                joiner.push_page(KvPage::new(&m.cfg, ps));
            }
            got = m.decode_step(t, &mut joiner);
        }
        let mut clean = KvCache::new(&m.cfg);
        let mut want = Vec::new();
        for &t in &seq {
            want = m.decode_step(t, &mut clean);
        }
        assert_eq!(got, want, "shared-prefix decode diverged");
        // Three holders now: donor, joiner, and the test's handle.
        assert_eq!(Arc::strong_count(&shared), 3);
        // Retirement returns only owned pages and drops the shared refs.
        assert_eq!(joiner.take_pages().len(), 1);
        assert_eq!(donor.take_pages().len(), 1);
        assert_eq!(Arc::strong_count(&shared), 1);
    }

    #[test]
    fn fork_page_copies_contents_and_restores_writability() {
        let m = tiny();
        let seq = [9usize, 1, 5, 13];
        let ps = 4usize;
        let mut donor = KvCache::paged(&m.cfg, ps);
        donor.push_page(KvPage::new(&m.cfg, ps));
        for &t in &seq {
            m.decode_step(t, &mut donor);
        }
        let shared = donor.share_page(0);
        let mut joiner = KvCache::paged(&m.cfg, ps);
        joiner.push_shared(shared);
        assert!(joiner.page_is_shared(0));
        assert_eq!(joiner.memory_bytes(), 0, "shared views are billed pool-wide");
        joiner.fork_page(0, KvPage::new(&m.cfg, ps));
        assert!(!joiner.page_is_shared(0));
        // The fork carries the donor's rows bit-for-bit: overwriting the
        // last position and decoding on top must equal a scalar decode of
        // the edited sequence.
        joiner.len = ps - 1;
        let edited = [9usize, 1, 5, 2, 8];
        let mut got = Vec::new();
        for &t in &edited[ps - 1..] {
            if joiner.needs_page() {
                joiner.push_page(KvPage::new(&m.cfg, ps));
            }
            got = m.decode_step(t, &mut joiner);
        }
        let mut clean = KvCache::new(&m.cfg);
        let mut want = Vec::new();
        for &t in &edited {
            want = m.decode_step(t, &mut clean);
        }
        assert_eq!(got, want, "post-fork decode diverged");
    }

    #[test]
    #[should_panic(expected = "write to shared KV page")]
    fn writing_into_shared_page_panics() {
        let m = tiny();
        let mut donor = KvCache::paged(&m.cfg, 2);
        donor.push_page(KvPage::new(&m.cfg, 2));
        m.decode_step(3, &mut donor);
        m.decode_step(4, &mut donor);
        let shared = donor.share_page(0);
        let mut joiner = KvCache::paged(&m.cfg, 2);
        joiner.push_shared(shared);
        joiner.len = 1;
        // Position 1 lands in the shared page: decode must refuse to write.
        let _ = m.decode_step(5, &mut joiner);
    }

    #[test]
    fn kv_cache_reuse_matches_fresh_cache() {
        // The pooled-serving path recycles caches via `reset_for_reuse`;
        // a recycled cache must be indistinguishable from a fresh one.
        let m = tiny();
        let mut cache = KvCache::new(&m.cfg);
        assert_eq!(cache.capacity(), m.cfg.seq_len);
        assert!(cache.memory_bytes() > 0);
        for &t in &[3usize, 9, 1] {
            m.decode_step(t, &mut cache);
        }
        cache.reset_for_reuse();
        assert_eq!(cache.remaining(), m.cfg.seq_len);
        let mut got = Vec::new();
        for &t in &[7usize, 2] {
            got = m.decode_step(t, &mut cache);
        }
        let mut clean = KvCache::new(&m.cfg);
        let mut want = Vec::new();
        for &t in &[7usize, 2] {
            want = m.decode_step(t, &mut clean);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn compressed_dense_equivalence() {
        // Replacing a layer with its CSR of the same dense weights changes
        // nothing.
        let mut m = tiny();
        let w = m.blocks[0].q.dense_view();
        m.set_linear(
            LinearId { block: 0, name: "q" },
            LinearOp::Compressed(CompressedLayer::Sparse(Csr::from_dense(&w))),
        );
        let m2 = tiny();
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6]];
        let a = m.forward(&toks);
        let b = m2.forward(&toks);
        assert!(a.fro_dist(&b) < 1e-4);
    }

    #[test]
    fn capture_collects_all_linears() {
        let m = tiny();
        let toks = vec![vec![1usize, 2, 3, 4]];
        let h = m.embed(&toks);
        let mut cap = ForwardCapture::default();
        let _ = m.block_forward(0, &h, 1, 4, Some(&mut cap), None);
        for name in LINEAR_NAMES {
            assert!(cap.inputs.contains_key(name), "missing {name}");
        }
        assert_eq!(cap.inputs["q"].cols, m.cfg.d_model);
        assert_eq!(cap.inputs["down"].cols, m.cfg.d_ff);
    }

    #[test]
    fn packed_model_matches_unpacked_forward_and_decode() {
        let mut m = tiny();
        // Compress two layers (one CSR-only, one SPL) then pack.
        let wq = m.blocks[0].q.dense_view();
        let pruned = crate::compress::threshold::hard_threshold(
            &wq,
            &wq,
            wq.rows * wq.cols / 2,
            crate::config::SparsityPattern::RowWise,
        );
        m.set_linear(
            LinearId { block: 0, name: "q" },
            LinearOp::Compressed(CompressedLayer::Sparse(Csr::from_dense(&pruned))),
        );
        let wu = m.blocks[1].up.dense_view();
        let spl = crate::sparse::SparsePlusLowRank {
            sparse: Csr::from_dense(&crate::compress::threshold::hard_threshold(
                &wu,
                &wu,
                wu.rows * wu.cols / 3,
                crate::config::SparsityPattern::RowWise,
            )),
            low_rank: None,
        };
        m.set_linear(
            LinearId { block: 1, name: "up" },
            LinearOp::Compressed(CompressedLayer::Spl(spl)),
        );

        let packed = m.packed_for_serving(8);
        assert_eq!(packed.kernel_plans().len(), 2);
        assert_eq!(packed.prunable_param_count(), m.prunable_param_count());

        let toks = vec![vec![1usize, 2, 3, 4, 5, 6]];
        let a = m.forward(&toks);
        let b = packed.forward(&toks);
        assert!(a.fro_dist(&b) < 1e-3, "packed forward diverges: {}", a.fro_dist(&b));

        let mut c1 = KvCache::new(&m.cfg);
        let mut c2 = KvCache::new(&packed.cfg);
        let mut l1 = Vec::new();
        let mut l2 = Vec::new();
        for &t in &[3usize, 9, 1, 7] {
            l1 = m.decode_step(t, &mut c1);
            l2 = packed.decode_step(t, &mut c2);
        }
        for (x, y) in l1.iter().zip(&l2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn decode_step_batch_matches_scalar_decode() {
        let m = tiny();
        let seqs = [vec![7usize, 3, 11, 2], vec![5usize, 1, 9, 14]];
        // Scalar reference: decode each sequence independently.
        let mut want = Vec::new();
        for s in &seqs {
            let mut cache = KvCache::new(&m.cfg);
            let mut logits = Vec::new();
            for &t in s {
                logits = m.decode_step(t, &mut cache);
            }
            want.push(logits);
        }
        // Lockstep batched decode over both sequences.
        let mut c0 = KvCache::new(&m.cfg);
        let mut c1 = KvCache::new(&m.cfg);
        let mut got = Matrix::zeros(0, 0);
        for step in 0..seqs[0].len() {
            let tokens = [seqs[0][step], seqs[1][step]];
            let mut caches = [&mut c0, &mut c1];
            got = m.decode_step_batch(&tokens, &mut caches);
        }
        assert_eq!(got.rows, 2);
        for (i, w) in want.iter().enumerate() {
            for (a, b) in got.row(i).iter().zip(w) {
                assert!((a - b).abs() < 1e-4, "seq {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn decode_step_batch_ws_is_bit_identical_and_stops_allocating() {
        // The serve engine's persistent-workspace path must be the same
        // arithmetic as the throwaway-workspace convenience, and must stop
        // taking fresh heap buffers once the per-step shapes have been
        // seen (the decode loop's xt/out reuse contract).
        let m = tiny();
        let seqs = [vec![7usize, 3, 11, 2, 8, 1], vec![5usize, 1, 9, 14, 2, 6]];
        let mut ws = Workspace::new();
        let mut c0 = KvCache::new(&m.cfg);
        let mut c1 = KvCache::new(&m.cfg);
        let mut r0 = KvCache::new(&m.cfg);
        let mut r1 = KvCache::new(&m.cfg);
        let mut warm = 0usize;
        for step in 0..seqs[0].len() {
            let tokens = [seqs[0][step], seqs[1][step]];
            let got = {
                let mut caches = [&mut c0, &mut c1];
                m.decode_step_batch_ws(&tokens, &mut caches, &mut ws)
            };
            let want = {
                let mut caches = [&mut r0, &mut r1];
                m.decode_step_batch(&tokens, &mut caches)
            };
            assert_eq!(got, want, "step {step}: workspace path diverged");
            ws.recycle(got);
            if step == 0 {
                warm = ws.alloc_count();
                assert!(warm > 0, "first step must populate the pool");
            }
        }
        assert_eq!(ws.alloc_count(), warm, "steady-state steps must not allocate");
        assert!(ws.reuse_count() > 0);
    }

    #[test]
    fn pack_is_idempotent_and_skips_dense() {
        let mut m = tiny();
        assert_eq!(m.pack_for_serving(4), 0, "all-dense model has nothing to pack");
        let w = m.blocks[0].q.dense_view();
        m.set_linear(
            LinearId { block: 0, name: "q" },
            LinearOp::Compressed(CompressedLayer::Sparse(Csr::from_dense(&w))),
        );
        assert_eq!(m.pack_for_serving(4), 1);
        assert_eq!(m.pack_for_serving(4), 0, "second pack is a no-op");
    }

    #[test]
    fn attention_probs_rows_sum_to_one() {
        let m = tiny();
        let toks = vec![vec![1usize, 2, 3, 4, 5]];
        let h = m.embed(&toks);
        let mut probs = Vec::new();
        let _ = m.block_forward(0, &h, 1, 5, None, Some(&mut probs));
        assert_eq!(probs.len(), 1);
        let p = &probs[0];
        for t in 0..5 {
            let sum: f32 = p.row(t).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {t} sums to {sum}");
            // causal: no mass beyond t
            for u in t + 1..5 {
                assert_eq!(p.at(t, u), 0.0);
            }
        }
    }

    #[test]
    fn loss_near_log_vocab_at_init() {
        let m = tiny();
        let c = crate::data::SyntheticCorpus::new(crate::data::CorpusConfig::for_vocab(
            m.cfg.vocab,
            1,
        ));
        let b = c.batch(2, 16, &mut c.stream(0));
        let loss = m.loss(&b.inputs, &b.targets);
        let logv = (m.cfg.vocab as f64).ln();
        assert!((loss - logv).abs() < 1.0, "init loss {loss} vs log(V) {logv}");
    }

    #[test]
    fn achieved_compression_tracks_layers() {
        let mut m = tiny();
        assert_eq!(m.achieved_compression(), 0.0);
        // Zero out half of q in block 0 via CSR.
        let w = m.blocks[0].q.dense_view();
        let k = w.rows * w.cols / 2;
        let pruned = crate::compress::threshold::hard_threshold(
            &w,
            &w,
            k,
            crate::config::SparsityPattern::LayerWise,
        );
        m.set_linear(
            LinearId { block: 0, name: "q" },
            LinearOp::Compressed(CompressedLayer::Sparse(Csr::from_dense(&pruned))),
        );
        assert!(m.achieved_compression() > 0.0);
    }
}
