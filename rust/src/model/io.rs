//! Model weight persistence: a JSON manifest plus a raw little-endian f32
//! blob. The same layout is produced by the training driver (which receives
//! parameters back from the PJRT train-step artifact) and consumed by every
//! evaluation/serving path, so trained models round-trip rust↔JAX exactly.

use super::lm::{Block, LinearOp, TransformerLM};
use crate::config::ModelConfig;
use crate::json::{self, Json};
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Canonical parameter order — MUST match `python/compile/model.py::param_names`.
pub fn param_names(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec!["tok_emb".to_string(), "pos_emb".to_string()];
    for b in 0..cfg.n_layers {
        for t in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w_up", "w_down"] {
            names.push(format!("block{b}.{t}"));
        }
    }
    names.push("lnf_g".into());
    names.push("lnf_b".into());
    names.push("head".into());
    names
}

/// Shape of each named parameter.
pub fn param_shape(cfg: &ModelConfig, name: &str) -> (usize, usize) {
    let d = cfg.d_model;
    match name {
        "tok_emb" => (cfg.vocab, d),
        "pos_emb" => (cfg.seq_len, d),
        "lnf_g" | "lnf_b" => (1, d),
        "head" => (cfg.vocab, d),
        _ => {
            let t = name.split('.').nth(1).expect("block param");
            match t {
                "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" => (1, d),
                "wq" | "wk" | "wv" | "wo" => (d, d),
                "w_up" => (cfg.d_ff, d),
                "w_down" => (d, cfg.d_ff),
                other => panic!("unknown block param '{other}'"),
            }
        }
    }
}

/// Flatten the model's parameters in canonical order (dense views).
pub fn flatten(model: &TransformerLM) -> Vec<(String, Matrix)> {
    let cfg = &model.cfg;
    let mut out = Vec::new();
    out.push(("tok_emb".to_string(), model.tok_emb.clone()));
    out.push(("pos_emb".to_string(), model.pos_emb.clone()));
    for (b, blk) in model.blocks.iter().enumerate() {
        let vecm = |v: &Vec<f32>| Matrix::from_vec(1, v.len(), v.clone());
        out.push((format!("block{b}.ln1_g"), vecm(&blk.ln1_g)));
        out.push((format!("block{b}.ln1_b"), vecm(&blk.ln1_b)));
        out.push((format!("block{b}.wq"), blk.q.dense_view()));
        out.push((format!("block{b}.wk"), blk.k.dense_view()));
        out.push((format!("block{b}.wv"), blk.v.dense_view()));
        out.push((format!("block{b}.wo"), blk.o.dense_view()));
        out.push((format!("block{b}.ln2_g"), vecm(&blk.ln2_g)));
        out.push((format!("block{b}.ln2_b"), vecm(&blk.ln2_b)));
        out.push((format!("block{b}.w_up"), blk.up.dense_view()));
        out.push((format!("block{b}.w_down"), blk.down.dense_view()));
    }
    out.push(("lnf_g".to_string(), Matrix::from_vec(1, cfg.d_model, model.lnf_g.clone())));
    out.push(("lnf_b".to_string(), Matrix::from_vec(1, cfg.d_model, model.lnf_b.clone())));
    out.push(("head".to_string(), model.head.clone()));
    out
}

/// Rebuild a model from named dense tensors.
pub fn assemble(cfg: &ModelConfig, tensors: &[(String, Matrix)]) -> Result<TransformerLM> {
    let get = |name: &str| -> Result<&Matrix> {
        tensors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m)
            .with_context(|| format!("missing tensor '{name}'"))
    };
    let vec_of = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.data.clone()) };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for b in 0..cfg.n_layers {
        blocks.push(Block {
            ln1_g: vec_of(&format!("block{b}.ln1_g"))?,
            ln1_b: vec_of(&format!("block{b}.ln1_b"))?,
            ln2_g: vec_of(&format!("block{b}.ln2_g"))?,
            ln2_b: vec_of(&format!("block{b}.ln2_b"))?,
            q: LinearOp::Dense(get(&format!("block{b}.wq"))?.clone()),
            k: LinearOp::Dense(get(&format!("block{b}.wk"))?.clone()),
            v: LinearOp::Dense(get(&format!("block{b}.wv"))?.clone()),
            o: LinearOp::Dense(get(&format!("block{b}.wo"))?.clone()),
            up: LinearOp::Dense(get(&format!("block{b}.w_up"))?.clone()),
            down: LinearOp::Dense(get(&format!("block{b}.w_down"))?.clone()),
        });
    }
    Ok(TransformerLM {
        cfg: cfg.clone(),
        tok_emb: get("tok_emb")?.clone(),
        pos_emb: get("pos_emb")?.clone(),
        blocks,
        lnf_g: vec_of("lnf_g")?,
        lnf_b: vec_of("lnf_b")?,
        head: get("head")?.clone(),
    })
}

/// Save a named tensor list (generic: LM, ViT, …) as manifest.json +
/// weights.bin under `dir/`.
pub fn save_tensors(dir: &Path, config: Json, tensors: &[(String, Matrix)]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut manifest = Json::obj();
    manifest.set("config", config);
    let mut entries = Vec::new();
    let mut offset = 0usize;
    let mut blob: Vec<u8> = Vec::new();
    for (name, m) in tensors {
        let mut e = Json::obj();
        e.set("name", json::s(name))
            .set("rows", json::num(m.rows as f64))
            .set("cols", json::num(m.cols as f64))
            .set("offset", json::num(offset as f64));
        entries.push(e);
        for &v in &m.data {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        offset += m.data.len();
    }
    manifest.set("tensors", Json::Arr(entries));
    std::fs::write(dir.join("manifest.json"), manifest.to_pretty())?;
    let mut f = std::fs::File::create(dir.join("weights.bin"))?;
    f.write_all(&blob)?;
    Ok(())
}

/// Load a tensor directory saved by [`save_tensors`].
pub fn load_tensors(dir: &Path) -> Result<(Json, Vec<(String, Matrix)>)> {
    let manifest =
        json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)
            .context("parsing manifest.json")?;
    let mut blob = Vec::new();
    std::fs::File::open(dir.join("weights.bin"))?.read_to_end(&mut blob)?;
    let entries = manifest
        .get("tensors")
        .and_then(Json::as_arr)
        .context("manifest missing 'tensors'")?;
    let mut tensors = Vec::with_capacity(entries.len());
    for e in entries {
        let name = e.req_str("name")?.to_string();
        let rows = e.req_usize("rows")?;
        let cols = e.req_usize("cols")?;
        let offset = e.req_usize("offset")?;
        let n = rows * cols;
        let bytes = blob
            .get(offset * 4..(offset + n) * 4)
            .context("weights.bin too short")?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push((name, Matrix::from_vec(rows, cols, data)));
    }
    let config = manifest.get("config").context("manifest missing 'config'")?.clone();
    Ok((config, tensors))
}

/// Save a model to `dir/` as manifest.json + weights.bin.
pub fn save(model: &TransformerLM, dir: &Path) -> Result<()> {
    save_tensors(dir, model.cfg.to_json(), &flatten(model))
}

/// Load a model saved by [`save`].
pub fn load(dir: &Path) -> Result<TransformerLM> {
    let (config, tensors) = load_tensors(dir)?;
    let cfg = ModelConfig::from_json(&config)?;
    assemble(&cfg, &tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let m = TransformerLM::init(&cfg, 7);
        let dir = std::env::temp_dir().join(format!("oats_io_test_{}", std::process::id()));
        save(&m, &dir).unwrap();
        let m2 = load(&dir).unwrap();
        let toks = vec![vec![1usize, 2, 3, 4]];
        assert!(m.forward(&toks).fro_dist(&m2.forward(&toks)) < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn param_names_match_flatten_order() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let m = TransformerLM::init(&cfg, 1);
        let names = param_names(&cfg);
        let tensors = flatten(&m);
        assert_eq!(names.len(), tensors.len());
        for (n, (tn, t)) in names.iter().zip(&tensors) {
            assert_eq!(n, tn);
            let (r, c) = param_shape(&cfg, n);
            assert_eq!((t.rows, t.cols), (r, c), "{n}");
        }
    }

    #[test]
    fn assemble_rejects_missing() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        assert!(assemble(&cfg, &[]).is_err());
    }
}
