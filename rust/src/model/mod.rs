//! The native transformer language model — the serving-engine side of the
//! system. Supports two execution paths per linear layer (dense GEMM or
//! compressed sparse+low-rank kernels), full-sequence forward for
//! training-parity/perplexity/calibration, and KV-cached single-token decode
//! for the throughput experiments (Table 7 / Table 14).

pub mod compressed_io;
pub mod io;
pub mod lm;

pub use lm::{
    Block, ForwardCapture, KvCache, KvPage, LinearId, LinearOp, TransformerLM, LINEAR_NAMES,
};
