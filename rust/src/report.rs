//! Table/figure rendering: paper-format rows for every experiment
//! regenerator, plus JSON result records for EXPERIMENTS.md.

use crate::json::{self, Json};

/// A simple fixed-width table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncols {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON record of the table (results log).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", json::s(&self.title));
        o.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| json::s(h)).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| json::s(c)).collect()))
                    .collect(),
            ),
        );
        o
    }
}

/// Format helpers matching the paper's precision.
pub fn pct(x: f64) -> String {
    format!("{:.2}", x)
}

pub fn ppl(x: f64) -> String {
    format!("{:.2}", x)
}

pub fn speedup(x: f64) -> String {
    format!("{:.2}x", x)
}

/// Append a results record (one JSON object per line) to `results.jsonl`
/// in the given directory.
pub fn append_result(dir: &std::path::Path, record: &Json) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join("results.jsonl"))?;
    writeln!(f, "{}", record.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "PPL"]);
        t.row(vec!["OATS".into(), "15.18".into()]);
        t.row(vec!["SparseGPT".into(), "16.80".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("SparseGPT"));
        // Columns aligned: both data lines have PPL at same offset.
        let lines: Vec<&str> = s.lines().collect();
        let off1 = lines[3].find("15.18").unwrap();
        let off2 = lines[4].find("16.80").unwrap();
        assert_eq!(off1, off2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_record() {
        let mut t = Table::new("T", &["h"]);
        t.row(vec!["v".into()]);
        let j = t.to_json();
        assert_eq!(j.req_str("title").unwrap(), "T");
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(59.988), "59.99");
        assert_eq!(speedup(1.375), "1.38x");
    }
}
