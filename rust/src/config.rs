//! Typed experiment configuration: model presets (the stand-ins for the
//! paper's Phi-3 / Llama-3 / Qwen families), compression settings, and
//! pipeline options. JSON-backed so configs can be checked into `configs/`
//! and reproduced exactly.

use crate::json::{self, Json};
use anyhow::Result;

/// Transformer LM architecture hyperparameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    /// Parameter count of the linear weights subject to compression
    /// (q,k,v,o + up,down per block; embeddings/head excluded per paper §3.1).
    pub fn prunable_params(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = 2 * self.d_model * self.d_ff;
        self.n_layers * (attn + mlp)
    }

    /// Total parameter count (incl. embeddings, head, layernorms).
    pub fn total_params(&self) -> usize {
        let emb = self.vocab * self.d_model + self.seq_len * self.d_model;
        let head = self.vocab * self.d_model;
        let ln = self.n_layers * 4 * self.d_model + 2 * self.d_model;
        emb + head + ln + self.prunable_params()
    }

    /// Model presets. Sizes scale the same way the paper's model families do
    /// (see DESIGN.md §3 substitution table).
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let c = match name {
            // stands in for Phi-3 Mini
            "tiny" => ModelConfig {
                name: "tiny".into(),
                vocab: 256,
                d_model: 64,
                n_heads: 4,
                n_layers: 2,
                d_ff: 256,
                seq_len: 64,
            },
            // stands in for Llama-3 8B
            "small" => ModelConfig {
                name: "small".into(),
                vocab: 256,
                d_model: 128,
                n_heads: 4,
                n_layers: 4,
                d_ff: 512,
                seq_len: 128,
            },
            // stands in for Phi-3 Medium
            "base" => ModelConfig {
                name: "base".into(),
                vocab: 512,
                d_model: 256,
                n_heads: 8,
                n_layers: 6,
                d_ff: 1024,
                seq_len: 128,
            },
            // stands in for Llama-3 70B
            "large" => ModelConfig {
                name: "large".into(),
                vocab: 512,
                d_model: 384,
                n_heads: 8,
                n_layers: 8,
                d_ff: 1536,
                seq_len: 128,
            },
            // stands in for Qwen-2.5 3B (different FFN ratio, Table 17)
            "alt" => ModelConfig {
                name: "alt".into(),
                vocab: 256,
                d_model: 128,
                n_heads: 4,
                n_layers: 4,
                d_ff: 768,
                seq_len: 128,
            },
            other => anyhow::bail!("unknown model preset '{other}' (tiny|small|base|large|alt)"),
        };
        Ok(c)
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", json::s(&self.name))
            .set("vocab", json::num(self.vocab as f64))
            .set("d_model", json::num(self.d_model as f64))
            .set("n_heads", json::num(self.n_heads as f64))
            .set("n_layers", json::num(self.n_layers as f64))
            .set("d_ff", json::num(self.d_ff as f64))
            .set("seq_len", json::num(self.seq_len as f64));
        o
    }

    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            vocab: v.req_usize("vocab")?,
            d_model: v.req_usize("d_model")?,
            n_heads: v.req_usize("n_heads")?,
            n_layers: v.req_usize("n_layers")?,
            d_ff: v.req_usize("d_ff")?,
            seq_len: v.req_usize("seq_len")?,
        })
    }
}

/// Which compression algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Dense,
    Magnitude,
    Wanda,
    SparseGpt,
    DsNoT,
    Oats,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" => Method::Dense,
            "magnitude" | "mag" => Method::Magnitude,
            "wanda" => Method::Wanda,
            "sparsegpt" => Method::SparseGpt,
            "dsnot" => Method::DsNoT,
            "oats" => Method::Oats,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "Dense",
            Method::Magnitude => "Magnitude",
            Method::Wanda => "Wanda",
            Method::SparseGpt => "SparseGPT",
            Method::DsNoT => "DSNoT",
            Method::Oats => "OATS",
        }
    }

    pub fn all_pruners() -> [Method; 5] {
        [Method::Magnitude, Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats]
    }
}

/// Granularity of the hard-threshold step (paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsityPattern {
    /// Top-k over the whole matrix.
    LayerWise,
    /// Top-⌊k/m⌋ per output row (Wanda's comparison-group; paper default).
    RowWise,
    /// N:M semi-structured.
    Nm { n: usize, m: usize },
}

impl SparsityPattern {
    pub fn parse(s: &str) -> Result<SparsityPattern> {
        match s.to_ascii_lowercase().as_str() {
            "layer" | "layerwise" => Ok(SparsityPattern::LayerWise),
            "row" | "rowwise" => Ok(SparsityPattern::RowWise),
            other => {
                if let Some((n, m)) = other.split_once(':') {
                    let n = n.parse()?;
                    let m = m.parse()?;
                    anyhow::ensure!(n > 0 && m > n, "bad N:M '{other}'");
                    Ok(SparsityPattern::Nm { n, m })
                } else {
                    anyhow::bail!("unknown sparsity pattern '{other}' (layer|row|N:M)")
                }
            }
        }
    }
}

/// Full compression run configuration (paper Algorithm 2 inputs + ablation
/// switches from §3.3 and Appendices A.3–A.5).
#[derive(Clone, Debug)]
pub struct CompressConfig {
    pub method: Method,
    /// Compression rate ρ ∈ (0,1).
    pub rate: f64,
    /// Rank ratio κ ∈ [0,1) — fraction of the kept budget spent on L.
    pub rank_ratio: f64,
    /// Alternating-thresholding iterations N.
    pub iters: usize,
    pub pattern: SparsityPattern,
    /// Scale by D = sqrt(diag(XᵀX)) (ablation: Table 6 "No Scaling").
    pub scale_by_d: bool,
    /// Use the outlier-robust median scaling instead (Appendix A.3).
    pub robust_scaling: bool,
    /// Perform hard-threshold before SVT (Appendix A.4 order ablation).
    pub threshold_first: bool,
    /// Only scale the low-rank term, prune S on raw magnitudes (App. A.5).
    pub scale_lowrank_only: bool,
    /// Use OWL non-uniform layerwise rates (paper §3.1, Table 5).
    pub owl: bool,
    /// OWL hyperparameter λ: rates clipped to rate ± λ.
    pub owl_lambda: f64,
    /// OWL outlier threshold multiple M.
    pub owl_m: f64,
    /// Structured rotate-and-slice on each block's FFN pair: `Some(rate)`
    /// deletes the lowest-energy fraction of d_ff channels (0.0 =
    /// rotation-only, an exact energy-ranked permutation). `None` (default)
    /// disables the pass entirely.
    pub slice_rate: Option<f64>,
    /// Per-layer error gate for the slice pass: the sliced pair is kept only
    /// when both layers' weight-space relative reconstruction errors stay at
    /// or below this bound (same ‖W−Ŵ‖_F/‖W‖_F machinery as `QuantGate`).
    /// Dropped-channel error scales like sqrt(slice_rate) on
    /// uniform-energy weights, so this bound is far looser than the i8
    /// quantization gate's 5 %.
    pub slice_max_rel_error: f64,
    /// Seed for the randomized SVD.
    pub seed: u64,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            method: Method::Oats,
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 80,
            pattern: SparsityPattern::RowWise,
            scale_by_d: true,
            robust_scaling: false,
            threshold_first: false,
            scale_lowrank_only: false,
            owl: false,
            owl_lambda: 0.08,
            owl_m: 5.0,
            slice_rate: None,
            slice_max_rel_error: 0.75,
            seed: 0xA75,
        }
    }
}

/// Calibration configuration (paper §3.1: 128 sequences, C4 → our corpus).
#[derive(Clone, Debug)]
pub struct CalibConfig {
    pub n_sequences: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { n_sequences: 128, seq_len: 128, seed: 0xCA11B }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_scale_monotonically() {
        let sizes: Vec<usize> = ["tiny", "small", "base", "large"]
            .iter()
            .map(|n| ModelConfig::preset(n).unwrap().total_params())
            .collect();
        for w in sizes.windows(2) {
            assert!(w[0] < w[1], "{sizes:?}");
        }
    }

    #[test]
    fn preset_unknown_fails() {
        assert!(ModelConfig::preset("llama-3-70b").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("base").unwrap();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("oats").unwrap(), Method::Oats);
        assert_eq!(Method::parse("SparseGPT").unwrap(), Method::SparseGpt);
        assert!(Method::parse("??").is_err());
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(SparsityPattern::parse("row").unwrap(), SparsityPattern::RowWise);
        assert_eq!(
            SparsityPattern::parse("2:8").unwrap(),
            SparsityPattern::Nm { n: 2, m: 8 }
        );
        assert!(SparsityPattern::parse("8:2").is_err());
        assert!(SparsityPattern::parse("x").is_err());
    }

    #[test]
    fn head_dim_divides() {
        for p in ["tiny", "small", "base", "large", "alt"] {
            let c = ModelConfig::preset(p).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{p}");
        }
    }
}
