//! Command-line argument parsing (stands in for `clap`): subcommands plus
//! `--flag value` / `--flag=value` / boolean `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of f64s.
    pub fn f64_list_flag(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.flag(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["compress", "--rate", "0.5", "--owl", "--model=base"]);
        assert_eq!(a.command, "compress");
        assert_eq!(a.f64_flag("rate", 0.0), 0.5);
        assert!(a.bool_flag("owl"));
        assert_eq!(a.flag("model"), Some("base"));
    }

    #[test]
    fn positionals() {
        let a = parse(&["eval", "path/to/model", "--ppl"]);
        assert_eq!(a.positional, vec!["path/to/model"]);
        assert!(a.bool_flag("ppl"));
    }

    #[test]
    fn negative_number_flag_value() {
        // `--offset -3` — "-3" does not start with "--" so it is the value.
        let a = parse(&["run", "--offset", "-3"]);
        assert_eq!(a.flag("offset"), Some("-3"));
    }

    #[test]
    fn list_flag() {
        let a = parse(&["sweep", "--rates", "0.3,0.4, 0.5"]);
        assert_eq!(a.f64_list_flag("rates", &[]), vec![0.3, 0.4, 0.5]);
        assert_eq!(a.f64_list_flag("missing", &[1.0]), vec![1.0]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.command, "");
        assert_eq!(a.usize_flag("iters", 80), 80);
        assert_eq!(a.flag_or("out", "artifacts"), "artifacts");
    }
}
