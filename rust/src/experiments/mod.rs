//! Experiment regenerators: one driver per table/figure of the paper
//! (DESIGN.md §6 experiment index). Each driver returns a
//! [`crate::report::Table`] shaped like the paper's and appends raw JSON
//! records to `results/results.jsonl`.

pub mod speed;
pub mod sweeps;
pub mod tables;
pub mod vision;

use crate::calib::CalibSet;
use crate::config::ModelConfig;
use crate::data::{CorpusConfig, SyntheticCorpus};
use crate::model::TransformerLM;
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;

/// Shared experiment context: trained-model cache, corpora, sizing knobs.
pub struct Ctx {
    pub artifacts: PathBuf,
    pub models: PathBuf,
    pub results: PathBuf,
    /// Reduced sizes for CI / smoke runs.
    pub quick: bool,
    corpora: HashMap<String, SyntheticCorpus>,
    model_cache: HashMap<String, TransformerLM>,
}

impl Ctx {
    pub fn new(root: &std::path::Path, quick: bool) -> Ctx {
        Ctx {
            artifacts: root.join("artifacts"),
            models: root.join("models"),
            results: root.join("results"),
            quick,
            corpora: HashMap::new(),
            model_cache: HashMap::new(),
        }
    }

    /// Training steps per preset (quick mode trains briefly).
    pub fn train_steps(&self, preset: &str) -> usize {
        if self.quick {
            40
        } else {
            // Sized so the fact-recall ("hard") suite trains well above
            // chance, leaving headroom for compression-induced degradation
            // (tiny reaches hard≈60% at 8k steps; larger presets learn the
            // same corpus faster per step).
            match preset {
                "tiny" => 8000,
                "small" => 2000,
                "base" => 1500,
                "large" => 800,
                _ => 4000,
            }
        }
    }

    pub fn corpus(&mut self, preset: &str) -> Result<&SyntheticCorpus> {
        if !self.corpora.contains_key(preset) {
            let cfg = ModelConfig::preset(preset)?;
            let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 0xC0DE));
            self.corpora.insert(preset.to_string(), corpus);
        }
        Ok(&self.corpora[preset])
    }

    /// Trained model for a preset (trains via the PJRT artifact on first use,
    /// then caches under models/<preset>/).
    pub fn model(&mut self, preset: &str) -> Result<TransformerLM> {
        if let Some(m) = self.model_cache.get(preset) {
            return Ok(m.clone());
        }
        let steps = self.train_steps(preset);
        let corpus_owned;
        {
            let corpus = self.corpus(preset)?;
            corpus_owned = SyntheticCorpus::new(corpus.cfg.clone());
        }
        let model = crate::train::ensure_trained_model(
            &self.artifacts,
            &self.models,
            preset,
            steps,
            &corpus_owned,
        )?;
        self.model_cache.insert(preset.to_string(), model.clone());
        Ok(model)
    }

    /// Calibration set (paper: 128 × 2048 from C4; here scaled to preset).
    pub fn calib(&mut self, preset: &str) -> Result<CalibSet> {
        let cfg = ModelConfig::preset(preset)?;
        let n_seq = if self.quick { 8 } else { 64 };
        let seq = cfg.seq_len.min(64);
        let corpus = self.corpus(preset)?;
        Ok(CalibSet::sample(corpus, n_seq, seq, 8))
    }

    /// Evaluation sizing.
    pub fn eval_batches(&self) -> usize {
        if self.quick {
            2
        } else {
            8
        }
    }

    pub fn eval_probes(&self) -> usize {
        if self.quick {
            24
        } else {
            150
        }
    }

    pub fn record(&self, record: &crate::json::Json) {
        let _ = crate::report::append_result(&self.results, record);
    }
}
