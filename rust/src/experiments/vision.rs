//! Vision experiments: Table 8 (ViT accuracy under compression) and the
//! Section-5 rollout analysis (Figures 3–4).

use super::Ctx;
use crate::compress::{compress_layer, CalibStats};
use crate::config::{CompressConfig, Method};
use crate::data::images::{ImageDataset, ImagesConfig};
use crate::json::{self, Json};
use crate::model::{ForwardCapture, LinearId, LinearOp, LINEAR_NAMES};
use crate::report::{pct, Table};
use crate::vit::rollout::{ascii_heatmap, heatmap_cosine, rollout_split, write_pgm};
use crate::vit::{Component, Vit};
use anyhow::Result;

/// Train (or load cached) the ViT used by the vision experiments.
pub fn trained_vit(ctx: &Ctx) -> Result<Vit> {
    let ds = ImageDataset::new(ImagesConfig::default());
    let steps = if ctx.quick { 60 } else { 500 };
    crate::train::ensure_trained_vit(&ctx.artifacts, &ctx.models, "tiny", steps, &ds)
}

/// Compress every layer of a ViT with the given config (sequential
/// calibration propagation, mirroring the LM pipeline).
pub fn compress_vit(
    vit: &Vit,
    cfg: &CompressConfig,
    calib_images: &[crate::data::images::Image],
) -> Result<Vit> {
    let mut v = vit.clone();
    let refs: Vec<&[f32]> = calib_images.iter().map(|i| i.pixels.as_slice()).collect();
    let mut h = v.embed(&refs);
    for b in 0..v.blocks.len() {
        let mut cap = ForwardCapture::default();
        let _ = v.block_forward(b, &h, refs.len(), Component::Both, None, Some(&mut cap));
        let mut stats: std::collections::HashMap<&'static str, CalibStats> = Default::default();
        for name in LINEAR_NAMES {
            let x = &cap.inputs[name];
            let mut st = CalibStats::new(x.cols);
            st.update(x, 128);
            st.finalize();
            stats.insert(name, st);
        }
        for name in LINEAR_NAMES {
            let w = v.blocks[b].linear(name).dense_view();
            let c = compress_layer(&w, &stats[name], cfg)?;
            v.set_linear(LinearId { block: b, name }, LinearOp::Compressed(c));
        }
        h = v.block_forward(b, &h, refs.len(), Component::Both, None, None);
    }
    Ok(v)
}

/// Table 8 analogue: top-1 accuracy under compression, all methods.
pub fn table8(ctx: &mut Ctx) -> Result<Table> {
    let vit = trained_vit(ctx)?;
    let ds = ImageDataset::new(ImagesConfig::default());
    let calib = ds.batch(if ctx.quick { 16 } else { 64 }, &mut ds.stream(0xCA));
    let eval_imgs = ds.batch(if ctx.quick { 64 } else { 400 }, &mut ds.stream(0xEF));

    let mut t = Table::new(
        "Table 8 — ViT top-1 accuracy (%) on synthetic-shapes validation",
        &["Compression", "Method", "Top-1"],
    );
    let dense_acc = vit.accuracy(&eval_imgs, Component::Both);
    t.row(vec!["0%".into(), "Dense".into(), pct(100.0 * dense_acc)]);
    for rate in [0.3, 0.4, 0.5] {
        for method in [Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats] {
            let cfg = CompressConfig {
                method,
                rate,
                rank_ratio: 0.2, // paper: ViT experiments use κ=20%
                iters: if ctx.quick { 6 } else { 80 },
                ..Default::default()
            };
            let cv = compress_vit(&vit, &cfg, &calib)?;
            let acc = cv.accuracy(&eval_imgs, Component::Both);
            let mut rec = Json::obj();
            rec.set("exp", json::s("t8_vit"))
                .set("rate", json::num(rate))
                .set("method", json::s(method.name()))
                .set("top1", json::num(100.0 * acc));
            ctx.record(&rec);
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                method.name().into(),
                pct(100.0 * acc),
            ]);
        }
    }
    Ok(t)
}

/// Figures 3–4: rollout split of a 50%-compressed ViT (κ=0.2); writes PGM
/// heatmaps + ASCII art and returns a table of S-vs-L heatmap cosines.
pub fn rollout_analysis(ctx: &mut Ctx, out_dir: &std::path::Path) -> Result<Table> {
    let vit = trained_vit(ctx)?;
    let ds = ImageDataset::new(ImagesConfig::default());
    let calib = ds.batch(if ctx.quick { 16 } else { 64 }, &mut ds.stream(0xCA));
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.5,
        rank_ratio: 0.2,
        iters: if ctx.quick { 6 } else { 80 },
        ..Default::default()
    };
    let cv = compress_vit(&vit, &cfg, &calib)?;

    std::fs::create_dir_all(out_dir)?;
    let mut t = Table::new(
        "Figure 4 — sparse vs low-rank rollout separation (cosine similarity)",
        &["Image", "Class", "cos(S, L)", "cos(S, Both)", "cos(L, Both)"],
    );
    let n = if ctx.quick { 4 } else { 12 };
    let mut rng = ds.stream(0xF16);
    let mut cos_sl_total = 0.0;
    for i in 0..n {
        let img = ds.render(i % crate::data::images::N_CLASSES, &mut rng);
        let split = rollout_split(&cv, &img.pixels);
        let cos_sl = heatmap_cosine(&split.sparse, &split.low_rank);
        let cos_sb = heatmap_cosine(&split.sparse, &split.both);
        let cos_lb = heatmap_cosine(&split.low_rank, &split.both);
        cos_sl_total += cos_sl;
        write_pgm(&split.sparse, split.side, &out_dir.join(format!("img{i}_sparse.pgm")))?;
        write_pgm(&split.low_rank, split.side, &out_dir.join(format!("img{i}_lowrank.pgm")))?;
        write_pgm(&split.both, split.side, &out_dir.join(format!("img{i}_both.pgm")))?;
        if i < 2 {
            println!("image {i} (class {}):", img.label);
            println!("  sparse rollout:\n{}", indent(&ascii_heatmap(&split.sparse, split.side)));
            let lowrank_map = ascii_heatmap(&split.low_rank, split.side);
            println!("  low-rank rollout:\n{}", indent(&lowrank_map));
        }
        let mut rec = Json::obj();
        rec.set("exp", json::s("fig4_rollout"))
            .set("image", json::num(i as f64))
            .set("class", json::num(img.label as f64))
            .set("cos_sl", json::num(cos_sl));
        ctx.record(&rec);
        t.row(vec![
            i.to_string(),
            img.label.to_string(),
            format!("{cos_sl:.3}"),
            format!("{cos_sb:.3}"),
            format!("{cos_lb:.3}"),
        ]);
    }
    t.row(vec![
        "mean".into(),
        "-".into(),
        format!("{:.3}", cos_sl_total / n as f64),
        "-".into(),
        "-".into(),
    ]);
    Ok(t)
}

fn indent(s: &str) -> String {
    s.lines().map(|l| format!("    {l}")).collect::<Vec<_>>().join("\n")
}
