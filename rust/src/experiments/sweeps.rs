//! Sweep regenerators: Figure 1 (rank ratio, iteration count), Figure 2
//! (N:M + rank-ratio trade-off), and Table 15 (hyperparameter grid).

use super::tables::paper_kappa;
use super::Ctx;
use crate::config::{CompressConfig, Method, SparsityPattern};
use crate::coordinator::pipeline::compress_clone;
use crate::eval;
use crate::json::{self, Json};
use crate::report::{pct, ppl, Table};
use anyhow::Result;

/// Figure 1 (left): zero-shot / five-shot proxies vs rank ratio κ.
pub fn rank_ratio_sweep(ctx: &mut Ctx, preset: &str, rate: f64) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let kappas = if ctx.quick {
        vec![0.0, 0.25, 0.5]
    } else {
        vec![0.0, 0.05, 0.1, 0.2, 0.25, 0.3, 0.4, 0.5, 0.6]
    };
    let mut t = Table::new(
        &format!("Figure 1a — rank-ratio sweep ({preset}, ρ={rate})"),
        &["κ", "Hard", "Easy", "PPL"],
    );
    for &kappa in &kappas {
        let cfg = CompressConfig {
            method: Method::Oats,
            rate,
            rank_ratio: kappa,
            iters: if ctx.quick { 6 } else { 40 },
            ..Default::default()
        };
        let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
        let row = eval::evaluate(&cm, &corpus, "sweep", ctx.eval_batches(), ctx.eval_probes());
        let mut rec = Json::obj();
        rec.set("exp", json::s("fig1_rank_ratio"))
            .set("kappa", json::num(kappa))
            .set("hard", json::num(row.hard))
            .set("easy", json::num(row.easy))
            .set("ppl", json::num(row.ppl));
        ctx.record(&rec);
        t.row(vec![format!("{kappa:.2}"), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }
    Ok(t)
}

/// Figure 1 (right): metrics vs iteration count N.
pub fn iteration_sweep(ctx: &mut Ctx, preset: &str, rate: f64) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let iters = if ctx.quick {
        vec![1, 5, 10]
    } else {
        vec![1, 5, 10, 20, 40, 80, 120]
    };
    let mut t = Table::new(
        &format!("Figure 1b — iteration sweep ({preset}, ρ={rate})"),
        &["N", "Hard", "Easy", "PPL"],
    );
    for &n in &iters {
        let cfg = CompressConfig {
            method: Method::Oats,
            rate,
            rank_ratio: paper_kappa(preset),
            iters: n,
            ..Default::default()
        };
        let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
        let row = eval::evaluate(&cm, &corpus, "sweep", ctx.eval_batches(), ctx.eval_probes());
        let mut rec = Json::obj();
        rec.set("exp", json::s("fig1_iters"))
            .set("iters", json::num(n as f64))
            .set("hard", json::num(row.hard))
            .set("easy", json::num(row.easy))
            .set("ppl", json::num(row.ppl));
        ctx.record(&rec);
        t.row(vec![n.to_string(), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }
    Ok(t)
}

/// Figure 2: OATS with 2:8 structured sparsity across rank ratios vs
/// baselines at 2:4 (compression on the x-axis).
pub fn nm_sweep(ctx: &mut Ctx, preset: &str) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let mut t = Table::new(
        &format!("Figure 2 — N:M structured sparsity trade-off ({preset})"),
        &["Method", "Pattern", "κ", "Achieved ρ", "Hard", "Easy", "PPL"],
    );
    // Baselines at 2:4 (fixed ρ=0.5 by the pattern).
    for method in [Method::SparseGpt, Method::Wanda, Method::DsNoT] {
        let cfg = CompressConfig {
            method,
            rate: 0.5,
            rank_ratio: 0.0,
            pattern: SparsityPattern::Nm { n: 2, m: 4 },
            ..Default::default()
        };
        let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
        let row = eval::evaluate(&cm, &corpus, "nm", ctx.eval_batches(), ctx.eval_probes());
        let achieved = cm.achieved_compression();
        let mut rec = Json::obj();
        rec.set("exp", json::s("fig2_nm"))
            .set("method", json::s(method.name()))
            .set("pattern", json::s("2:4"))
            .set("achieved", json::num(achieved))
            .set("hard", json::num(row.hard))
            .set("easy", json::num(row.easy))
            .set("ppl", json::num(row.ppl));
        ctx.record(&rec);
        t.row(vec![
            method.name().into(),
            "2:4".into(),
            "-".into(),
            format!("{:.1}%", achieved * 100.0),
            pct(row.hard),
            pct(row.easy),
            ppl(row.ppl),
        ]);
    }
    // OATS at 2:8 with varying κ. Effective rate: sparse term fixes nnz at
    // 25% of entries; the low-rank budget is set by κ through the rate knob:
    // ρ_total = 1 − (0.25 + κ·(1−ρ)) — we express the paper's sweep by
    // holding the 2:8 pattern and varying κ with rate chosen so the
    // low-rank budget matches κ/(1−κ)·nnz.
    let kappas = if ctx.quick {
        vec![0.25, 0.5]
    } else {
        vec![0.25, 0.3, 0.35, 0.4, 0.45, 0.5]
    };
    for &kappa in &kappas {
        // With a 2:8 sparse term (25% density), choose rate so the solver's
        // sparse share matches: k/(dd) = (1−κ)(1−ρ) = 0.25 ⇒ ρ = 1 − 0.25/(1−κ).
        let rate = 1.0 - 0.25 / (1.0 - kappa);
        let cfg = CompressConfig {
            method: Method::Oats,
            rate,
            rank_ratio: kappa,
            iters: if ctx.quick { 6 } else { 40 },
            pattern: SparsityPattern::Nm { n: 2, m: 8 },
            ..Default::default()
        };
        let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
        let row = eval::evaluate(&cm, &corpus, "nm", ctx.eval_batches(), ctx.eval_probes());
        let achieved = cm.achieved_compression();
        let mut rec = Json::obj();
        rec.set("exp", json::s("fig2_nm"))
            .set("method", json::s("OATS"))
            .set("pattern", json::s("2:8"))
            .set("kappa", json::num(kappa))
            .set("achieved", json::num(achieved))
            .set("hard", json::num(row.hard))
            .set("easy", json::num(row.easy))
            .set("ppl", json::num(row.ppl));
        ctx.record(&rec);
        t.row(vec![
            "OATS".into(),
            "2:8".into(),
            format!("{kappa:.2}"),
            format!("{:.1}%", achieved * 100.0),
            pct(row.hard),
            pct(row.easy),
            ppl(row.ppl),
        ]);
    }
    Ok(t)
}

/// Table 15: the κ × ρ hyperparameter grid.
pub fn hyper_grid(ctx: &mut Ctx, preset: &str) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let rates = if ctx.quick { vec![0.4] } else { vec![0.3, 0.4, 0.5] };
    let kappas = if ctx.quick { vec![0.1, 0.3] } else { vec![0.1, 0.2, 0.3] };
    let mut t = Table::new(
        &format!("Table 15 — hyperparameter grid ({preset})"),
        &["ρ", "κ", "Hard", "Easy", "PPL"],
    );
    for &rate in &rates {
        for &kappa in &kappas {
            let cfg = CompressConfig {
                method: Method::Oats,
                rate,
                rank_ratio: kappa,
                iters: if ctx.quick { 6 } else { 40 },
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
            let row = eval::evaluate(&cm, &corpus, "grid", ctx.eval_batches(), ctx.eval_probes());
            let mut rec = Json::obj();
            rec.set("exp", json::s("t15_grid"))
                .set("rate", json::num(rate))
                .set("kappa", json::num(kappa))
                .set("hard", json::num(row.hard))
                .set("easy", json::num(row.easy))
                .set("ppl", json::num(row.ppl));
            ctx.record(&rec);
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                format!("{kappa:.1}"),
                pct(row.hard),
                pct(row.easy),
                ppl(row.ppl),
            ]);
        }
    }
    Ok(t)
}
