//! Hardware-speedup experiments: Table 7 (single-token CPU throughput),
//! Table 14 (256-token sequences), and Table 9 (decomposition wall-clock).
//!
//! These run on the rust serving engine (the DeepSparse stand-in): the same
//! model is executed dense, with unstructured pruning (Wanda), and with
//! OATS' sparse+low-rank layers, through identical batching/decode code, so
//! throughput differences isolate the weight-format kernels.

use super::tables::paper_kappa;
use super::Ctx;
use crate::config::{CompressConfig, Method, SparsityPattern};
use crate::coordinator::serve::{generate, run_load, ServeConfig, ServeStats};
use crate::json::{self, Json};
use crate::model::TransformerLM;
use crate::report::{speedup, Table};
use crate::util::trace;
use anyhow::Result;
use std::sync::Arc;

/// Run the Table 7 measurement — short prompts through the continuous-
/// batching engine — and return the full serving stats (the bench harness
/// records wall time and telemetry, not just the throughput scalar).
pub fn decode_stats(model: &TransformerLM, n_requests: usize, gen_tokens: usize) -> ServeStats {
    let cfg = ServeConfig { slots: 8, gen_tokens, ..Default::default() };
    let prompts: Vec<Vec<usize>> = (0..n_requests)
        .map(|i| vec![(i * 7) % model.cfg.vocab, (i * 13) % model.cfg.vocab, 1])
        .collect();
    run_load(Arc::new(model.clone()), cfg, prompts)
}

/// Single-token decode throughput (tokens/s) of a model: the Table 7
/// measurement — one token generated per request from short prompts.
pub fn decode_throughput(model: &TransformerLM, n_requests: usize, gen_tokens: usize) -> f64 {
    decode_stats(model, n_requests, gen_tokens).tokens_per_second()
}

/// Sequential-generation wall time: one long request (Table 14's regime,
/// where prefill/compute dominates and sparse-format gains shrink).
/// Returns `(seconds, tokens_generated)`.
pub fn sequence_walltime(model: &TransformerLM, tokens: usize) -> (f64, usize) {
    // Single-stream decode: pack for batch 1. At batch 1 the planner keeps
    // CSR for unstructured layers (BCSR needs batch ≥ 2 to pay off), so this
    // only swaps in N:M- or Dense-planned formats where they apply — the
    // measurement stays an honest single-stream scalar-decode number.
    let packed;
    let m = if model.needs_packing() {
        packed = model.packed_for_serving(1);
        &packed
    } else {
        model
    };
    let t = trace::timed("walltime_generate");
    let out = generate(m, &[1, 2, 3], tokens);
    (t.finish(), out.len())
}

/// Sequential-generation throughput (tokens/s).
pub fn sequence_throughput(model: &TransformerLM, tokens: usize) -> f64 {
    let (secs, n) = sequence_walltime(model, tokens);
    n as f64 / secs
}

/// Tables 7/14 runner.
pub fn throughput_table(ctx: &mut Ctx, preset: &str, seq_mode: bool) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let title = if seq_mode {
        "Table 14 — CPU throughput, 256-token sequences (tokens/s)"
    } else {
        "Table 7 — CPU single-token throughput (tokens/s)"
    };
    let mut t = Table::new(title, &["Compression", "Method", "Throughput", "Speedup"]);

    let measure = |m: &TransformerLM| -> f64 {
        if seq_mode {
            let n = if ctx.quick { 32 } else { 128.min(m.cfg.seq_len - 4) };
            sequence_throughput(m, n)
        } else {
            let n_req = if ctx.quick { 16 } else { 64 };
            decode_throughput(m, n_req, 4)
        }
    };

    let dense_tp = measure(&model);
    t.row(vec!["0%".into(), "Dense".into(), format!("{dense_tp:.1}"), speedup(1.0)]);

    for rate in [0.3, 0.4, 0.5] {
        // Unstructured pruning (Wanda) vs OATS.
        for (method, kappa, label) in [
            (Method::Wanda, 0.0, "Unstructured"),
            (Method::Oats, paper_kappa(preset), "OATS"),
        ] {
            let cfg = CompressConfig {
                method,
                rate,
                rank_ratio: kappa,
                iters: if ctx.quick { 4 } else { 40 },
                pattern: SparsityPattern::RowWise,
                ..Default::default()
            };
            let (cm, _) = crate::coordinator::pipeline::compress_clone(&model, &calib, &cfg, 6)?;
            let tp = measure(&cm);
            let mut rec = Json::obj();
            rec.set("exp", json::s(if seq_mode { "t14_seq" } else { "t7_decode" }))
                .set("preset", json::s(preset))
                .set("rate", json::num(rate))
                .set("method", json::s(label))
                .set("tokens_per_s", json::num(tp))
                .set("speedup", json::num(tp / dense_tp));
            ctx.record(&rec);
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                label.into(),
                format!("{tp:.1}"),
                speedup(tp / dense_tp),
            ]);
        }
    }
    Ok(t)
}

/// One Table 9 measurement row.
pub struct WalltimeRow {
    pub preset: &'static str,
    pub serial_s_per_iter: f64,
    pub parallel_s_per_iter: f64,
}

/// Table 9 measurements: wall-clock per OATS alternating-thresholding
/// iteration for one transformer block's six linears, serial and with the
/// §A.2-style 4-worker fan-out. Shared by the table printer and the bench
/// JSON emitter.
pub fn walltime_rows(quick: bool) -> Result<Vec<WalltimeRow>> {
    use crate::compress::oats::alternating_thresholding;
    use crate::compress::params;
    use crate::tensor::Matrix;
    use crate::util::prng::Rng;

    let presets = if quick { vec!["tiny"] } else { vec!["tiny", "small", "base", "large"] };
    let mut rows = Vec::new();
    for preset in presets {
        let cfg = crate::config::ModelConfig::preset(preset)?;
        let mut rng = Rng::new(1);
        // A block = 4 attention (d×d) + up (dff×d) + down (d×dff).
        let layers: Vec<(usize, usize)> = vec![
            (cfg.d_model, cfg.d_model),
            (cfg.d_model, cfg.d_model),
            (cfg.d_model, cfg.d_model),
            (cfg.d_model, cfg.d_model),
            (cfg.d_ff, cfg.d_model),
            (cfg.d_model, cfg.d_ff),
        ];
        let mats: Vec<Matrix> = layers
            .iter()
            .map(|&(o, i)| Matrix::randn(o, i, 1.0, &mut rng))
            .collect();
        let iters = 3;
        let run_one = |m: &Matrix| {
            let p = params::solve(m.rows, m.cols, 0.5, 0.25);
            let mut r = Rng::new(7);
            let _ = alternating_thresholding(
                m,
                iters,
                p.rank,
                p.nonzeros,
                SparsityPattern::RowWise,
                false,
                None,
                &mut r,
            );
        };
        // Serial.
        let t_serial = trace::timed("walltime_serial");
        for m in &mats {
            run_one(m);
        }
        let serial = t_serial.finish() / iters as f64;
        // Parallel (4 workers, as in paper §A.2's multi-GPU analogy).
        let t_par = trace::timed("walltime_parallel");
        std::thread::scope(|s| {
            for m in &mats {
                s.spawn(move || run_one(m));
            }
        });
        let par = t_par.finish() / iters as f64;
        rows.push(WalltimeRow { preset, serial_s_per_iter: serial, parallel_s_per_iter: par });
    }
    Ok(rows)
}

/// Render measured [`WalltimeRow`]s as the paper-style Table 9 — the one
/// presentation shared by the `bench-table t9` path and the
/// `table9_walltime` bench target.
pub fn walltime_table_from_rows(rows: &[WalltimeRow]) -> Table {
    let mut t = Table::new(
        "Table 9 — seconds per OATS iteration per transformer block",
        &["Preset", "s/iter (serial)", "s/iter (4 workers)"],
    );
    for row in rows {
        t.row(vec![
            row.preset.into(),
            format!("{:.3}", row.serial_s_per_iter),
            format!("{:.3}", row.parallel_s_per_iter),
        ]);
    }
    t
}

/// Table 9: wall-clock per OATS alternating-thresholding iteration, per
/// preset (the paper reports seconds per transformer block per iteration),
/// plus the 4-worker parallel variant from §A.2.
pub fn walltime_table(quick: bool) -> Result<Table> {
    Ok(walltime_table_from_rows(&walltime_rows(quick)?))
}
