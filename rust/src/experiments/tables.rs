//! Table regenerators: the method-comparison grid (Tables 2–4, 16), the
//! high-compression OWL table (5), the ablations (6, 10–13), and the
//! alternate-architecture benchmark (17).

use super::Ctx;
use crate::config::{CompressConfig, Method, SparsityPattern};
use crate::coordinator::pipeline::compress_clone;
use crate::eval::{self, EvalRow};
use crate::json::{self, Json};
use crate::report::{pct, ppl, Table};
use anyhow::Result;

/// One grid cell: a compressed model's evaluation.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub preset: String,
    pub rate: f64,
    pub method: Method,
    pub row: EvalRow,
    pub achieved_rate: f64,
}

/// Paper Table 1 hyperparameters, adapted per DESIGN.md: κ=0.25 for the
/// Phi-3-like presets, κ=0.3 for the Llama-3-like ones.
pub fn paper_kappa(preset: &str) -> f64 {
    match preset {
        "small" | "large" => 0.30,
        _ => 0.25,
    }
}

fn oats_iters(quick: bool) -> usize {
    if quick {
        8
    } else {
        80
    }
}

/// Run the full (preset × rate × method) grid that feeds Tables 2/3/4/16.
pub fn run_grid(
    ctx: &mut Ctx,
    presets: &[&str],
    rates: &[f64],
    methods: &[Method],
) -> Result<Vec<GridResult>> {
    let mut out = Vec::new();
    for &preset in presets {
        let model = ctx.model(preset)?;
        let calib = ctx.calib(preset)?;
        let corpus_cfg = ctx.corpus(preset)?.cfg.clone();
        let corpus = crate::data::SyntheticCorpus::new(corpus_cfg);
        // Dense reference row.
        let (eb, ep) = (ctx.eval_batches(), ctx.eval_probes());
        let dense_row = eval::evaluate(&model, &corpus, "Dense", eb, ep);
        out.push(GridResult {
            preset: preset.into(),
            rate: 0.0,
            method: Method::Dense,
            row: dense_row,
            achieved_rate: 0.0,
        });
        for &rate in rates {
            for &method in methods {
                let cfg = CompressConfig {
                    method,
                    rate,
                    rank_ratio: paper_kappa(preset),
                    iters: oats_iters(ctx.quick),
                    pattern: SparsityPattern::RowWise,
                    ..Default::default()
                };
                let (cm, _report) = compress_clone(&model, &calib, &cfg, 6)?;
                let label = format!("{}@{rate}", method.name());
                let row =
                    eval::evaluate(&cm, &corpus, &label, ctx.eval_batches(), ctx.eval_probes());
                let achieved = cm.achieved_compression();
                let mut rec = Json::obj();
                rec.set("exp", json::s("grid"))
                    .set("preset", json::s(preset))
                    .set("rate", json::num(rate))
                    .set("method", json::s(method.name()))
                    .set("ppl", json::num(row.ppl))
                    .set("hard", json::num(row.hard))
                    .set("easy", json::num(row.easy))
                    .set("achieved", json::num(achieved));
                ctx.record(&rec);
                out.push(GridResult {
                    preset: preset.into(),
                    rate,
                    method,
                    row,
                    achieved_rate: achieved,
                });
            }
        }
    }
    Ok(out)
}

fn grid_table(
    results: &[GridResult],
    title: &str,
    metric: impl Fn(&EvalRow) -> String,
) -> Table {
    let presets: Vec<String> = {
        let mut seen = Vec::new();
        for r in results {
            if !seen.contains(&r.preset) {
                seen.push(r.preset.clone());
            }
        }
        seen
    };
    let mut headers: Vec<&str> = vec!["Compression", "Method"];
    let preset_cols: Vec<String> = presets.clone();
    for p in &preset_cols {
        headers.push(p);
    }
    let mut t = Table::new(title, &headers);
    // Group rows by (rate, method) in paper order.
    let mut keys: Vec<(u64, Method)> = Vec::new();
    for r in results {
        let key = ((r.rate * 100.0) as u64, r.method);
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    keys.sort_by_key(|&(rate, m)| (rate, method_order(m)));
    for (rate_pct, method) in keys {
        let mut cells = vec![
            format!("{}%", rate_pct),
            method.name().to_string(),
        ];
        for p in &presets {
            let cell = results
                .iter()
                .find(|r| {
                    r.preset == *p
                        && ((r.rate * 100.0) as u64) == rate_pct
                        && r.method == method
                })
                .map(|r| metric(&r.row))
                .unwrap_or_else(|| "-".into());
            cells.push(cell);
        }
        t.row(cells);
    }
    t
}

fn method_order(m: Method) -> usize {
    match m {
        Method::Dense => 0,
        Method::Magnitude => 1,
        Method::SparseGpt => 2,
        Method::Wanda => 3,
        Method::DsNoT => 4,
        Method::Oats => 5,
    }
}

/// Table 2 analogue: hard-suite (MMLU-proxy) accuracy.
pub fn table2(results: &[GridResult]) -> Table {
    grid_table(results, "Table 2 — Hard suite (MMLU proxy) accuracy (%)", |r| pct(r.hard))
}

/// Table 3 analogue: easy-suite (zero-shot proxy) accuracy.
pub fn table3(results: &[GridResult]) -> Table {
    grid_table(results, "Table 3 — Easy suite (zero-shot proxy) accuracy (%)", |r| pct(r.easy))
}

/// Table 4 analogue: held-out perplexity.
pub fn table4(results: &[GridResult]) -> Table {
    grid_table(results, "Table 4 — Held-out perplexity (lower is better)", |r| ppl(r.ppl))
}

/// Table 16 analogue: OATS − Wanda performance gaps.
pub fn table16(results: &[GridResult]) -> Table {
    let mut t = Table::new(
        "Table 16 — OATS improvement over Wanda",
        &["Preset", "Compression", "Hard Δ", "Easy Δ", "PPL Δ"],
    );
    for r in results.iter().filter(|r| r.method == Method::Oats) {
        if let Some(w) = results.iter().find(|w| {
            w.method == Method::Wanda && w.preset == r.preset && w.rate == r.rate
        }) {
            t.row(vec![
                r.preset.clone(),
                format!("{}%", (r.rate * 100.0) as u64),
                format!("{:+.2}", r.row.hard - w.row.hard),
                format!("{:+.2}", r.row.easy - w.row.easy),
                format!("{:+.2}", r.row.ppl - w.row.ppl),
            ]);
        }
    }
    t
}

/// Table 5 analogue: ρ=0.6 with OWL ratios.
pub fn table5(ctx: &mut Ctx, presets: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 — Hard suite (%) at 60% compression with OWL ratios",
        &["Method", "Preset", "Hard", "Easy", "PPL"],
    );
    for &preset in presets {
        let model = ctx.model(preset)?;
        let calib = ctx.calib(preset)?;
        let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
        for method in [Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats] {
            let cfg = CompressConfig {
                method,
                rate: 0.6,
                rank_ratio: paper_kappa(preset),
                iters: oats_iters(ctx.quick),
                owl: true,
                ..Default::default()
            };
            let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
            let (eb, ep) = (ctx.eval_batches(), ctx.eval_probes());
            let row = eval::evaluate(&cm, &corpus, method.name(), eb, ep);
            let mut rec = Json::obj();
            rec.set("exp", json::s("t5_owl60"))
                .set("preset", json::s(preset))
                .set("method", json::s(method.name()))
                .set("hard", json::num(row.hard))
                .set("easy", json::num(row.easy))
                .set("ppl", json::num(row.ppl));
            ctx.record(&rec);
            t.row(vec![
                method.name().into(),
                preset.into(),
                pct(row.hard),
                pct(row.easy),
                ppl(row.ppl),
            ]);
        }
    }
    Ok(t)
}

/// Tables 6 + 11 + 12 + 13 — the ablation suite (paper: Phi-3 Mini, ρ=0.4,
/// κ=0.2 for T6/T12/T13; ρ=0.5 κ=0.25 for T11).
pub fn ablation_tables(ctx: &mut Ctx, preset: &str) -> Result<Vec<Table>> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let iters = oats_iters(ctx.quick);
    let base = CompressConfig {
        method: Method::Oats,
        rate: 0.4,
        rank_ratio: 0.2,
        iters,
        ..Default::default()
    };
    let eval_cfg = |ctx: &mut Ctx, cfg: &CompressConfig, label: &str| -> Result<EvalRow> {
        let (cm, _) = compress_clone(&model, &calib, cfg, 6)?;
        let row = eval::evaluate(&cm, &corpus, label, ctx.eval_batches(), ctx.eval_probes());
        let mut rec = Json::obj();
        rec.set("exp", json::s("ablation"))
            .set("label", json::s(label))
            .set("hard", json::num(row.hard))
            .set("easy", json::num(row.easy))
            .set("ppl", json::num(row.ppl));
        ctx.record(&rec);
        Ok(row)
    };

    // Table 6: scaling × granularity.
    let mut t6 = Table::new(
        "Table 6 — Ablation: D-scaling × threshold granularity (ρ=0.4, κ=0.2)",
        &["Scaling", "Granularity", "Hard", "Easy", "PPL"],
    );
    for (scale, pattern, s_label, p_label) in [
        (false, SparsityPattern::LayerWise, "No Scaling", "Layer-Wise"),
        (false, SparsityPattern::RowWise, "No Scaling", "Row-Wise"),
        (true, SparsityPattern::LayerWise, "Scaling by D", "Layer-Wise"),
        (true, SparsityPattern::RowWise, "Scaling by D", "Row-Wise"),
    ] {
        let cfg = CompressConfig { scale_by_d: scale, pattern, ..base.clone() };
        let row = eval_cfg(ctx, &cfg, &format!("t6:{s_label}/{p_label}"))?;
        t6.row(vec![s_label.into(), p_label.into(), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }

    // Table 11: robust (median) vs second-moment scaling (ρ=0.5, κ=0.25).
    let mut t11 = Table::new(
        "Table 11 — Robust vs second-moment scaling (ρ=0.5, κ=0.25)",
        &["Scaling matrix", "Hard", "Easy", "PPL"],
    );
    for (robust, label) in [(true, "D_robust (median)"), (false, "D (second moment)")] {
        let cfg = CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            robust_scaling: robust,
            ..base.clone()
        };
        let row = eval_cfg(ctx, &cfg, &format!("t11:{label}"))?;
        t11.row(vec![label.into(), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }

    // Table 12: thresholding order.
    let mut t12 = Table::new(
        "Table 12 — Thresholding order (ρ=0.4, κ=0.2)",
        &["First op", "Hard", "Easy", "PPL"],
    );
    for (first, label) in [(true, "Hard-Thresholding"), (false, "SVT (OATS)")] {
        let cfg = CompressConfig { threshold_first: first, ..base.clone() };
        let row = eval_cfg(ctx, &cfg, &format!("t12:{label}"))?;
        t12.row(vec![label.into(), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }

    // Table 13: outlier scaling on low-rank term only.
    let mut t13 = Table::new(
        "Table 13 — Outlier scaling on both terms vs low-rank only (ρ=0.4, κ=0.2)",
        &["Outlier scaling", "Hard", "Easy", "PPL"],
    );
    for (lronly, label) in [(true, "Low-Rank Term Only"), (false, "Both Terms (OATS)")] {
        let cfg = CompressConfig { scale_lowrank_only: lronly, ..base.clone() };
        let row = eval_cfg(ctx, &cfg, &format!("t13:{label}"))?;
        t13.row(vec![label.into(), pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    }

    Ok(vec![t6, t11, t12, t13])
}

/// Table 10 analogue: the largest preset compressed with only N=20 iterations.
pub fn table10(ctx: &mut Ctx, preset: &str) -> Result<Table> {
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let cfg = CompressConfig {
        method: Method::Oats,
        rate: 0.5,
        rank_ratio: 0.3,
        iters: if ctx.quick { 4 } else { 20 },
        ..Default::default()
    };
    let (cm, _) = compress_clone(&model, &calib, &cfg, 6)?;
    let row = eval::evaluate(&cm, &corpus, "OATS@N=20", ctx.eval_batches(), ctx.eval_probes());
    let mut t = Table::new(
        &format!("Table 10 — OATS on '{preset}' with N=20 iterations (ρ=0.5, κ=0.3)"),
        &["Hard", "Easy", "PPL"],
    );
    t.row(vec![pct(row.hard), pct(row.easy), ppl(row.ppl)]);
    Ok(t)
}

/// Table 17 analogue: the alternate architecture (Qwen stand-in).
pub fn table17(ctx: &mut Ctx) -> Result<Table> {
    let results = run_grid(
        ctx,
        &["alt"],
        &[0.3, 0.4, 0.5],
        &[Method::SparseGpt, Method::Wanda, Method::DsNoT, Method::Oats],
    )?;
    let mut t = Table::new(
        "Table 17 — Alternate architecture ('alt' = Qwen-2.5 stand-in)",
        &["Compression", "Method", "Hard", "Easy", "PPL"],
    );
    for r in &results {
        t.row(vec![
            format!("{}%", (r.rate * 100.0) as u64),
            r.method.name().into(),
            pct(r.row.hard),
            pct(r.row.easy),
            ppl(r.row.ppl),
        ]);
    }
    Ok(t)
}

/// Table 20 analogue: DSNoT with each initial mask, reported separately.
pub fn table20(ctx: &mut Ctx, preset: &str) -> Result<Table> {
    use crate::compress::{dsnot, CalibStats};
    let model = ctx.model(preset)?;
    let calib = ctx.calib(preset)?;
    let corpus = crate::data::SyntheticCorpus::new(ctx.corpus(preset)?.cfg.clone());
    let mut t = Table::new(
        "Table 20 — DSNoT initialized from each base method",
        &["Compression", "Init", "Hard", "Easy", "PPL"],
    );
    for rate in [0.3, 0.5] {
        for (init_method, label) in
            [(Method::SparseGpt, "SparseGPT"), (Method::Wanda, "Wanda")]
        {
            // Manual pipeline: init masks from `init_method`, then refine.
            let mut m = model.clone();
            let mut hidden: Vec<crate::tensor::Matrix> =
                calib.batches.iter().map(|b| m.embed(&b.inputs)).collect();
            let bsz: Vec<usize> = calib.batches.iter().map(|b| b.inputs.len()).collect();
            let s = calib.seq_len;
            for b in 0..m.blocks.len() {
                let mut stats: std::collections::HashMap<&'static str, CalibStats> =
                    Default::default();
                for (h, &bs) in hidden.iter().zip(&bsz) {
                    let mut cap = crate::model::ForwardCapture::default();
                    let _ = m.block_forward(b, h, bs, s, Some(&mut cap), None);
                    for name in crate::model::LINEAR_NAMES {
                        let x = &cap.inputs[name];
                        stats
                            .entry(name)
                            .or_insert_with(|| CalibStats::new(x.cols))
                            .update(x, 128);
                    }
                }
                for st in stats.values_mut() {
                    st.finalize();
                }
                for name in crate::model::LINEAR_NAMES {
                    let w = m.blocks[b].linear(name).dense_view();
                    let cfg = CompressConfig { method: init_method, rate, ..Default::default() };
                    let init = crate::compress::compress_layer(&w, &stats[name], &cfg)?.to_dense();
                    let refined = dsnot::refine(&w, &init, &stats[name], cfg.pattern);
                    m.set_linear(
                        crate::model::LinearId { block: b, name },
                        crate::model::LinearOp::Compressed(
                            crate::compress::CompressedLayer::Sparse(
                                crate::sparse::Csr::from_dense(&refined),
                            ),
                        ),
                    );
                }
                for (h, &bs) in hidden.iter_mut().zip(&bsz) {
                    *h = m.block_forward(b, h, bs, s, None, None);
                }
            }
            let row = eval::evaluate(
                &m,
                &corpus,
                &format!("DSNoT w/ {label}"),
                ctx.eval_batches(),
                ctx.eval_probes(),
            );
            t.row(vec![
                format!("{}%", (rate * 100.0) as u64),
                label.into(),
                pct(row.hard),
                pct(row.easy),
                ppl(row.ppl),
            ]);
        }
    }
    Ok(t)
}
