//! Training driver: runs the AOT `train_step` artifact (L2 fwd/bwd + AdamW)
//! from rust through the PJRT runtime, keeping parameters as literals
//! between steps. Produces the trained models every experiment consumes.

use crate::config::ModelConfig;
use crate::data::SyntheticCorpus;
use crate::model::{io, TransformerLM};
use crate::runtime::{self, Engine};
use crate::tensor::Matrix;
use anyhow::{Context, Result};

/// LM trainer state: parameter/optimizer literals in canonical order.
pub struct Trainer {
    pub engine: Engine,
    pub cfg: ModelConfig,
    names: Vec<String>,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    step: i32,
    pub losses: Vec<f32>,
}

impl Trainer {
    /// Initialize from a freshly-initialized rust model (weights transfer
    /// exactly; optimizer state starts at zero).
    pub fn new(engine: Engine, seed: u64) -> Result<Trainer> {
        let cfg = engine.model_config()?;
        let model = TransformerLM::init(&cfg, seed);
        let tensors = io::flatten(&model);
        let names: Vec<String> = tensors.iter().map(|(n, _)| n.clone()).collect();
        let params = runtime::literals_from_tensors(&tensors)?;
        let zeros: Vec<(String, Matrix)> = tensors
            .iter()
            .map(|(n, t)| (n.clone(), Matrix::zeros(t.rows, t.cols)))
            .collect();
        let m = runtime::literals_from_tensors(&zeros)?;
        let v = runtime::literals_from_tensors(&zeros)?;
        Ok(Trainer { engine, cfg, names, params, m, v, step: 0, losses: Vec::new() })
    }

    /// One optimizer step on a token batch. Returns the loss.
    pub fn step(&mut self, inputs: &[Vec<usize>], targets: &[Vec<usize>]) -> Result<f32> {
        let np = self.params.len();
        // Long-lived state is passed by reference — no per-step copies
        // (§Perf iteration 1: see EXPERIMENTS.md).
        let step_lit = runtime::literal_i32(self.step);
        let tok_lit = runtime::literal_from_tokens(inputs)?;
        let tgt_lit = runtime::literal_from_tokens(targets)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * np + 3);
        args.extend(self.params.iter().chain(&self.m).chain(&self.v));
        args.push(&step_lit);
        args.push(&tok_lit);
        args.push(&tgt_lit);

        let outs = self.engine.run("train_step", &args)?;
        anyhow::ensure!(outs.len() == 3 * np + 2, "train_step returned {} outputs", outs.len());
        let mut it = outs.into_iter();
        self.params = (&mut it).take(np).collect();
        self.m = (&mut it).take(np).collect();
        self.v = (&mut it).take(np).collect();
        self.step = runtime::i32_from_literal(&it.next().context("missing step")?)?;
        let loss = runtime::f32_from_literal(&it.next().context("missing loss")?)?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Train for `n_steps` on corpus batches; returns the loss curve.
    pub fn train(&mut self, corpus: &SyntheticCorpus, n_steps: usize) -> Result<Vec<f32>> {
        let batch = self.engine.train_batch()?;
        let seq = self.cfg.seq_len;
        let mut rng = corpus.stream(0x7EA1);
        let mut curve = Vec::with_capacity(n_steps);
        for _ in 0..n_steps {
            let b = corpus.batch(batch, seq, &mut rng);
            curve.push(self.step(&b.inputs, &b.targets)?);
        }
        Ok(curve)
    }

    /// Export the current parameters into a native rust model.
    pub fn to_model(&self) -> Result<TransformerLM> {
        let mut tensors = Vec::with_capacity(self.names.len());
        for (name, lit) in self.names.iter().zip(&self.params) {
            let (rows, cols) = io::param_shape(&self.cfg, name);
            tensors.push((name.clone(), runtime::matrix_from_literal(lit, rows, cols)?));
        }
        io::assemble(&self.cfg, &tensors)
    }
}


/// ViT trainer state: drives the `vit_train_step` artifact.
pub struct VitTrainer {
    pub engine: Engine,
    pub cfg: crate::vit::VitConfig,
    names: Vec<String>,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    step: i32,
    pub losses: Vec<f32>,
}

impl VitTrainer {
    pub fn new(engine: Engine, seed: u64) -> Result<VitTrainer> {
        let vc = engine.manifest.get("vit_config").context("manifest lacks vit_config")?;
        let cfg = crate::vit::VitConfig {
            image_side: vc.req_usize("image_side")?,
            n_classes: vc.req_usize("n_classes")?,
            d_model: vc.req_usize("d_model")?,
            n_heads: vc.req_usize("n_heads")?,
            n_layers: vc.req_usize("n_layers")?,
            d_ff: vc.req_usize("d_ff")?,
        };
        let vit = crate::vit::Vit::init(&cfg, seed);
        let tensors = crate::vit::io::flatten(&vit);
        let names: Vec<String> = tensors.iter().map(|(n, _)| n.clone()).collect();
        let params = runtime::literals_from_tensors(&tensors)?;
        let zeros: Vec<(String, Matrix)> = tensors
            .iter()
            .map(|(n, t)| (n.clone(), Matrix::zeros(t.rows, t.cols)))
            .collect();
        let m = runtime::literals_from_tensors(&zeros)?;
        let v = runtime::literals_from_tensors(&zeros)?;
        Ok(VitTrainer { engine, cfg, names, params, m, v, step: 0, losses: Vec::new() })
    }

    /// One AdamW step on an image batch.
    pub fn step(&mut self, images: &Matrix, labels: &[usize]) -> Result<f32> {
        let np = self.params.len();
        let step_lit = runtime::literal_i32(self.step);
        let img_lit = runtime::literal_from_matrix(images)?;
        let lbl_lit = runtime::literal_from_labels(labels);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * np + 3);
        args.extend(self.params.iter().chain(&self.m).chain(&self.v));
        args.push(&step_lit);
        args.push(&img_lit);
        args.push(&lbl_lit);
        let outs = self.engine.run("vit_train_step", &args)?;
        anyhow::ensure!(outs.len() == 3 * np + 2, "vit_train_step returned {}", outs.len());
        let mut it = outs.into_iter();
        self.params = (&mut it).take(np).collect();
        self.m = (&mut it).take(np).collect();
        self.v = (&mut it).take(np).collect();
        self.step = runtime::i32_from_literal(&it.next().context("step")?)?;
        let loss = runtime::f32_from_literal(&it.next().context("loss")?)?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Train on balanced synthetic image batches.
    pub fn train(&mut self, ds: &crate::data::ImageDataset, n_steps: usize) -> Result<Vec<f32>> {
        let batch = self.engine.train_batch()?;
        let mut rng = ds.stream(0x717);
        let mut curve = Vec::with_capacity(n_steps);
        for _ in 0..n_steps {
            let imgs = ds.batch(batch, &mut rng);
            let (m, labels) = ds.to_matrix(&imgs);
            curve.push(self.step(&m, &labels)?);
        }
        Ok(curve)
    }

    pub fn to_vit(&self) -> Result<crate::vit::Vit> {
        let mut tensors = Vec::with_capacity(self.names.len());
        for (name, lit) in self.names.iter().zip(&self.params) {
            let (rows, cols) = crate::vit::io::param_shape(&self.cfg, name);
            tensors.push((name.clone(), runtime::matrix_from_literal(lit, rows, cols)?));
        }
        crate::vit::io::assemble(&self.cfg, &tensors)
    }
}

/// Train (or reuse a cached) ViT; cached under `models/vit/`.
pub fn ensure_trained_vit(
    artifacts_dir: &std::path::Path,
    models_dir: &std::path::Path,
    preset: &str,
    n_steps: usize,
    ds: &crate::data::ImageDataset,
) -> Result<crate::vit::Vit> {
    let model_dir = models_dir.join("vit");
    if model_dir.join("manifest.json").exists() {
        return crate::vit::io::load(&model_dir);
    }
    let engine = Engine::load(&artifacts_dir.join(preset))?;
    let mut trainer = VitTrainer::new(engine, 0x71E)?;
    let curve = trainer.train(ds, n_steps)?;
    let vit = trainer.to_vit()?;
    crate::vit::io::save(&vit, &model_dir)?;
    let curve_json = crate::json::Json::Arr(
        curve.iter().map(|&l| crate::json::num(l as f64)).collect(),
    );
    std::fs::write(model_dir.join("loss_curve.json"), curve_json.to_pretty())?;
    Ok(vit)
}

/// Train (or reuse a cached) model for a preset; the standard entry used by
/// the experiment harnesses. Models are cached under `models/<preset>/`.
pub fn ensure_trained_model(
    artifacts_dir: &std::path::Path,
    models_dir: &std::path::Path,
    preset: &str,
    n_steps: usize,
    corpus: &SyntheticCorpus,
) -> Result<TransformerLM> {
    let model_dir = models_dir.join(preset);
    if model_dir.join("manifest.json").exists() {
        return io::load(&model_dir);
    }
    let engine = Engine::load(&artifacts_dir.join(preset))?;
    let mut trainer = Trainer::new(engine, 0x5EED0 + preset.len() as u64)?;
    let curve = trainer.train(corpus, n_steps)?;
    let model = trainer.to_model()?;
    io::save(&model, &model_dir)?;
    // Persist the loss curve alongside the weights (E2E evidence).
    let curve_json = crate::json::Json::Arr(
        curve.iter().map(|&l| crate::json::num(l as f64)).collect(),
    );
    std::fs::write(model_dir.join("loss_curve.json"), curve_json.to_pretty())?;
    Ok(model)
}
