//! Attention rollout (Abnar & Zuidema 2020) with the paper's Section 5
//! protocol: average attention over heads, mix with the residual identity,
//! multiply across blocks, read off the CLS row, and discard the bottom 40%
//! of attention pixels (Appendix A.11).

use crate::tensor::Matrix;
use crate::vit::{Component, Vit};

/// Fraction of lowest-attention pixels zeroed in the final map (A.11).
pub const DISCARD_FRACTION: f64 = 0.4;

/// Rollout R = ∏_ℓ norm(0.5·A_ℓ + 0.5·I); returns the CLS-row attention over
/// patch tokens (length n_patches).
pub fn rollout_from_maps(maps: &[Matrix]) -> Vec<f32> {
    assert!(!maps.is_empty());
    let t = maps[0].rows;
    let mut r = Matrix::eye(t);
    for a in maps {
        // 0.5 A + 0.5 I, row-renormalized.
        let mut m = a.clone();
        m.scale(0.5);
        for i in 0..t {
            *m.at_mut(i, i) += 0.5;
            let s: f32 = m.row(i).iter().sum();
            let inv = 1.0 / s.max(1e-12);
            for v in m.row_mut(i) {
                *v *= inv;
            }
        }
        r = crate::tensor::matmul(&m, &r);
    }
    // CLS row, skipping the CLS column itself.
    r.row(0)[1..].to_vec()
}

/// Zero the bottom `DISCARD_FRACTION` of entries (A.11's visualization step).
pub fn discard_low(mut heat: Vec<f32>) -> Vec<f32> {
    let n = heat.len();
    let cut = ((n as f64) * DISCARD_FRACTION) as usize;
    if cut == 0 {
        return heat;
    }
    let mut sorted: Vec<f32> = heat.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let thresh = sorted[cut - 1];
    for v in &mut heat {
        if *v <= thresh {
            *v = 0.0;
        }
    }
    heat
}

/// Full Section-5 analysis for one image: rollouts through the complete
/// model, the sparse-only path, and the low-rank-only path.
pub struct RolloutSplit {
    pub both: Vec<f32>,
    pub sparse: Vec<f32>,
    pub low_rank: Vec<f32>,
    /// patches per image side.
    pub side: usize,
}

pub fn rollout_split(vit: &Vit, pixels: &[f32]) -> RolloutSplit {
    let run = |comp: Component| -> Vec<f32> {
        discard_low(rollout_from_maps(&vit.attention_maps(pixels, comp)))
    };
    RolloutSplit {
        both: run(Component::Both),
        sparse: run(Component::SparseOnly),
        low_rank: run(Component::LowRankOnly),
        side: vit.cfg.image_side / super::PATCH,
    }
}

/// Cosine similarity between two heatmaps — used to quantify the paper's
/// claim that S and L attend to *different* regions.
pub fn heatmap_cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// ASCII rendering of a patch heatmap (for terminal reports and
/// EXPERIMENTS.md evidence).
pub fn ascii_heatmap(heat: &[f32], side: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let max = heat.iter().cloned().fold(0f32, f32::max).max(1e-12);
    let mut out = String::new();
    for y in 0..side {
        for x in 0..side {
            let v = heat[y * side + x] / max;
            let idx = ((v * (RAMP.len() - 1) as f32).round() as usize).min(RAMP.len() - 1);
            out.push(RAMP[idx] as char);
            out.push(RAMP[idx] as char); // double-width for aspect ratio
        }
        out.push('\n');
    }
    out
}

/// Write a binary PGM image of the heatmap (viewable evidence artifact).
pub fn write_pgm(heat: &[f32], side: usize, path: &std::path::Path) -> std::io::Result<()> {
    let max = heat.iter().cloned().fold(0f32, f32::max).max(1e-12);
    let mut buf = format!("P5\n{side} {side}\n255\n").into_bytes();
    for &v in heat {
        buf.push(((v / max) * 255.0) as u8);
    }
    std::fs::write(path, buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::VitConfig;

    #[test]
    fn rollout_of_identity_attention_is_uniformish() {
        // If every attention map is uniform, rollout CLS row is uniform.
        let t = 5;
        let uniform = Matrix::filled(t, t, 1.0 / t as f32);
        let heat = rollout_from_maps(&[uniform.clone(), uniform]);
        assert_eq!(heat.len(), t - 1);
        for w in heat.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-5);
        }
    }

    #[test]
    fn rollout_follows_strong_attention() {
        // CLS attends only to token 2 in both layers ⇒ heat concentrates at
        // patch index 1 (token 2).
        let t = 4;
        let mut a = Matrix::zeros(t, t);
        for i in 0..t {
            *a.at_mut(i, i) = 1.0;
        }
        *a.at_mut(0, 0) = 0.0;
        *a.at_mut(0, 2) = 1.0;
        let heat = rollout_from_maps(&[a.clone(), a]);
        let best = crate::tensor::argmax(&heat);
        assert_eq!(best, 1, "heat={heat:?}");
    }

    #[test]
    fn discard_low_zeroes_fraction() {
        let heat: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let out = discard_low(heat);
        let zeros = out.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 4);
        assert!(out[9] > 0.0);
    }

    #[test]
    fn discard_low_tolerates_nan_heat() {
        // Regression: the threshold sort unwrapped `partial_cmp` and a NaN
        // heat value (degenerate rollout on an all-masked image) panicked
        // the visualization. `total_cmp` sorts NaN above every finite heat,
        // keeping the cut threshold finite.
        let mut heat: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        heat[3] = f32::NAN;
        let out = discard_low(heat);
        assert_eq!(out.len(), 10);
        let zeros = out.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 4, "cut fraction unchanged by the NaN entry");
    }

    #[test]
    fn cosine_props() {
        let a = vec![1.0, 0.0, 1.0];
        assert!((heatmap_cosine(&a, &a) - 1.0).abs() < 1e-9);
        assert_eq!(heatmap_cosine(&a, &[0.0, 1.0, 0.0]), 0.0);
    }

    #[test]
    fn ascii_heatmap_renders() {
        let s = ascii_heatmap(&[0.0, 0.5, 0.9, 1.0], 2);
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains('@'));
    }

    #[test]
    fn split_runs_on_uncompressed_model() {
        // On a dense model SparseOnly == Both == LowRankOnly (no SPL layers),
        // so the cosines are 1.
        let vit = Vit::init(&VitConfig::small(16, 8), 1);
        let ds = crate::data::images::ImageDataset::new(Default::default());
        let img = ds.render(0, &mut ds.stream(0));
        let split = rollout_split(&vit, &img.pixels);
        assert!((heatmap_cosine(&split.both, &split.sparse) - 1.0).abs() < 1e-5);
        assert!((heatmap_cosine(&split.both, &split.low_rank) - 1.0).abs() < 1e-5);
        assert_eq!(split.both.len(), 16);
    }
}
