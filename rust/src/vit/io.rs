//! ViT weight persistence and flattening (canonical order matching
//! `python/compile/model.py::vit_param_names`).

use super::{Vit, VitConfig};
use crate::json::{self, Json};
use crate::model::io::{load_tensors, save_tensors};
use crate::model::{Block, LinearOp};
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::path::Path;

pub fn param_names(n_layers: usize) -> Vec<String> {
    let mut names = vec!["patch_proj".to_string(), "cls".to_string(), "pos_emb".to_string()];
    for b in 0..n_layers {
        for t in ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w_up", "w_down"] {
            names.push(format!("block{b}.{t}"));
        }
    }
    names.push("lnf_g".into());
    names.push("lnf_b".into());
    names.push("head".into());
    names
}

pub fn param_shape(cfg: &VitConfig, name: &str) -> (usize, usize) {
    let d = cfg.d_model;
    match name {
        "patch_proj" => (d, cfg.patch_dim()),
        "cls" => (1, d),
        "pos_emb" => (cfg.n_tokens(), d),
        "lnf_g" | "lnf_b" => (1, d),
        "head" => (cfg.n_classes, d),
        _ => {
            let t = name.split('.').nth(1).expect("block param");
            match t {
                "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" => (1, d),
                "wq" | "wk" | "wv" | "wo" => (d, d),
                "w_up" => (cfg.d_ff, d),
                "w_down" => (d, cfg.d_ff),
                other => panic!("unknown block param '{other}'"),
            }
        }
    }
}

pub fn flatten(vit: &Vit) -> Vec<(String, Matrix)> {
    let vecm = |v: &Vec<f32>| Matrix::from_vec(1, v.len(), v.clone());
    let mut out = vec![
        ("patch_proj".to_string(), vit.patch_proj.clone()),
        ("cls".to_string(), vecm(&vit.cls_token)),
        ("pos_emb".to_string(), vit.pos_emb.clone()),
    ];
    for (b, blk) in vit.blocks.iter().enumerate() {
        out.push((format!("block{b}.ln1_g"), vecm(&blk.ln1_g)));
        out.push((format!("block{b}.ln1_b"), vecm(&blk.ln1_b)));
        out.push((format!("block{b}.wq"), blk.q.dense_view()));
        out.push((format!("block{b}.wk"), blk.k.dense_view()));
        out.push((format!("block{b}.wv"), blk.v.dense_view()));
        out.push((format!("block{b}.wo"), blk.o.dense_view()));
        out.push((format!("block{b}.ln2_g"), vecm(&blk.ln2_g)));
        out.push((format!("block{b}.ln2_b"), vecm(&blk.ln2_b)));
        out.push((format!("block{b}.w_up"), blk.up.dense_view()));
        out.push((format!("block{b}.w_down"), blk.down.dense_view()));
    }
    out.push(("lnf_g".to_string(), vecm(&vit.lnf_g)));
    out.push(("lnf_b".to_string(), vecm(&vit.lnf_b)));
    out.push(("head".to_string(), vit.head.clone()));
    out
}

pub fn assemble(cfg: &VitConfig, tensors: &[(String, Matrix)]) -> Result<Vit> {
    let get = |name: &str| -> Result<&Matrix> {
        tensors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m)
            .with_context(|| format!("missing tensor '{name}'"))
    };
    let vec_of = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.data.clone()) };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for b in 0..cfg.n_layers {
        blocks.push(Block {
            ln1_g: vec_of(&format!("block{b}.ln1_g"))?,
            ln1_b: vec_of(&format!("block{b}.ln1_b"))?,
            ln2_g: vec_of(&format!("block{b}.ln2_g"))?,
            ln2_b: vec_of(&format!("block{b}.ln2_b"))?,
            q: LinearOp::Dense(get(&format!("block{b}.wq"))?.clone()),
            k: LinearOp::Dense(get(&format!("block{b}.wk"))?.clone()),
            v: LinearOp::Dense(get(&format!("block{b}.wv"))?.clone()),
            o: LinearOp::Dense(get(&format!("block{b}.wo"))?.clone()),
            up: LinearOp::Dense(get(&format!("block{b}.w_up"))?.clone()),
            down: LinearOp::Dense(get(&format!("block{b}.w_down"))?.clone()),
        });
    }
    Ok(Vit {
        cfg: cfg.clone(),
        patch_proj: get("patch_proj")?.clone(),
        cls_token: vec_of("cls")?,
        pos_emb: get("pos_emb")?.clone(),
        blocks,
        lnf_g: vec_of("lnf_g")?,
        lnf_b: vec_of("lnf_b")?,
        head: get("head")?.clone(),
    })
}

fn config_json(cfg: &VitConfig) -> Json {
    let mut o = Json::obj();
    o.set("image_side", json::num(cfg.image_side as f64))
        .set("n_classes", json::num(cfg.n_classes as f64))
        .set("d_model", json::num(cfg.d_model as f64))
        .set("n_heads", json::num(cfg.n_heads as f64))
        .set("n_layers", json::num(cfg.n_layers as f64))
        .set("d_ff", json::num(cfg.d_ff as f64));
    o
}

fn config_from_json(v: &Json) -> Result<VitConfig> {
    Ok(VitConfig {
        image_side: v.req_usize("image_side")?,
        n_classes: v.req_usize("n_classes")?,
        d_model: v.req_usize("d_model")?,
        n_heads: v.req_usize("n_heads")?,
        n_layers: v.req_usize("n_layers")?,
        d_ff: v.req_usize("d_ff")?,
    })
}

pub fn save(vit: &Vit, dir: &Path) -> Result<()> {
    save_tensors(dir, config_json(&vit.cfg), &flatten(vit))
}

pub fn load(dir: &Path) -> Result<Vit> {
    let (config, tensors) = load_tensors(dir)?;
    let cfg = config_from_json(&config)?;
    assemble(&cfg, &tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = VitConfig::small(16, 8);
        let v = Vit::init(&cfg, 9);
        let dir = std::env::temp_dir().join(format!("oats_vit_io_{}", std::process::id()));
        save(&v, &dir).unwrap();
        let v2 = load(&dir).unwrap();
        let img: Vec<f32> = (0..256).map(|i| (i % 7) as f32 * 0.1).collect();
        let a = v.forward(&[&img], crate::vit::Component::Both);
        let b = v2.forward(&[&img], crate::vit::Component::Both);
        assert!(a.fro_dist(&b) < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn names_match_flatten() {
        let cfg = VitConfig::small(16, 8);
        let v = Vit::init(&cfg, 1);
        let names = param_names(cfg.n_layers);
        let tensors = flatten(&v);
        assert_eq!(names.len(), tensors.len());
        for (n, (tn, t)) in names.iter().zip(&tensors) {
            assert_eq!(n, tn);
            assert_eq!((t.rows, t.cols), param_shape(&cfg, n), "{n}");
        }
    }
}
