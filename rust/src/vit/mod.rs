//! Vision transformer (ViT) for the image experiments (Table 8) and the
//! sparse-vs-low-rank attention-rollout analysis (Section 5, Figures 3–4).
//!
//! Architecture: 4×4 patch embedding, CLS token, pre-LN encoder blocks with
//! bidirectional attention, classification head on the CLS output. The six
//! per-block linears reuse [`LinearOp`], so the whole compression stack
//! (OATS + baselines + pipeline) applies unchanged.

pub mod io;
pub mod rollout;

use crate::compress::CompressedLayer;
use crate::config::ModelConfig;
use crate::model::{Block, LinearOp, LINEAR_NAMES};
use crate::tensor::{self, Matrix};
use crate::util::prng::Rng;

pub const PATCH: usize = 4;

/// ViT configuration is a [`ModelConfig`] reinterpretation: `seq_len` =
/// number of patches + 1 (CLS), `vocab` = number of classes.
#[derive(Clone, Debug)]
pub struct VitConfig {
    pub image_side: usize,
    pub n_classes: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
}

impl VitConfig {
    pub fn small(image_side: usize, n_classes: usize) -> VitConfig {
        VitConfig { image_side, n_classes, d_model: 64, n_heads: 4, n_layers: 3, d_ff: 256 }
    }

    pub fn n_patches(&self) -> usize {
        (self.image_side / PATCH) * (self.image_side / PATCH)
    }

    /// Tokens = patches + CLS.
    pub fn n_tokens(&self) -> usize {
        self.n_patches() + 1
    }

    pub fn patch_dim(&self) -> usize {
        PATCH * PATCH
    }

    /// The equivalent ModelConfig (for shared utilities/accounting).
    pub fn as_model_config(&self) -> ModelConfig {
        ModelConfig {
            name: "vit".into(),
            vocab: self.n_classes,
            d_model: self.d_model,
            n_heads: self.n_heads,
            n_layers: self.n_layers,
            d_ff: self.d_ff,
            seq_len: self.n_tokens(),
        }
    }
}

/// Which decomposition component a compressed forward uses (Section 5's
/// split analysis; `Both` is normal inference).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Component {
    Both,
    SparseOnly,
    LowRankOnly,
}

#[derive(Clone, Debug)]
pub struct Vit {
    pub cfg: VitConfig,
    /// patch projection: d_model × patch_dim
    pub patch_proj: Matrix,
    pub cls_token: Vec<f32>,
    pub pos_emb: Matrix, // n_tokens × d
    pub blocks: Vec<Block>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    /// classifier: n_classes × d
    pub head: Matrix,
}

const LN_EPS: f32 = 1e-5;

impl Vit {
    pub fn init(cfg: &VitConfig, seed: u64) -> Vit {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let resid_std = 0.02 / ((2 * cfg.n_layers) as f32).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                q: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                k: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                v: LinearOp::Dense(Matrix::randn(d, d, 0.02, &mut rng)),
                o: LinearOp::Dense(Matrix::randn(d, d, resid_std, &mut rng)),
                up: LinearOp::Dense(Matrix::randn(cfg.d_ff, d, 0.02, &mut rng)),
                down: LinearOp::Dense(Matrix::randn(d, cfg.d_ff, resid_std, &mut rng)),
            })
            .collect();
        Vit {
            cfg: cfg.clone(),
            patch_proj: Matrix::randn(d, cfg.patch_dim(), 0.05, &mut rng),
            cls_token: {
                let mut v = vec![0.0; d];
                rng.fill_normal(&mut v, 0.02);
                v
            },
            pos_emb: Matrix::randn(cfg.n_tokens(), d, 0.01, &mut rng),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            head: Matrix::randn(cfg.n_classes, d, 0.02, &mut rng),
        }
    }

    /// Patchify one image (row-major side×side) → [n_patches × patch_dim].
    pub fn patchify(&self, pixels: &[f32]) -> Matrix {
        let side = self.cfg.image_side;
        assert_eq!(pixels.len(), side * side);
        let pe = side / PATCH;
        let mut m = Matrix::zeros(pe * pe, PATCH * PATCH);
        for py in 0..pe {
            for px in 0..pe {
                let row = m.row_mut(py * pe + px);
                for y in 0..PATCH {
                    for x in 0..PATCH {
                        row[y * PATCH + x] = pixels[(py * PATCH + y) * side + px * PATCH + x];
                    }
                }
            }
        }
        m
    }

    /// Embed a batch of images → hidden [B·T × d], T = n_tokens.
    pub fn embed(&self, images: &[&[f32]]) -> Matrix {
        let t = self.cfg.n_tokens();
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(images.len() * t, d);
        for (b, px) in images.iter().enumerate() {
            let patches = self.patchify(px);
            let proj = tensor::matmul_bt(&patches, &self.patch_proj); // [P × d]
            // CLS at position 0
            let cls_emb = self.cls_token.iter().zip(self.pos_emb.row(0));
            let cls_row = h.row_mut(b * t);
            for (o, (&c, &p)) in cls_row.iter_mut().zip(cls_emb) {
                *o = c + p;
            }
            for p in 0..patches.rows {
                let row = h.row_mut(b * t + 1 + p);
                for (o, (&v, &pe)) in
                    row.iter_mut().zip(proj.row(p).iter().zip(self.pos_emb.row(1 + p)))
                {
                    *o = v + pe;
                }
            }
        }
        h
    }

    fn linear_fwd(&self, op: &LinearOp, x: &Matrix, comp: Component) -> Matrix {
        match (op, comp) {
            (LinearOp::Compressed(CompressedLayer::Spl(spl)), Component::SparseOnly) => {
                spl.sparse.matmul_xt(x)
            }
            (LinearOp::Compressed(CompressedLayer::Spl(spl)), Component::LowRankOnly) => {
                let mut out = Matrix::zeros(x.rows, spl.sparse.rows);
                if let Some(lr) = &spl.low_rank {
                    lr.apply_batch_accumulate(x, &mut out);
                }
                out
            }
            _ => op.forward(x),
        }
    }

    /// One encoder block (bidirectional attention). Optionally records the
    /// head-averaged attention matrix per image.
    pub fn block_forward(
        &self,
        block_idx: usize,
        h: &Matrix,
        bsz: usize,
        comp: Component,
        mut attn_store: Option<&mut Vec<Matrix>>,
        mut capture: Option<&mut crate::model::ForwardCapture>,
    ) -> Matrix {
        let blk = &self.blocks[block_idx];
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let hd = d / nh;
        let t = self.cfg.n_tokens();
        let scale = 1.0 / (hd as f32).sqrt();

        let mut x = h.clone();
        tensor::layernorm_rows(&mut x, &blk.ln1_g, &blk.ln1_b, LN_EPS);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("q", x.clone());
            c.inputs.insert("k", x.clone());
            c.inputs.insert("v", x.clone());
        }
        let q = self.linear_fwd(&blk.q, &x, comp);
        let k = self.linear_fwd(&blk.k, &x, comp);
        let v = self.linear_fwd(&blk.v, &x, comp);
        let mut ctx = Matrix::zeros(h.rows, d);
        for b in 0..bsz {
            let base = b * t;
            let mut mean_probs = if attn_store.is_some() {
                Some(Matrix::zeros(t, t))
            } else {
                None
            };
            for head in 0..nh {
                let off = head * hd;
                for i in 0..t {
                    let qrow = &q.row(base + i)[off..off + hd];
                    let mut scores = vec![0.0f32; t];
                    for (j, sc) in scores.iter_mut().enumerate() {
                        *sc = tensor::dot(qrow, &k.row(base + j)[off..off + hd]) * scale;
                    }
                    tensor::softmax_inplace(&mut scores);
                    let crow = &mut ctx.row_mut(base + i)[off..off + hd];
                    for (j, &p) in scores.iter().enumerate() {
                        let vrow = &v.row(base + j)[off..off + hd];
                        for (cv, &vv) in crow.iter_mut().zip(vrow) {
                            *cv += p * vv;
                        }
                    }
                    if let Some(pm) = mean_probs.as_mut() {
                        for (j, &p) in scores.iter().enumerate() {
                            *pm.at_mut(i, j) += p / nh as f32;
                        }
                    }
                }
            }
            if let (Some(pm), Some(store)) = (mean_probs, attn_store.as_deref_mut()) {
                store.push(pm);
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("o", ctx.clone());
        }
        let attn = self.linear_fwd(&blk.o, &ctx, comp);
        let mut h2 = h.clone();
        h2.axpy(1.0, &attn);

        let mut x2 = h2.clone();
        tensor::layernorm_rows(&mut x2, &blk.ln2_g, &blk.ln2_b, LN_EPS);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("up", x2.clone());
        }
        let mut u = self.linear_fwd(&blk.up, &x2, comp);
        tensor::gelu_inplace(&mut u.data);
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("down", u.clone());
        }
        let mlp = self.linear_fwd(&blk.down, &u, comp);
        h2.axpy(1.0, &mlp);
        h2
    }

    /// Class logits for a batch of images.
    pub fn forward(&self, images: &[&[f32]], comp: Component) -> Matrix {
        let t = self.cfg.n_tokens();
        let mut h = self.embed(images);
        for i in 0..self.blocks.len() {
            h = self.block_forward(i, &h, images.len(), comp, None, None);
        }
        // CLS rows → final LN → head
        let mut cls = Matrix::zeros(images.len(), self.cfg.d_model);
        for b in 0..images.len() {
            cls.row_mut(b).copy_from_slice(h.row(b * t));
        }
        tensor::layernorm_rows(&mut cls, &self.lnf_g, &self.lnf_b, LN_EPS);
        tensor::matmul_bt(&cls, &self.head)
    }

    /// Top-1 accuracy on labelled images.
    pub fn accuracy(&self, images: &[crate::data::images::Image], comp: Component) -> f64 {
        let mut correct = 0usize;
        for chunk in images.chunks(16) {
            let refs: Vec<&[f32]> = chunk.iter().map(|i| i.pixels.as_slice()).collect();
            let logits = self.forward(&refs, comp);
            for (b, img) in chunk.iter().enumerate() {
                if tensor::argmax(logits.row(b)) == img.label {
                    correct += 1;
                }
            }
        }
        correct as f64 / images.len() as f64
    }

    /// Attention matrices (head-averaged) for one image, per block.
    pub fn attention_maps(&self, pixels: &[f32], comp: Component) -> Vec<Matrix> {
        let mut h = self.embed(&[pixels]);
        let mut maps = Vec::with_capacity(self.blocks.len());
        for i in 0..self.blocks.len() {
            let mut store = Vec::new();
            h = self.block_forward(i, &h, 1, comp, Some(&mut store), None);
            maps.push(store.pop().expect("attention recorded"));
        }
        maps
    }

    /// All prunable linear ids (same naming as the LM).
    pub fn linear_ids(&self) -> Vec<crate::model::LinearId> {
        (0..self.blocks.len())
            .flat_map(|b| {
                LINEAR_NAMES.iter().map(move |&n| crate::model::LinearId { block: b, name: n })
            })
            .collect()
    }

    pub fn set_linear(&mut self, id: crate::model::LinearId, op: LinearOp) {
        *self.blocks[id.block].linear_mut(id.name) = op;
    }

    pub fn achieved_compression(&self) -> f64 {
        let dense: usize = self.cfg.as_model_config().prunable_params();
        let now: usize = self
            .blocks
            .iter()
            .flat_map(|b| LINEAR_NAMES.iter().map(move |&n| b.linear(n).param_count()))
            .sum();
        1.0 - now as f64 / dense as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::{ImageDataset, ImagesConfig};

    fn tiny_vit() -> Vit {
        Vit::init(&VitConfig::small(16, 8), 3)
    }

    #[test]
    fn forward_shapes() {
        let v = tiny_vit();
        let ds = ImageDataset::new(ImagesConfig::default());
        let imgs = ds.batch(4, &mut ds.stream(0));
        let refs: Vec<&[f32]> = imgs.iter().map(|i| i.pixels.as_slice()).collect();
        let logits = v.forward(&refs, Component::Both);
        assert_eq!((logits.rows, logits.cols), (4, 8));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn patchify_layout() {
        let v = tiny_vit();
        // pixel value = row-major index; check patch (0,0) picks the corner.
        let px: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let p = v.patchify(&px);
        assert_eq!(p.rows, 16);
        assert_eq!(p.at(0, 0), 0.0);
        assert_eq!(p.at(0, 1), 1.0);
        assert_eq!(p.at(0, 4), 16.0); // second row of the patch
        assert_eq!(p.at(1, 0), 4.0); // next patch to the right
    }

    #[test]
    fn attention_maps_are_stochastic_matrices() {
        let v = tiny_vit();
        let ds = ImageDataset::new(ImagesConfig::default());
        let img = ds.render(2, &mut ds.stream(1));
        let maps = v.attention_maps(&img.pixels, Component::Both);
        assert_eq!(maps.len(), v.cfg.n_layers);
        for m in &maps {
            assert_eq!(m.rows, v.cfg.n_tokens());
            for r in 0..m.rows {
                let s: f32 = m.row(r).iter().sum();
                assert!((s - 1.0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn component_split_differs_after_compression() {
        use crate::compress::{compress_layer, CalibStats};
        use crate::config::CompressConfig;
        let mut v = tiny_vit();
        let ds = ImageDataset::new(ImagesConfig::default());
        let imgs = ds.batch(8, &mut ds.stream(2));
        let refs: Vec<&[f32]> = imgs.iter().map(|i| i.pixels.as_slice()).collect();
        // Compress every layer with OATS (stats from real block inputs).
        let cfg = CompressConfig { rate: 0.5, rank_ratio: 0.3, iters: 3, ..Default::default() };
        let mut h = v.embed(&refs);
        for b in 0..v.blocks.len() {
            let mut cap = crate::model::ForwardCapture::default();
            let _ = v.block_forward(b, &h, refs.len(), Component::Both, None, Some(&mut cap));
            for name in LINEAR_NAMES {
                let w = v.blocks[b].linear(name).dense_view();
                let stats = CalibStats::from_activations(&cap.inputs[name]);
                let c = compress_layer(&w, &stats, &cfg).unwrap();
                v.set_linear(crate::model::LinearId { block: b, name }, LinearOp::Compressed(c));
            }
            h = v.block_forward(b, &h, refs.len(), Component::Both, None, None);
        }
        assert!(v.achieved_compression() > 0.4);
        let both = v.forward(&refs, Component::Both);
        let sp = v.forward(&refs, Component::SparseOnly);
        let lr = v.forward(&refs, Component::LowRankOnly);
        assert!(both.fro_dist(&sp) > 1e-3);
        assert!(both.fro_dist(&lr) > 1e-3);
        assert!(sp.fro_dist(&lr) > 1e-3);
    }

    #[test]
    fn accuracy_bounds() {
        let v = tiny_vit();
        let ds = ImageDataset::new(ImagesConfig::default());
        let imgs = ds.batch(16, &mut ds.stream(3));
        let acc = v.accuracy(&imgs, Component::Both);
        assert!((0.0..=1.0).contains(&acc));
    }
}
