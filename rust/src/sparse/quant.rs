//! i8-quantized BCSR tiles: the first compression axis where the dispatch
//! layer arbitrates an accuracy/speed trade-off instead of a pure layout
//! choice.
//!
//! Each f32 BCSR tile is quantized **symmetrically at pack time**: one f32
//! scale per tile (`scale = max|w| / 127`), values stored as `i8`
//! (`w ≈ scale · q`). The on-disk checkpoint format is untouched —
//! quantization happens when a layer is packed for serving, and
//! dequantization recovers f32 values for re-serialization.
//!
//! The batched kernel shares the [`super::microkernel`] tile-walk engine
//! with the f32 tiles: Xᵀ panels, the register-blocked lane fold (the
//! `i8 → f32` widening and the multiply-add both run over a contiguous
//! batch lane), and row tiles parallelized across threads. The per-tile
//! scale is applied **once per tile** per output row: the raw `Σ q·x`
//! partial accumulates unscaled in the lane registers and one scaled fold
//! moves it into the row accumulator, so the hot loop never touches the
//! scale.
//!
//! Accuracy is gated at plan time: [`QBcsr::max_tile_rel_error`] reports the
//! worst per-tile relative Frobenius quantization error, and
//! [`crate::sparse::KernelPlan::choose`] falls back to f32 BCSR when it
//! exceeds the configured bound (outlier-dominated tiles quantize badly —
//! exactly the regime OATS targets — so the gate matters in practice).

use super::bcsr::Bcsr;
use super::csr::Csr;
use super::lowrank::LowRank;
use super::microkernel::{self, I8TileRun, Isa, TileWalk};
use crate::tensor::Matrix;

/// One quantized tile: a local CSR with i8 values and a single f32 scale.
#[derive(Clone, Debug, PartialEq)]
struct QTile {
    /// len = rows-in-tile + 1, offsets into `cols`/`values`.
    indptr: Vec<u32>,
    /// Column offsets relative to the tile's first column.
    cols: Vec<u16>,
    /// Symmetrically quantized values in [-127, 127].
    values: Vec<i8>,
    /// Dequantization scale: `w ≈ scale · q`. Zero for all-zero tiles.
    scale: f32,
}

/// Block-compressed-sparse-row matrix with i8 tile values and per-tile f32
/// scales, produced by quantizing a packed [`Bcsr`].
#[derive(Clone, Debug, PartialEq)]
pub struct QBcsr {
    pub rows: usize,
    pub cols: usize,
    pub row_tile: usize,
    pub col_tile: usize,
    /// Tiles in row-tile-major order: `tiles[rt * n_col_tiles + ct]`.
    tiles: Vec<QTile>,
    nnz: usize,
    /// Worst per-tile relative Frobenius quantization error, measured at
    /// pack time (the plan gate's input).
    max_tile_rel_error: f64,
}

impl QBcsr {
    /// Quantize a packed f32 BCSR matrix, tile by tile, measuring the
    /// per-tile relative error as it goes.
    pub fn quantize(b: &Bcsr) -> QBcsr {
        let mut tiles = Vec::with_capacity(b.tiles().len());
        let mut max_rel = 0.0f64;
        for t in b.tiles() {
            let max_abs = t.values.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            let mut values = Vec::with_capacity(t.values.len());
            let mut err2 = 0.0f64;
            let mut norm2 = 0.0f64;
            for &v in &t.values {
                let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
                let dq = q as f32 * scale;
                err2 += f64::from(v - dq) * f64::from(v - dq);
                norm2 += f64::from(v) * f64::from(v);
                values.push(q);
            }
            if norm2 > 0.0 {
                max_rel = max_rel.max((err2 / norm2).sqrt());
            }
            tiles.push(QTile { indptr: t.indptr.clone(), cols: t.cols.clone(), values, scale });
        }
        QBcsr {
            rows: b.rows,
            cols: b.cols,
            row_tile: b.row_tile,
            col_tile: b.col_tile,
            tiles,
            nnz: b.nnz(),
            max_tile_rel_error: max_rel,
        }
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Worst per-tile relative Frobenius quantization error
    /// `‖w − scale·q‖_F / ‖w‖_F`, measured at pack time.
    pub fn max_tile_rel_error(&self) -> f64 {
        self.max_tile_rel_error
    }

    /// In-memory footprint (indptr + u16 column offsets + i8 values + one
    /// f32 scale per tile) — compare against [`Bcsr::memory_bytes`].
    pub fn memory_bytes(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| 4 * t.indptr.len() + 2 * t.cols.len() + t.values.len() + 4)
            .sum()
    }

    fn n_col_tiles(&self) -> usize {
        self.cols.div_ceil(self.col_tile).max(1)
    }

    fn n_row_tiles(&self) -> usize {
        self.rows.div_ceil(self.row_tile).max(1)
    }

    /// Dense dequantized reconstruction.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let n_ct = self.n_col_tiles();
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            for ct in 0..n_ct {
                let c0 = ct * self.col_tile;
                let tile = &self.tiles[rt * n_ct + ct];
                for (lr, r) in (r0..r1).enumerate() {
                    for i in tile.indptr[lr] as usize..tile.indptr[lr + 1] as usize {
                        let v = tile.values[i] as f32 * tile.scale;
                        m.data[r * self.cols + c0 + tile.cols[i] as usize] = v;
                    }
                }
            }
        }
        m
    }

    /// Dequantized portable CSR view (re-serialization path — the on-disk
    /// format never stores i8). Structure matches the source BCSR exactly;
    /// values carry the quantization round-off.
    ///
    /// Note: nonzeros whose i8 value rounded to 0 are kept as explicit 0.0
    /// entries so the sparsity structure (and `nnz` accounting) is
    /// preserved through a save/load round-trip.
    pub fn to_csr(&self) -> Csr {
        let n_ct = self.n_col_tiles();
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        indptr.push(0u32);
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            for lr in 0..(r1 - r0) {
                for ct in 0..n_ct {
                    let c0 = (ct * self.col_tile) as u32;
                    let tile = &self.tiles[rt * n_ct + ct];
                    let lo = tile.indptr[lr] as usize;
                    let hi = tile.indptr[lr + 1] as usize;
                    for i in lo..hi {
                        indices.push(c0 + tile.cols[i] as u32);
                        values.push(tile.values[i] as f32 * tile.scale);
                    }
                }
                indptr.push(indices.len() as u32);
            }
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// y = A·x — scalar per-row kernel for the single-token decode path.
    /// The raw `Σ q·x` partial per (row, tile) is scaled once on fold-in.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let n_ct = self.n_col_tiles();
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            y[r0..r1].iter_mut().for_each(|v| *v = 0.0);
            for ct in 0..n_ct {
                let c0 = ct * self.col_tile;
                let tile = &self.tiles[rt * n_ct + ct];
                if tile.cols.is_empty() {
                    continue;
                }
                let xs = &x[c0..];
                for (lr, yv) in y[r0..r1].iter_mut().enumerate() {
                    let lo = tile.indptr[lr] as usize;
                    let hi = tile.indptr[lr + 1] as usize;
                    let mut acc = 0.0f32;
                    for i in lo..hi {
                        acc += tile.values[i] as f32 * xs[tile.cols[i] as usize];
                    }
                    *yv += tile.scale * acc;
                }
            }
        }
    }

    /// C = X · Aᵀ for activations X [b × cols] — the tiled batched kernel,
    /// routed through the shared [`microkernel`] tile-walk engine.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        microkernel::fused_forward(self, None, x)
    }
}

/// The QBcsr side of the shared tile-walk engine: each local-CSR row
/// accumulates its raw `Σ q·x` partial in the lane registers and the
/// per-tile scale is applied **once per (row, tile)** on the fold into the
/// row accumulator — the hot loop never touches the scale. Parallelism,
/// the (f32) low-rank pass, and the output scatter live in
/// [`microkernel::fused_tile_walk`].
impl TileWalk for QBcsr {
    fn out_rows(&self) -> usize {
        self.rows
    }

    fn in_cols(&self) -> usize {
        self.cols
    }

    fn walk_row_tile(&self) -> usize {
        self.row_tile
    }

    fn nnz_count(&self) -> usize {
        self.nnz
    }

    fn fold_tile(&self, r0: usize, r1: usize, xt: &Matrix, acc: &mut [f32], isa: Isa) {
        let n_ct = self.n_col_tiles();
        let stripe = &self.tiles[(r0 / self.row_tile) * n_ct..];
        microkernel::fold_tile_stripe(
            n_ct,
            self.col_tile,
            r1 - r0,
            xt.cols,
            acc,
            |ct| &stripe[ct],
            |tile| tile.indptr.as_slice(),
            |tile, lo, hi, c0, arow| {
                let values = &tile.values[lo..hi];
                let cols = &tile.cols[lo..hi];
                let run = I8TileRun { values, cols, base: c0 };
                microkernel::fold_i8_tile(isa, run, xt, arow, tile.scale);
            },
        );
    }
}

/// Fused quantized sparse-plus-low-rank product
/// `C = X·Sᵀ + X·(U·Vt)ᵀ` over a pre-quantized sparse term — the QBcsr
/// counterpart of [`super::spl::fused_matmul`]. The rank-space projection
/// `T = Vt·Xᵀ` is computed once in f32; only the sparse tiles are i8.
pub fn fused_matmul(sparse: &QBcsr, low_rank: Option<&LowRank>, x: &Matrix) -> Matrix {
    microkernel::fused_forward(sparse, low_rank, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_bt;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    #[test]
    fn quantize_roundtrip_error_is_bounded() {
        // Symmetric i8 quantization: per-element error ≤ scale/2 =
        // max|w|/254 within each tile.
        check("qbcsr dequant error bound", 25, |g| {
            let rows = g.usize_range(1, 150);
            let cols = g.usize_range(1, 150);
            let rt = *g.choose(&[1usize, 8, 64]);
            let ct = *g.choose(&[8usize, 64, 512]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&m, rt, ct));
            assert_eq!(q.nnz(), m.nnz());
            let wmax = m.data.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let dq = q.to_dense();
            for (a, b) in dq.data.iter().zip(&m.data) {
                assert!((a - b).abs() <= wmax / 254.0 + 1e-6, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn exactly_representable_values_quantize_losslessly() {
        // values in {-1, 0, 1} map onto q ∈ {-127, 0, 127} exactly.
        let mut m = Matrix::zeros(40, 30);
        let mut rng = Rng::new(7);
        for v in &mut m.data {
            *v = [0.0f32, 1.0, -1.0][rng.below(3)];
        }
        let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&m, 16, 16));
        assert_eq!(q.to_dense(), m);
        assert_eq!(q.max_tile_rel_error(), 0.0);
    }

    #[test]
    fn qbcsr_matvec_matches_dequantized_dense() {
        check("qbcsr matvec == dequant dense", 20, |g| {
            let rows = g.usize_range(1, 120);
            let cols = g.usize_range(1, 120);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.55, &mut rng);
            let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&m, 16, 32));
            let x = g.vec_normal(cols, 1.0);
            let mut y = vec![0.0; rows];
            q.matvec(&x, &mut y);
            let want = crate::tensor::matvec(&q.to_dense(), &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn qbcsr_matmul_xt_matches_dequantized_dense_prop() {
        check("qbcsr matmul_xt == dequant dense", 20, |g| {
            let rows = g.usize_range(1, 120);
            let cols = g.usize_range(1, 120);
            let b = g.usize_range(1, 10);
            let rt = *g.choose(&[1usize, 8, 64]);
            let ct = *g.choose(&[8usize, 64, 512]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let x = Matrix::randn(b, cols, 1.0, &mut rng);
            let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&m, rt, ct));
            let got = q.matmul_xt(&x);
            let want = matmul_bt(&x, &q.to_dense());
            assert!(got.fro_dist(&want) < 1e-3, "dist {}", got.fro_dist(&want));
        });
    }

    #[test]
    fn qbcsr_parallel_path_matches_serial() {
        // Big enough that b·nnz crosses the threading threshold.
        let mut rng = Rng::new(9);
        let m = random_sparse(600, 600, 0.5, &mut rng);
        let x = Matrix::randn(8, 600, 1.0, &mut rng);
        let q = QBcsr::quantize(&Bcsr::from_dense(&m));
        let got = q.matmul_xt(&x);
        let want = matmul_bt(&x, &q.to_dense());
        assert!(got.fro_dist(&want) < 1e-2, "dist {}", got.fro_dist(&want));
    }

    #[test]
    fn fused_quant_matches_unfused_reference() {
        let mut rng = Rng::new(11);
        let m = random_sparse(90, 70, 0.6, &mut rng);
        let lr = LowRank {
            u: Matrix::randn(90, 4, 0.3, &mut rng),
            vt: Matrix::randn(4, 70, 0.3, &mut rng),
        };
        let x = Matrix::randn(5, 70, 1.0, &mut rng);
        let q = QBcsr::quantize(&Bcsr::from_dense_tiled(&m, 16, 32));
        let got = fused_matmul(&q, Some(&lr), &x);
        let mut want = matmul_bt(&x, &q.to_dense());
        lr.apply_batch_accumulate(&x, &mut want);
        assert!(got.fro_dist(&want) < 1e-3, "dist {}", got.fro_dist(&want));
    }

    #[test]
    fn all_zero_matrix_quantizes_cleanly() {
        let z = Matrix::zeros(20, 20);
        let q = QBcsr::quantize(&Bcsr::from_dense(&z));
        assert_eq!(q.nnz(), 0);
        assert_eq!(q.max_tile_rel_error(), 0.0);
        let x = Matrix::randn(3, 20, 1.0, &mut Rng::new(1));
        assert_eq!(q.matmul_xt(&x), Matrix::zeros(3, 20));
        assert_eq!(q.to_dense(), z);
    }

    #[test]
    fn to_csr_preserves_structure() {
        let mut rng = Rng::new(4);
        let m = random_sparse(70, 45, 0.7, &mut rng);
        let bcsr = Bcsr::from_dense(&m);
        let q = QBcsr::quantize(&bcsr);
        let csr = q.to_csr();
        assert_eq!(csr.nnz(), m.nnz());
        assert!(csr.to_dense().fro_dist(&q.to_dense()) < 1e-12);
    }

    #[test]
    fn quantized_footprint_is_smaller() {
        let mut rng = Rng::new(5);
        let m = random_sparse(256, 256, 0.5, &mut rng);
        let bcsr = Bcsr::from_dense(&m);
        let q = QBcsr::quantize(&bcsr);
        // 6 B/nnz (f32 value + u16 offset) → 3 B/nnz: comfortably below.
        assert!(
            (q.memory_bytes() as f64) < (bcsr.memory_bytes() as f64) * 0.75,
            "qbcsr {} !< bcsr {}",
            q.memory_bytes(),
            bcsr.memory_bytes()
        );
    }

    #[test]
    fn outlier_dominated_tile_reports_large_error() {
        // One huge value forces the i8 step so large the small values all
        // collapse to zero — the regime the plan gate protects against.
        let m = crate::util::prop::outlier_dominated(64, 64);
        let q = QBcsr::quantize(&Bcsr::from_dense(&m));
        assert!(
            q.max_tile_rel_error() > 0.1,
            "outlier tile error {}",
            q.max_tile_rel_error()
        );
    }
}
