//! N:M semi-structured sparsity: the pattern descriptor (paper §2.2) and a
//! packed execution format mirroring sparse-tensor-core layouts — `n` value
//! slots + in-group offsets per group of `m` consecutive columns, giving the
//! kernel a fixed, branch-free iteration structure.

use super::microkernel::{self, Isa, NmRowRun, TileWalk};
use crate::tensor::Matrix;

/// Output rows per parallel stripe of the packed N:M batched kernel.
const NM_ROW_TILE: usize = 64;

/// N:M sparsity pattern descriptor: at most `n` nonzeros per group of `m`
/// consecutive entries along each row (NVIDIA sparse-tensor-core layout;
/// paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmPattern {
    pub n: usize,
    pub m: usize,
}

impl NmPattern {
    pub const TWO_FOUR: NmPattern = NmPattern { n: 2, m: 4 };
    pub const TWO_EIGHT: NmPattern = NmPattern { n: 2, m: 8 };

    /// Check that a dense matrix satisfies the pattern (trailing partial
    /// groups are allowed up to ceil(n * len/m) nonzeros).
    pub fn validates(&self, w: &Matrix) -> bool {
        for r in 0..w.rows {
            let row = w.row(r);
            for g in (0..row.len()).step_by(self.m) {
                let end = (g + self.m).min(row.len());
                let nnz = row[g..end].iter().filter(|&&v| v != 0.0).count();
                let cap = if end - g == self.m {
                    self.n
                } else {
                    // partial trailing group: proportional cap, rounded up
                    (self.n * (end - g)).div_ceil(self.m)
                };
                if nnz > cap {
                    return false;
                }
            }
        }
        true
    }

    /// Implied sparsity (fraction zero) of a full pattern.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.n as f64 / self.m as f64
    }

    /// [`NmPattern::validates`] directly on a CSR matrix — O(nnz + rows ·
    /// cols/m), no dense materialization. Robust to unsorted per-row column
    /// indices (a malformed checkpoint must fail validation, not falsely
    /// pass it).
    pub fn validates_csr(&self, csr: &crate::sparse::Csr) -> bool {
        let groups = csr.cols.div_ceil(self.m).max(1);
        let mut counts = vec![0u32; groups];
        for r in 0..csr.rows {
            counts.iter_mut().for_each(|c| *c = 0);
            let lo = csr.indptr[r] as usize;
            let hi = csr.indptr[r + 1] as usize;
            for &c in &csr.indices[lo..hi] {
                let g = c as usize / self.m;
                counts[g] += 1;
                let start = g * self.m;
                let end = (start + self.m).min(csr.cols);
                let cap = if end - start == self.m {
                    self.n
                } else {
                    (self.n * (end - start)).div_ceil(self.m)
                };
                if counts[g] as usize > cap {
                    return false;
                }
            }
        }
        true
    }
}

/// Packed N:M matrix: per (row, group) exactly `n` slots, each a value plus
/// its offset inside the group. Underfull groups pad with zero-value slots
/// (offset 0 — the product contributes nothing), so the kernel loop bounds
/// are compile-time-predictable per matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct NmPacked {
    pub rows: usize,
    pub cols: usize,
    pub pattern: NmPattern,
    groups_per_row: usize,
    /// `rows * groups_per_row * n` value slots.
    values: Vec<f32>,
    /// Same length; offset of each slot inside its group (`< m ≤ 256`).
    offsets: Vec<u8>,
    nnz: usize,
}

impl NmPacked {
    /// Pack a dense matrix; `None` if it violates the pattern (or the group
    /// width exceeds the `u8` offset range).
    pub fn pack(w: &Matrix, pattern: NmPattern) -> Option<NmPacked> {
        if pattern.m > 256 || pattern.n == 0 || !pattern.validates(w) {
            return None;
        }
        let groups_per_row = w.cols.div_ceil(pattern.m).max(1);
        let slots = w.rows * groups_per_row * pattern.n;
        let mut values = vec![0.0f32; slots];
        let mut offsets = vec![0u8; slots];
        let mut nnz = 0usize;
        for r in 0..w.rows {
            let row = w.row(r);
            for g in 0..groups_per_row {
                let base = g * pattern.m;
                let end = (base + pattern.m).min(w.cols);
                let slot0 = (r * groups_per_row + g) * pattern.n;
                let mut k = 0usize;
                for (off, &v) in row[base..end].iter().enumerate() {
                    if v != 0.0 {
                        // A partial trailing group can legally hold up to
                        // ceil(n·len/m) ≤ n nonzeros, so k < n always.
                        values[slot0 + k] = v;
                        offsets[slot0 + k] = off as u8;
                        k += 1;
                        nnz += 1;
                    }
                }
            }
        }
        let (rows, cols) = (w.rows, w.cols);
        Some(NmPacked { rows, cols, pattern, groups_per_row, values, offsets, nnz })
    }

    /// Stored nonzero count (zero-padding slots excluded).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// In-memory footprint of the packed representation (f32 value slots +
    /// u8 in-group offsets, padding slots included).
    pub fn memory_bytes(&self) -> usize {
        4 * self.values.len() + self.offsets.len()
    }

    /// Portable CSR view — O(nnz), no dense temporary. Groups and in-group
    /// offsets are stored ascending, so indices come out ascending.
    pub fn to_csr(&self) -> crate::sparse::Csr {
        let n = self.pattern.n;
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        indptr.push(0u32);
        for r in 0..self.rows {
            for g in 0..self.groups_per_row {
                let base = (g * self.pattern.m) as u32;
                let slot0 = (r * self.groups_per_row + g) * n;
                for k in 0..n {
                    let v = self.values[slot0 + k];
                    if v != 0.0 {
                        indices.push(base + self.offsets[slot0 + k] as u32);
                        values.push(v);
                    }
                }
            }
            indptr.push(indices.len() as u32);
        }
        crate::sparse::Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let n = self.pattern.n;
        for r in 0..self.rows {
            for g in 0..self.groups_per_row {
                let base = g * self.pattern.m;
                let slot0 = (r * self.groups_per_row + g) * n;
                for k in 0..n {
                    let v = self.values[slot0 + k];
                    if v != 0.0 {
                        m.data[r * self.cols + base + self.offsets[slot0 + k] as usize] = v;
                    }
                }
            }
        }
        m
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let n = self.pattern.n;
        for (r, yv) in y.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for g in 0..self.groups_per_row {
                let base = g * self.pattern.m;
                let slot0 = (r * self.groups_per_row + g) * n;
                for k in 0..n {
                    acc += self.values[slot0 + k] * x[base + self.offsets[slot0 + k] as usize];
                }
            }
            *yv = acc;
        }
    }

    /// C = X · Aᵀ via the transposed-panel trick (see `bcsr`), routed
    /// through the shared [`microkernel`] tile-walk engine: the inner loop
    /// is the register-blocked lane fold over each row's value slots.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        microkernel::fused_forward(self, None, x)
    }
}

/// The N:M side of the shared tile-walk engine: one packed-group run per
/// output row (padding slots skipped inside the run), folded through the
/// f32 lane kernels. Parallelism, the fused low-rank pass, and the output
/// scatter live in [`microkernel::fused_tile_walk`].
impl TileWalk for NmPacked {
    fn out_rows(&self) -> usize {
        self.rows
    }

    fn in_cols(&self) -> usize {
        self.cols
    }

    fn walk_row_tile(&self) -> usize {
        NM_ROW_TILE
    }

    fn nnz_count(&self) -> usize {
        self.nnz
    }

    fn fold_tile(&self, r0: usize, r1: usize, xt: &Matrix, acc: &mut [f32], isa: Isa) {
        let b = xt.cols;
        let n = self.pattern.n;
        let slots_per_row = self.groups_per_row * n;
        for (lr, r) in (r0..r1).enumerate() {
            let slot0 = r * slots_per_row;
            let run = NmRowRun {
                values: &self.values[slot0..slot0 + slots_per_row],
                offsets: &self.offsets[slot0..slot0 + slots_per_row],
                n,
                m: self.pattern.m,
            };
            microkernel::fold_nm_row(isa, run, xt, &mut acc[lr * b..(lr + 1) * b], 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::threshold::hard_threshold;
    use crate::config::SparsityPattern;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn nm_pattern_validation() {
        // 2:4-valid row
        let ok = Matrix::from_vec(1, 8, vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
        assert!(NmPattern::TWO_FOUR.validates(&ok));
        // violating group
        let bad = Matrix::from_vec(1, 8, vec![1.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(!NmPattern::TWO_FOUR.validates(&bad));
    }

    #[test]
    fn nm_pattern_partial_group() {
        // 6 cols with 2:4: trailing group of 2 may hold ceil(2*2/4)=1 nonzero.
        let ok = Matrix::from_vec(1, 6, vec![1.0, 2.0, 0.0, 0.0, 5.0, 0.0]);
        assert!(NmPattern::TWO_FOUR.validates(&ok));
        let bad = Matrix::from_vec(1, 6, vec![1.0, 2.0, 0.0, 0.0, 5.0, 6.0]);
        assert!(!NmPattern::TWO_FOUR.validates(&bad));
    }

    #[test]
    fn nm_sparsity_values() {
        assert!((NmPattern::TWO_FOUR.sparsity() - 0.5).abs() < 1e-12);
        assert!((NmPattern::TWO_EIGHT.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn validates_csr_agrees_with_dense_prop() {
        check("validates_csr == validates", 30, |g| {
            let rows = g.usize_range(1, 40);
            let cols = g.usize_range(1, 60);
            let pat = *g.choose(&[NmPattern::TWO_FOUR, NmPattern::TWO_EIGHT]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            // Mix of conforming and violating matrices.
            let w = if g.bool() {
                let dense = Matrix::randn(rows, cols, 1.0, &mut rng);
                hard_threshold(&dense, &dense, 0, SparsityPattern::Nm { n: pat.n, m: pat.m })
            } else {
                let mut m = Matrix::randn(rows, cols, 1.0, &mut rng);
                for v in &mut m.data {
                    if rng.f64() < 0.5 {
                        *v = 0.0;
                    }
                }
                m
            };
            let csr = crate::sparse::Csr::from_dense(&w);
            assert_eq!(pat.validates_csr(&csr), pat.validates(&w));
        });
    }

    #[test]
    fn nm_pack_rejects_violations() {
        let bad = Matrix::from_vec(1, 8, vec![1.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(NmPacked::pack(&bad, NmPattern::TWO_FOUR).is_none());
    }

    #[test]
    fn nm_pack_roundtrip_prop() {
        check("nm pack/to_dense roundtrip", 25, |g| {
            let rows = g.usize_range(1, 40);
            let cols = g.usize_range(1, 70);
            let pat = *g.choose(&[NmPattern::TWO_FOUR, NmPattern::TWO_EIGHT]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let w = Matrix::randn(rows, cols, 1.0, &mut rng);
            let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: pat.n, m: pat.m });
            let packed = NmPacked::pack(&pruned, pat).expect("pruned matrix must validate");
            assert_eq!(packed.to_dense(), pruned);
            assert_eq!(packed.nnz(), pruned.nnz());
            assert_eq!(packed.to_csr(), crate::sparse::Csr::from_dense(&pruned));
        });
    }

    #[test]
    fn nm_kernels_match_dense_prop() {
        check("nm matvec/matmul_xt == dense", 25, |g| {
            let rows = g.usize_range(1, 50);
            let cols = g.usize_range(1, 60);
            let b = g.usize_range(1, 6);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let w = Matrix::randn(rows, cols, 1.0, &mut rng);
            let pruned = hard_threshold(&w, &w, 0, SparsityPattern::Nm { n: 2, m: 4 });
            let packed = NmPacked::pack(&pruned, NmPattern::TWO_FOUR).unwrap();

            let x = g.vec_normal(cols, 1.0);
            let mut y = vec![0.0; rows];
            packed.matvec(&x, &mut y);
            let want = crate::tensor::matvec(&pruned, &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4);
            }

            let xb = Matrix::randn(b, cols, 1.0, &mut rng);
            let got = packed.matmul_xt(&xb);
            let wantb = crate::tensor::matmul_bt(&xb, &pruned);
            assert!(got.fro_dist(&wantb) < 1e-3);
        });
    }
}
