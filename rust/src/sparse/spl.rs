//! The OATS compressed layer: W ≈ S + L with S sparse and L = U·Vᵀ low
//! rank, and the fused kernel that evaluates both terms in one pass over
//! the output.

use super::bcsr::Bcsr;
use super::csr::Csr;
use super::lowrank::LowRank;
use super::microkernel;
use crate::tensor::Matrix;

/// The OATS compressed layer: W ≈ S + L with S sparse (CSR) and L low-rank.
#[derive(Clone, Debug)]
pub struct SparsePlusLowRank {
    pub sparse: Csr,
    pub low_rank: Option<LowRank>,
}

impl SparsePlusLowRank {
    /// Dense reconstruction S + U·Vt.
    pub fn to_dense(&self) -> Matrix {
        let mut d = self.sparse.to_dense();
        if let Some(lr) = &self.low_rank {
            d.axpy(1.0, &lr.to_dense());
        }
        d
    }

    /// Nonzero-parameter count (paper's compression accounting, Eq. ρ):
    /// k + r(dout + din).
    pub fn param_count(&self) -> usize {
        self.sparse.nnz() + self.low_rank.as_ref().map_or(0, |lr| lr.params())
    }

    /// Achieved compression rate vs the dense layer.
    pub fn compression_rate(&self) -> f64 {
        1.0 - self.param_count() as f64 / (self.sparse.rows * self.sparse.cols) as f64
    }

    /// y = (S + UVt) x — the fused serving kernel (single vector).
    pub fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.sparse.matvec(x, y);
        if let Some(lr) = &self.low_rank {
            lr.apply_accumulate(x, y);
        }
    }

    /// C = X (S + UVt)ᵀ — batched serving kernel (scalar CSR + two GEMMs).
    pub fn apply_batch(&self, x: &Matrix) -> Matrix {
        let mut out = self.sparse.matmul_xt(x);
        if let Some(lr) = &self.low_rank {
            lr.apply_batch_accumulate(x, &mut out);
        }
        out
    }

    /// C = X (S + UVt)ᵀ through the tiled fused kernel: S is packed to BCSR
    /// and each output tile receives its sparse and low-rank contributions in
    /// one accumulator pass (one write per output element).
    ///
    /// This convenience packs S on every call; the serving engine keeps the
    /// packing alive across calls via [`crate::sparse::PackedLinear`].
    pub fn matmul_fused(&self, x: &Matrix) -> Matrix {
        let bcsr = Bcsr::from_csr(&self.sparse);
        fused_matmul(&bcsr, self.low_rank.as_ref(), x)
    }
}

/// Fused sparse-plus-low-rank product `C = X·Sᵀ + X·(U·Vt)ᵀ` over a
/// pre-packed BCSR sparse term.
///
/// The activation block is transposed once (Xᵀ [in × b]); the rank-space
/// projection `T = Vt·Xᵀ` [r × b] is computed once; then a single pass over
/// the row tiles of S accumulates `S·Xᵀ` and `U·T` together — each
/// activation row streams through both terms exactly once. The pass itself
/// is the shared [`super::microkernel`] tile-walk engine.
pub fn fused_matmul(sparse: &Bcsr, low_rank: Option<&LowRank>, x: &Matrix) -> Matrix {
    microkernel::fused_forward(sparse, low_rank, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    fn random_spl(rows: usize, cols: usize, r: usize, rng: &mut Rng) -> SparsePlusLowRank {
        let s = random_sparse(rows, cols, 0.7, rng);
        SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(rows, r, 1.0, rng),
                vt: Matrix::randn(r, cols, 1.0, rng),
            }),
        }
    }

    #[test]
    fn spl_apply_matches_dense_reconstruction_prop() {
        check("spl apply == dense(S+L)·x", 20, |g| {
            let rows = g.usize_range(2, 24);
            let cols = g.usize_range(2, 24);
            let r = g.usize_range(1, cols.min(rows).min(4) + 1);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let s = random_sparse(rows, cols, 0.8, &mut rng);
            let spl = SparsePlusLowRank {
                sparse: Csr::from_dense(&s),
                low_rank: Some(LowRank {
                    u: Matrix::randn(rows, r, 1.0, &mut rng),
                    vt: Matrix::randn(r, cols, 1.0, &mut rng),
                }),
            };
            let x = g.vec_normal(cols, 1.0);
            let mut y = vec![0.0; rows];
            spl.apply(&x, &mut y);
            let want = crate::tensor::matvec(&spl.to_dense(), &x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn spl_fused_matches_apply_batch_prop() {
        check("fused == apply_batch", 20, |g| {
            let rows = g.usize_range(2, 100);
            let cols = g.usize_range(2, 100);
            let b = g.usize_range(1, 9);
            let r = g.usize_range(1, 8);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let spl = random_spl(rows, cols, r, &mut rng);
            let x = Matrix::randn(b, cols, 1.0, &mut rng);
            let fused = spl.matmul_fused(&x);
            let unfused = spl.apply_batch(&x);
            assert!(fused.fro_dist(&unfused) < 1e-3, "dist {}", fused.fro_dist(&unfused));
        });
    }

    #[test]
    fn spl_fused_without_low_rank() {
        let mut rng = Rng::new(6);
        let s = random_sparse(40, 30, 0.6, &mut rng);
        let spl = SparsePlusLowRank { sparse: Csr::from_dense(&s), low_rank: None };
        let x = Matrix::randn(3, 30, 1.0, &mut rng);
        let fused = spl.matmul_fused(&x);
        let want = crate::tensor::matmul_bt(&x, &s);
        assert!(fused.fro_dist(&want) < 1e-4);
    }

    #[test]
    fn spl_param_count_and_rate() {
        let mut rng = Rng::new(5);
        let s = random_sparse(10, 10, 0.9, &mut rng);
        let nnz = s.nnz();
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(10, 2, 1.0, &mut rng),
                vt: Matrix::randn(2, 10, 1.0, &mut rng),
            }),
        };
        assert_eq!(spl.param_count(), nnz + 2 * 20);
        let rate = spl.compression_rate();
        assert!((rate - (1.0 - (nnz as f64 + 40.0) / 100.0)).abs() < 1e-12);
    }
}
