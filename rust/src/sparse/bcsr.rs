//! Tiled block-CSR: the cache-aware batched kernel behind Table 7's CPU
//! speedups.
//!
//! Layout: the weight matrix A (out×in) is cut into row tiles × column
//! tiles; each tile stores its nonzeros in a local CSR with `u16` in-tile
//! column offsets (half the index bytes of global-`u32` CSR). The batched
//! kernel `C = X · Aᵀ` works on Xᵀ panels:
//!
//! * the activation block is transposed once to Xᵀ [in × b], so for every
//!   nonzero `a[r,c]` the b-wide row `Xᵀ[c, ·]` is contiguous — the inner
//!   loop is the register-blocked SIMD lane fold of
//!   [`super::microkernel`] instead of a scalar gather-multiply;
//! * weight values/indices stream through cache **once per batch**, not once
//!   per activation row (the scalar kernel re-reads all of A for every row
//!   of X — at 2048² / 50% that is b× more memory traffic);
//! * the column tile bounds the live Xᵀ working set to
//!   `col_tile · b · 4` bytes (L1-sized at the defaults), and the row tile
//!   keeps the local accumulator `row_tile · b · 4` bytes resident.
//!
//! Row tiles are independent, so the kernel parallelizes over them.

use super::csr::Csr;
use super::microkernel::{self, F32TileRun, Isa, TileWalk};
use crate::tensor::Matrix;

/// Default row-tile height: 64 output rows × batch 8 × 4 B = 2 KiB of
/// accumulator per tile.
pub const DEFAULT_ROW_TILE: usize = 64;
/// Default column-tile width: 512 input columns × batch 8 × 4 B = 16 KiB of
/// live Xᵀ panel — half a typical 32 KiB L1d.
pub const DEFAULT_COL_TILE: usize = 512;

/// One (row-tile × col-tile) block: a local CSR with in-tile column offsets.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct Tile {
    /// len = rows-in-tile + 1, offsets into `cols`/`values`.
    pub(crate) indptr: Vec<u32>,
    /// Column offsets relative to the tile's first column (< col_tile ≤ 65536).
    pub(crate) cols: Vec<u16>,
    pub(crate) values: Vec<f32>,
}

/// Block-compressed-sparse-row matrix with cache-sized tiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Bcsr {
    pub rows: usize,
    pub cols: usize,
    pub row_tile: usize,
    pub col_tile: usize,
    /// Tiles in row-tile-major order: `tiles[rt * n_col_tiles + ct]`.
    tiles: Vec<Tile>,
    nnz: usize,
}

impl Bcsr {
    /// Pack a dense matrix with the default tile sizes.
    pub fn from_dense(m: &Matrix) -> Bcsr {
        Self::from_dense_tiled(m, DEFAULT_ROW_TILE, DEFAULT_COL_TILE)
    }

    /// Pack a dense matrix with explicit tile sizes.
    pub fn from_dense_tiled(m: &Matrix, row_tile: usize, col_tile: usize) -> Bcsr {
        assert!(row_tile > 0 && col_tile > 0, "tile sizes must be positive");
        assert!(col_tile <= 1 << 16, "col_tile must fit u16 offsets");
        let n_rt = m.rows.div_ceil(row_tile).max(1);
        let n_ct = m.cols.div_ceil(col_tile).max(1);
        let mut tiles = Vec::with_capacity(n_rt * n_ct);
        let mut nnz = 0usize;
        for rt in 0..n_rt {
            let r0 = rt * row_tile;
            let r1 = (r0 + row_tile).min(m.rows);
            for ct in 0..n_ct {
                let c0 = ct * col_tile;
                let c1 = (c0 + col_tile).min(m.cols);
                let mut indptr = Vec::with_capacity(r1 - r0 + 1);
                let mut cols = Vec::new();
                let mut values = Vec::new();
                indptr.push(0u32);
                for r in r0..r1 {
                    let row = &m.row(r)[c0..c1];
                    for (off, &v) in row.iter().enumerate() {
                        if v != 0.0 {
                            cols.push(off as u16);
                            values.push(v);
                        }
                    }
                    indptr.push(cols.len() as u32);
                }
                nnz += values.len();
                tiles.push(Tile { indptr, cols, values });
            }
        }
        Bcsr { rows: m.rows, cols: m.cols, row_tile, col_tile, tiles, nnz }
    }

    /// Re-tile an existing CSR matrix directly — the checkpoint pre-packing
    /// path; no dense temporary. Relies on per-row column indices being
    /// ascending (true for every CSR built in this crate).
    pub fn from_csr(csr: &Csr) -> Bcsr {
        Self::from_csr_tiled(csr, DEFAULT_ROW_TILE, DEFAULT_COL_TILE)
    }

    /// [`Bcsr::from_csr`] with explicit tile sizes.
    pub fn from_csr_tiled(csr: &Csr, row_tile: usize, col_tile: usize) -> Bcsr {
        assert!(row_tile > 0 && col_tile > 0, "tile sizes must be positive");
        assert!(col_tile <= 1 << 16, "col_tile must fit u16 offsets");
        let n_rt = csr.rows.div_ceil(row_tile).max(1);
        let n_ct = csr.cols.div_ceil(col_tile).max(1);
        let mut tiles = Vec::with_capacity(n_rt * n_ct);
        for rt in 0..n_rt {
            let r0 = rt * row_tile;
            let r1 = (r0 + row_tile).min(csr.rows);
            let mut stripe: Vec<Tile> = (0..n_ct)
                .map(|_| Tile {
                    indptr: Vec::with_capacity(r1 - r0 + 1),
                    cols: Vec::new(),
                    values: Vec::new(),
                })
                .collect();
            for tile in stripe.iter_mut() {
                tile.indptr.push(0);
            }
            for r in r0..r1 {
                let lo = csr.indptr[r] as usize;
                let hi = csr.indptr[r + 1] as usize;
                for i in lo..hi {
                    let c = csr.indices[i] as usize;
                    let ct = c / col_tile;
                    stripe[ct].cols.push((c - ct * col_tile) as u16);
                    stripe[ct].values.push(csr.values[i]);
                }
                for tile in stripe.iter_mut() {
                    tile.indptr.push(tile.cols.len() as u32);
                }
            }
            tiles.extend(stripe);
        }
        Bcsr {
            rows: csr.rows,
            cols: csr.cols,
            row_tile,
            col_tile,
            tiles,
            nnz: csr.nnz(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// In-memory footprint of the packed representation (indptr + u16
    /// column offsets + f32 values) — the baseline the i8 quantized format
    /// is compared against.
    pub fn memory_bytes(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| 4 * t.indptr.len() + 2 * t.cols.len() + 4 * t.values.len())
            .sum()
    }

    /// Tiles in row-tile-major order — the quantizer's input view.
    pub(crate) fn tiles(&self) -> &[Tile] {
        &self.tiles
    }

    pub(crate) fn n_col_tiles(&self) -> usize {
        self.cols.div_ceil(self.col_tile).max(1)
    }

    pub(crate) fn n_row_tiles(&self) -> usize {
        self.rows.div_ceil(self.row_tile).max(1)
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let n_ct = self.n_col_tiles();
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            for ct in 0..n_ct {
                let c0 = ct * self.col_tile;
                let tile = &self.tiles[rt * n_ct + ct];
                for (lr, r) in (r0..r1).enumerate() {
                    for i in tile.indptr[lr] as usize..tile.indptr[lr + 1] as usize {
                        m.data[r * self.cols + c0 + tile.cols[i] as usize] = tile.values[i];
                    }
                }
            }
        }
        m
    }

    /// Portable CSR view (used by the structure-preserving checkpoint
    /// format). Merges each row's per-tile segments directly — column tiles
    /// are ascending and in-tile offsets are ascending, so no dense
    /// temporary and no sort are needed.
    pub fn to_csr(&self) -> Csr {
        let n_ct = self.n_col_tiles();
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        indptr.push(0u32);
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            for lr in 0..(r1 - r0) {
                for ct in 0..n_ct {
                    let c0 = (ct * self.col_tile) as u32;
                    let tile = &self.tiles[rt * n_ct + ct];
                    let lo = tile.indptr[lr] as usize;
                    let hi = tile.indptr[lr + 1] as usize;
                    for i in lo..hi {
                        indices.push(c0 + tile.cols[i] as u32);
                        values.push(tile.values[i]);
                    }
                }
                indptr.push(indices.len() as u32);
            }
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// y = A·x — scalar per-row kernel for the single-token decode path.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let n_ct = self.n_col_tiles();
        for rt in 0..self.n_row_tiles() {
            let r0 = rt * self.row_tile;
            let r1 = (r0 + self.row_tile).min(self.rows);
            y[r0..r1].iter_mut().for_each(|v| *v = 0.0);
            for ct in 0..n_ct {
                let c0 = ct * self.col_tile;
                let tile = &self.tiles[rt * n_ct + ct];
                if tile.cols.is_empty() {
                    continue;
                }
                let xs = &x[c0..];
                for (lr, yv) in y[r0..r1].iter_mut().enumerate() {
                    let lo = tile.indptr[lr] as usize;
                    let hi = tile.indptr[lr + 1] as usize;
                    let mut acc = 0.0f32;
                    for i in lo..hi {
                        acc += tile.values[i] * xs[tile.cols[i] as usize];
                    }
                    *yv += acc;
                }
            }
        }
    }

    /// C = X · Aᵀ for activations X [b × cols] — the tiled batched kernel,
    /// routed through the shared [`microkernel`] tile-walk engine.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        microkernel::fused_forward(self, None, x)
    }
}

/// The BCSR side of the shared tile-walk engine: per row tile, walk the
/// stripe's column tiles and fold each local-CSR row through the f32 lane
/// kernels (scale 1.0 — the identity fold). Parallelism, the fused
/// low-rank pass, and the output scatter live in
/// [`microkernel::fused_tile_walk`].
impl TileWalk for Bcsr {
    fn out_rows(&self) -> usize {
        self.rows
    }

    fn in_cols(&self) -> usize {
        self.cols
    }

    fn walk_row_tile(&self) -> usize {
        self.row_tile
    }

    fn nnz_count(&self) -> usize {
        self.nnz
    }

    fn fold_tile(&self, r0: usize, r1: usize, xt: &Matrix, acc: &mut [f32], isa: Isa) {
        let n_ct = self.n_col_tiles();
        let stripe = &self.tiles[(r0 / self.row_tile) * n_ct..];
        microkernel::fold_tile_stripe(
            n_ct,
            self.col_tile,
            r1 - r0,
            xt.cols,
            acc,
            |ct| &stripe[ct],
            |tile| tile.indptr.as_slice(),
            |tile, lo, hi, c0, arow| {
                let values = &tile.values[lo..hi];
                let cols = &tile.cols[lo..hi];
                let run = F32TileRun { values, cols, base: c0 };
                microkernel::fold_f32_tile(isa, run, xt, arow, 1.0);
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    #[test]
    fn bcsr_roundtrip_prop() {
        check("bcsr dense roundtrip", 30, |g| {
            let rows = g.usize_range(1, 200);
            let cols = g.usize_range(1, 200);
            let rt = *g.choose(&[1usize, 3, 16, 64]);
            let ct = *g.choose(&[4usize, 32, 512]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.65, &mut rng);
            let b = Bcsr::from_dense_tiled(&m, rt, ct);
            assert_eq!(b.to_dense(), m);
            assert_eq!(b.nnz(), m.nnz());
        });
    }

    #[test]
    fn bcsr_matvec_matches_csr() {
        check("bcsr matvec == csr", 25, |g| {
            let rows = g.usize_range(1, 150);
            let cols = g.usize_range(1, 150);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let x = g.vec_normal(cols, 1.0);
            let csr = Csr::from_dense(&m);
            let bcsr = Bcsr::from_dense_tiled(&m, 16, 32);
            let mut y1 = vec![0.0; rows];
            let mut y2 = vec![0.0; rows];
            csr.matvec(&x, &mut y1);
            bcsr.matvec(&x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn bcsr_matmul_xt_matches_dense_prop() {
        check("bcsr matmul_xt == dense", 25, |g| {
            let rows = g.usize_range(1, 120);
            let cols = g.usize_range(1, 120);
            let b = g.usize_range(1, 10);
            let rt = *g.choose(&[1usize, 8, 64]);
            let ct = *g.choose(&[8usize, 64, 512]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let x = Matrix::randn(b, cols, 1.0, &mut rng);
            let got = Bcsr::from_dense_tiled(&m, rt, ct).matmul_xt(&x);
            let want = crate::tensor::matmul_bt(&x, &m);
            assert!(got.fro_dist(&want) < 1e-3, "dist {}", got.fro_dist(&want));
        });
    }

    #[test]
    fn bcsr_parallel_path_matches_serial() {
        // Big enough that b·nnz crosses the threading threshold.
        let mut rng = Rng::new(9);
        let m = random_sparse(600, 600, 0.5, &mut rng);
        let x = Matrix::randn(8, 600, 1.0, &mut rng);
        let got = Bcsr::from_dense(&m).matmul_xt(&x);
        let want = Csr::from_dense(&m).matmul_xt(&x);
        assert!(got.fro_dist(&want) < 1e-2, "dist {}", got.fro_dist(&want));
    }

    #[test]
    fn bcsr_from_csr_preserves_structure() {
        let mut rng = Rng::new(4);
        let m = random_sparse(70, 45, 0.7, &mut rng);
        let csr = Csr::from_dense(&m);
        let bcsr = Bcsr::from_csr(&csr);
        assert_eq!(bcsr.to_csr(), csr);
        assert!((bcsr.sparsity() - csr.sparsity()).abs() < 1e-12);
    }

    #[test]
    fn bcsr_from_csr_equals_from_dense_prop() {
        // The direct CSR tiling (no dense temporary) must produce the exact
        // structure the dense pass produces, across tile geometries.
        check("from_csr == from_dense", 25, |g| {
            let rows = g.usize_range(1, 150);
            let cols = g.usize_range(1, 150);
            let rt = *g.choose(&[1usize, 8, 64]);
            let ct = *g.choose(&[8usize, 100, 512]);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let csr = Csr::from_dense(&m);
            assert_eq!(
                Bcsr::from_csr_tiled(&csr, rt, ct),
                Bcsr::from_dense_tiled(&m, rt, ct)
            );
        });
    }

    #[test]
    fn bcsr_empty_and_full() {
        let z = Matrix::zeros(10, 10);
        let b = Bcsr::from_dense(&z);
        assert_eq!(b.nnz(), 0);
        let x = Matrix::randn(2, 10, 1.0, &mut Rng::new(1));
        assert_eq!(b.matmul_xt(&x), Matrix::zeros(2, 10));
        let f = Matrix::filled(5, 7, 2.0);
        let bf = Bcsr::from_dense(&f);
        assert_eq!(bf.nnz(), 35);
        assert_eq!(bf.to_dense(), f);
    }
}
