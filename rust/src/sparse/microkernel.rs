//! The shared tile-walk engine and the register-blocked SIMD microkernels
//! behind every batched sparse format.
//!
//! Before this module, csr/bcsr/nm/quant each carried their own copy of the
//! same SAFETY-critical scaffolding: a row-tile parallel loop, a local
//! accumulator, an optional fused low-rank pass, a raw-pointer scatter into
//! the `[b × rows]` output, and the `b·nnz ≥ 2²⁰` thread gate. All four now
//! route through [`fused_tile_walk`], parameterized over a value accessor
//! ([`TileWalk::fold_tile`] + the [`NnzRun`] family: f32 values for `Bcsr`,
//! i8 × per-tile-scale for `QBcsr`, packed groups for `NmPacked`, global
//! u32 columns for `Csr`), so the **one** `unsafe` scatter in the sparse
//! kernels lives here and is audited once.
//!
//! ## Register-blocked lane kernels
//!
//! The hot inner loop — the b-wide axpy over a row's nonzeros — runs as
//! monomorphized `[f32; L]` accumulator kernels for L ∈ {16, 8, 4} with a
//! scalar (L = 1) tail: a lane of L batch columns is held in registers
//! while the row's nonzeros stream past once, then folded into the row
//! accumulator with one (optionally scaled) store per element. On x86_64
//! the whole fold is cloned behind `#[target_feature(enable = "avx2,fma")]`
//! and selected at runtime via `is_x86_feature_detected!` ([`Isa`]); other
//! architectures keep the autovectorized generic build. No `std::arch`
//! intrinsics and no new dependencies — the clones only let LLVM pick
//! 256-bit vectors for the fixed-size lane arrays.
//!
//! ## Numerics contract
//!
//! Laning is across **batch columns**; each output element still folds its
//! nonzeros in index order, with one rounding per multiply-add and one
//! per scale fold, exactly like the scalar tail. Consequently results are
//! bit-identical across batch widths and lane/tail splits for a fixed
//! input column, and bit-identical between the SIMD and generic builds
//! (the `target_feature` clones change vector width, never the operation
//! sequence — Rust performs no implicit FMA contraction, and the kernels
//! use none explicitly, uniformly across lanes and tail). The serve
//! engine's `engine == generate_lockstep` bit-identity properties rest on
//! this invariance.
//!
//! ## Workspace
//!
//! [`Workspace`] is a recycled-buffer pool threaded through
//! [`PackedLinear::forward_ws`] and `TransformerLM::decode_step_batch_ws`
//! so the serve decode loop stops paying a fresh `x.transpose()` +
//! `Matrix::zeros` heap allocation on every step: buffers cycle through
//! the pool and the per-step allocation count drops to zero once shapes
//! have been seen (tracked by [`Workspace::alloc_count`], exported in the
//! serve telemetry as `ws_buffer_allocs`).
//!
//! [`PackedLinear::forward_ws`]: super::plan::PackedLinear::forward_ws

use super::lowrank::LowRank;
use crate::tensor::Matrix;
use crate::util::threadpool::{available_threads, parallel_for, SendPtr};
use std::cell::Cell;

/// Lane widths the dispatcher tries, widest first; columns past the last
/// full lane fold through the scalar (L = 1) tail.
pub const LANE_WIDTHS: [usize; 3] = [16, 8, 4];

/// `b·nnz` at which the row-tile loop fans out across threads.
const PARALLEL_MIN_WORK: usize = 1 << 20;

/// Which instruction-set build the lane kernels run through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The portable build (autovectorized at the crate's base target).
    Generic,
    /// x86_64 clones compiled with `avx2,fma` enabled (runtime-detected).
    Avx2Fma,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Generic => "generic",
            Isa::Avx2Fma => "avx2+fma",
        }
    }
}

/// Runtime ISA detection, decided once per process.
pub fn detected_isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static DETECTED: OnceLock<Isa> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                Isa::Avx2Fma
            } else {
                Isa::Generic
            }
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        Isa::Generic
    }
}

thread_local! {
    /// Test/bench override consulted by [`active_isa`]. Never upgrades past
    /// what detection found (forcing AVX2 on a non-AVX2 host would be UB).
    static ISA_OVERRIDE: Cell<Option<Isa>> = const { Cell::new(None) };
}

/// The ISA the next kernel call on this thread will dispatch to. The
/// engine reads it once per kernel call, on the caller's thread, before
/// fanning out — so [`with_isa`] works even though the row tiles run on
/// scoped worker threads.
pub fn active_isa() -> Isa {
    let detected = detected_isa();
    match ISA_OVERRIDE.with(Cell::get) {
        Some(Isa::Generic) => Isa::Generic,
        _ => detected,
    }
}

/// Run `f` with the lane kernels pinned to `isa` (downgrade only) on this
/// thread — the bench/test hook for SIMD-vs-generic comparisons.
pub fn with_isa<T>(isa: Isa, f: impl FnOnce() -> T) -> T {
    ISA_OVERRIDE.with(|o| {
        let prev = o.replace(Some(isa));
        let out = f();
        o.set(prev);
        out
    })
}

/// One row's nonzeros in fold order, abstracted over the storage format.
/// `for_each` is monomorphized + inlined into the lane kernels, so each
/// format pays only its own decode cost (u16+base, i8 widen, packed-group,
/// u32) in the inner loop.
pub(crate) trait NnzRun: Copy {
    /// Visit `(value, xt_row_index)` for every nonzero, in index order.
    fn for_each(self, f: impl FnMut(f32, usize));
}

/// f32 values with tile-local u16 column offsets (`Bcsr` tiles).
#[derive(Clone, Copy)]
pub(crate) struct F32TileRun<'a> {
    pub values: &'a [f32],
    pub cols: &'a [u16],
    pub base: usize,
}

impl NnzRun for F32TileRun<'_> {
    #[inline(always)]
    fn for_each(self, mut f: impl FnMut(f32, usize)) {
        for (&v, &c) in self.values.iter().zip(self.cols) {
            f(v, self.base + c as usize);
        }
    }
}

/// i8 values with tile-local u16 column offsets (`QBcsr` tiles); the
/// per-tile scale is applied by the fold's `scale` argument, not here, so
/// the raw `Σ q·x` partial accumulates unscaled exactly as before.
#[derive(Clone, Copy)]
pub(crate) struct I8TileRun<'a> {
    pub values: &'a [i8],
    pub cols: &'a [u16],
    pub base: usize,
}

impl NnzRun for I8TileRun<'_> {
    #[inline(always)]
    fn for_each(self, mut f: impl FnMut(f32, usize)) {
        for (&v, &c) in self.values.iter().zip(self.cols) {
            f(v as f32, self.base + c as usize);
        }
    }
}

/// f32 values with global u32 column indices (`Csr` rows).
#[derive(Clone, Copy)]
pub(crate) struct GlobalCsrRun<'a> {
    pub values: &'a [f32],
    pub cols: &'a [u32],
}

impl NnzRun for GlobalCsrRun<'_> {
    #[inline(always)]
    fn for_each(self, mut f: impl FnMut(f32, usize)) {
        for (&v, &c) in self.values.iter().zip(self.cols) {
            f(v, c as usize);
        }
    }
}

/// One `NmPacked` row: `n` value slots per group of `m` columns, padding
/// slots skipped (their stored value is exactly 0.0).
#[derive(Clone, Copy)]
pub(crate) struct NmRowRun<'a> {
    pub values: &'a [f32],
    pub offsets: &'a [u8],
    pub n: usize,
    pub m: usize,
}

impl NnzRun for NmRowRun<'_> {
    #[inline(always)]
    fn for_each(self, mut f: impl FnMut(f32, usize)) {
        let groups = self.values.len() / self.n;
        for g in 0..groups {
            let base = g * self.m;
            let slot0 = g * self.n;
            for k in 0..self.n {
                let v = self.values[slot0 + k];
                if v == 0.0 {
                    continue;
                }
                f(v, base + self.offsets[slot0 + k] as usize);
            }
        }
    }
}

/// A dense coefficient row against consecutive xt rows — the fused
/// low-rank pass (`values = U[r, ·]`, xt = `T = Vt·Xᵀ`).
#[derive(Clone, Copy)]
pub(crate) struct DenseRun<'a> {
    pub values: &'a [f32],
}

impl NnzRun for DenseRun<'_> {
    #[inline(always)]
    fn for_each(self, mut f: impl FnMut(f32, usize)) {
        for (j, &v) in self.values.iter().enumerate() {
            f(v, j);
        }
    }
}

/// One lane of `L` batch columns starting at `col`: the `[f32; L]`
/// register accumulator streams the run once (`reg[l] += v · x[l]`, one
/// rounding per multiply-add, nonzeros in index order), then folds into
/// the row accumulator with one scaled store per element. `scale = 1.0`
/// is the f32 formats' identity fold; QBcsr passes its per-tile scale so
/// the raw i8 partial is scaled once per (row, tile), never in the loop.
#[inline(always)]
fn fold_lane<R: NnzRun, const L: usize>(
    run: R,
    xt: &Matrix,
    acc: &mut [f32],
    scale: f32,
    col: usize,
) {
    let mut reg = [0.0f32; L];
    run.for_each(|v, c| {
        let x = &xt.row(c)[col..col + L];
        for (r, &xv) in reg.iter_mut().zip(x) {
            *r += v * xv;
        }
    });
    for (a, &r) in acc[col..col + L].iter_mut().zip(reg.iter()) {
        *a += scale * r;
    }
}

/// Fold one row's nonzeros into its b-wide accumulator, lane-blocked:
/// widest lanes first, scalar (L = 1) tail. Every batch column sees the
/// identical operation sequence regardless of which lane covers it, so
/// the lane/tail split never changes results.
#[inline(always)]
fn fold_row_lanes<R: NnzRun>(run: R, xt: &Matrix, acc: &mut [f32], scale: f32) {
    let b = acc.len();
    let mut col = 0usize;
    while col + 16 <= b {
        fold_lane::<R, 16>(run, xt, acc, scale, col);
        col += 16;
    }
    while col + 8 <= b {
        fold_lane::<R, 8>(run, xt, acc, scale, col);
        col += 8;
    }
    while col + 4 <= b {
        fold_lane::<R, 4>(run, xt, acc, scale, col);
        col += 4;
    }
    while col < b {
        fold_lane::<R, 1>(run, xt, acc, scale, col);
        col += 1;
    }
}

/// Generates the per-format ISA dispatch: a portable entry plus (on
/// x86_64) a monomorphic `#[target_feature(enable = "avx2,fma")]` clone of
/// the same `#[inline(always)]` fold body. The clone's arithmetic is
/// operation-for-operation the generic path's — only the vectors widen.
macro_rules! isa_dispatch {
    ($(#[$doc:meta])* $name:ident, $avx2:ident, $run:ty) => {
        // SAFETY: `unsafe fn` solely because of `#[target_feature]` — the
        // body is the same safe portable fold, recompiled with wider
        // vectors. Callers must guarantee avx2+fma are actually available;
        // the dispatcher below is the only caller and checks `detected_isa`
        // first.
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $avx2(run: $run, xt: &Matrix, acc: &mut [f32], scale: f32) {
            fold_row_lanes(run, xt, acc, scale);
        }

        $(#[$doc])*
        #[cfg(target_arch = "x86_64")]
        #[inline]
        pub(crate) fn $name(isa: Isa, run: $run, xt: &Matrix, acc: &mut [f32], scale: f32) {
            match isa {
                // SAFETY: `Isa::Avx2Fma` is only ever produced by
                // `detected_isa` after `is_x86_feature_detected!` confirmed
                // both features on this CPU (`active_isa` never upgrades an
                // override past detection).
                Isa::Avx2Fma => unsafe { $avx2(run, xt, acc, scale) },
                Isa::Generic => fold_row_lanes(run, xt, acc, scale),
            }
        }

        $(#[$doc])*
        #[cfg(not(target_arch = "x86_64"))]
        #[inline]
        pub(crate) fn $name(_isa: Isa, run: $run, xt: &Matrix, acc: &mut [f32], scale: f32) {
            fold_row_lanes(run, xt, acc, scale);
        }
    };
}

isa_dispatch!(
    /// Lane-blocked fold of an f32 tile-local run (`Bcsr`).
    fold_f32_tile, fold_f32_tile_avx2, F32TileRun<'_>
);
isa_dispatch!(
    /// Lane-blocked fold of an i8 tile-local run (`QBcsr`; pass the tile scale).
    fold_i8_tile, fold_i8_tile_avx2, I8TileRun<'_>
);
isa_dispatch!(
    /// Lane-blocked fold of a global-index CSR row.
    fold_global_csr, fold_global_csr_avx2, GlobalCsrRun<'_>
);
isa_dispatch!(
    /// Lane-blocked fold of a packed N:M row (padding slots skipped).
    fold_nm_row, fold_nm_row_avx2, NmRowRun<'_>
);
isa_dispatch!(
    /// Lane-blocked fold of a dense coefficient row (the low-rank pass).
    fold_dense, fold_dense_avx2, DenseRun<'_>
);

/// A batched sparse format the tile-walk engine can drive. Implementors
/// only describe their geometry and how to fold one row tile's sparse term
/// into a local accumulator; the engine owns parallelism, the fused
/// low-rank pass, and the output scatter.
pub(crate) trait TileWalk: Sync {
    /// Output rows of the operator (`A` is out × in).
    fn out_rows(&self) -> usize;
    /// Input columns (`xt` must be `[in_cols × b]`).
    fn in_cols(&self) -> usize;
    /// Rows per tile of the parallel row-tile loop.
    fn walk_row_tile(&self) -> usize;
    /// Stored nonzeros — the thread gate's work estimate.
    fn nnz_count(&self) -> usize;
    /// Fold the sparse term for output rows `r0..r1` into `acc`
    /// `[(r1-r0) × b]` (zero-initialized), dispatching the b-wide axpys
    /// through the `isa` lane kernels. `r0` is always a multiple of
    /// [`TileWalk::walk_row_tile`].
    fn fold_tile(&self, r0: usize, r1: usize, xt: &Matrix, acc: &mut [f32], isa: Isa);
}

/// The stripe walk shared by the `Bcsr`/`QBcsr` [`TileWalk::fold_tile`]
/// impls: for each non-empty column tile of the row stripe, slice every
/// local-CSR row's nonzero run boundaries out of `indptr` and hand
/// `(tile, lo, hi, column base, b-wide accumulator lane)` to the format's
/// `fold` closure, which borrows its run storage from the tile and
/// dispatches the lane kernel. Keeping the walk here means the two tile
/// formats cannot drift apart on stripe indexing or lane offsets — only
/// the run type (f32 vs i8 + per-tile scale) differs between them.
pub(crate) fn fold_tile_stripe<'t, T: 't>(
    n_ct: usize,
    col_tile: usize,
    tile_rows: usize,
    b: usize,
    acc: &mut [f32],
    tile_at: impl Fn(usize) -> &'t T,
    indptr: impl Fn(&'t T) -> &'t [u32],
    mut fold: impl FnMut(&'t T, usize, usize, usize, &mut [f32]),
) {
    for ct in 0..n_ct {
        let tile = tile_at(ct);
        let ip = indptr(tile);
        // `ip[tile_rows]` is the tile's total nonzero count.
        if ip[tile_rows] == 0 {
            continue;
        }
        let c0 = ct * col_tile;
        for lr in 0..tile_rows {
            let (lo, hi) = (ip[lr] as usize, ip[lr + 1] as usize);
            if lo == hi {
                continue;
            }
            fold(tile, lo, hi, c0, &mut acc[lr * b..(lr + 1) * b]);
        }
    }
}

/// The one tile-walk engine: writes `out[b × rows] = X·Aᵀ (+ (X·Vtᵀ)·Uᵀ)`
/// for any [`TileWalk`] source.
///
/// `xt` is the pre-transposed activation block `[cols × b]`; when
/// `low_rank = Some((u, t))`, `u` is the out×r factor and `t = Vt·Xᵀ`
/// `[r × b]` — its contribution is added inside the same row-tile pass, so
/// every output element is produced (sparse plus low-rank) in one write.
/// Row tiles are independent and fan out across threads once
/// `b·nnz ≥ 2²⁰` (thread count cached process-wide, no per-call syscall).
pub(crate) fn fused_tile_walk<S: TileWalk>(
    src: &S,
    xt: &Matrix,
    low_rank: Option<(&Matrix, &Matrix)>,
    out: &mut Matrix,
) {
    let b = xt.cols;
    let n_out = src.out_rows();
    assert_eq!(xt.rows, src.in_cols(), "tile walk: xt must be [cols × b]");
    assert_eq!((out.rows, out.cols), (b, n_out), "tile walk: out must be [b × rows]");
    if let Some((u, t)) = low_rank {
        assert_eq!((u.rows, u.cols), (n_out, t.rows), "tile walk: U shape");
        assert_eq!(t.cols, b, "tile walk: T shape");
    }
    let row_tile = src.walk_row_tile();
    let n_rt = n_out.div_ceil(row_tile).max(1);
    let threads = if b * src.nnz_count() >= PARALLEL_MIN_WORK { available_threads() } else { 1 };
    // Dispatch is decided here, on the caller's thread, so the bench/test
    // override applies even though tiles run on scoped workers.
    let isa = active_isa();
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    parallel_for(threads, n_rt, |rt| {
        let r0 = rt * row_tile;
        let r1 = (r0 + row_tile).min(n_out);
        let tr = r1 - r0;
        // Local accumulator [tr × b]: stays cache-resident across the
        // sparse fold and the low-rank pass.
        let mut acc = vec![0.0f32; tr * b];
        src.fold_tile(r0, r1, xt, &mut acc, isa);
        if let Some((u, t)) = low_rank {
            // acc[lr, ·] += Σ_j U[r0+lr, j] · T[j, ·] — the same lane
            // kernels carry the rank-space term.
            for lr in 0..tr {
                let run = DenseRun { values: u.row(r0 + lr) };
                fold_dense(isa, run, t, &mut acc[lr * b..(lr + 1) * b], 1.0);
            }
        }
        // Scatter the tile to the [b × rows] output layout — the single
        // unsafe write shared by every sparse format.
        let op = out_ptr;
        for lr in 0..tr {
            for (bi, &av) in acc[lr * b..(lr + 1) * b].iter().enumerate() {
                // SAFETY: row tiles own disjoint column ranges of `out`
                // (r0..r1 never overlaps between `parallel_for` items), so
                // every (bi, r0+lr) address is written by exactly one
                // worker, and `out` outlives the scoped threads.
                unsafe { *op.0.add(bi * n_out + r0 + lr) = av };
            }
        }
    });
}

/// Fused batched forward `C = X·Aᵀ (+ X·(U·Vt)ᵀ)` with scratch and output
/// taken from a fresh throwaway [`Workspace`] — the convenience entry for
/// callers without a persistent workspace.
pub(crate) fn fused_forward<S: TileWalk>(
    src: &S,
    low_rank: Option<&LowRank>,
    x: &Matrix,
) -> Matrix {
    fused_forward_ws(src, low_rank, x, &mut Workspace::new())
}

/// [`fused_forward`] against a caller-owned [`Workspace`]: the Xᵀ panel,
/// the rank-space projection `T = Vt·Xᵀ`, and the output all come from the
/// pool, so a serving loop that keeps `ws` across steps allocates nothing
/// once shapes have been seen.
pub(crate) fn fused_forward_ws<S: TileWalk>(
    src: &S,
    low_rank: Option<&LowRank>,
    x: &Matrix,
    ws: &mut Workspace,
) -> Matrix {
    assert_eq!(x.cols, src.in_cols(), "fused kernel dim mismatch");
    let xt = ws.transposed(x);
    // Uninit is safe here: the tile-walk scatter writes every (bi, row)
    // element exactly once, and `matmul_into` zero-fills `t` itself.
    let mut out = ws.matrix_uninit(x.rows, src.out_rows());
    match low_rank {
        Some(lr) => {
            let mut t = ws.matrix_uninit(lr.vt.rows, xt.cols);
            crate::tensor::matmul_into(&lr.vt, &xt, &mut t);
            fused_tile_walk(src, &xt, Some((&lr.u, &t)), &mut out);
            ws.recycle(t);
        }
        None => fused_tile_walk(src, &xt, None, &mut out),
    }
    ws.recycle(xt);
    out
}

/// A pool of recycled f32 buffers for the batched kernels and the serve
/// decode loop. `take` hands back the smallest pooled buffer whose
/// capacity fits (zero-filled to the requested length); `recycle` returns
/// a matrix's storage to the pool. Fresh heap allocations happen only when
/// nothing pooled fits, so a loop with stable shapes allocates only on its
/// first pass — [`Workspace::alloc_count`] is the regression telemetry.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    allocs: usize,
    reuses: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Fresh heap allocations so far (buffers created because nothing
    /// pooled had the capacity). Flat across iterations ⇒ steady state.
    pub fn alloc_count(&self) -> usize {
        self.allocs
    }

    /// Pool hits so far.
    pub fn reuse_count(&self) -> usize {
        self.reuses
    }

    /// A buffer of exactly `len` elements, best-fit from the pool. With
    /// `zero`, contents are zero-filled; without, a recycled checkout
    /// keeps whatever stale values it held (only freshly grown elements
    /// are written), so the steady-state cost is zero — reserved for
    /// consumers that overwrite every element before reading.
    fn take(&mut self, len: usize, zero: bool) -> Vec<f32> {
        let best = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, v)| v.capacity() >= len)
            .min_by_key(|(_, v)| v.capacity())
            .map(|(i, _)| i);
        match best {
            Some(i) => {
                let mut v = self.free.swap_remove(i);
                if zero {
                    v.clear();
                } else {
                    v.truncate(len);
                }
                v.resize(len, 0.0);
                self.reuses += 1;
                v
            }
            None => {
                self.allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// A zero-filled `rows × cols` matrix backed by pooled storage — for
    /// buffers that are accumulated into (e.g. attention context).
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols, true))
    }

    /// A `rows × cols` matrix backed by pooled storage with **arbitrary
    /// (stale) contents** — the hot-path variant for consumers that write
    /// every element before reading any (full scatters, `copy_from_slice`
    /// fills, the `*_into` GEMMs): it skips the per-checkout zero-fill
    /// [`Workspace::matrix`] pays.
    pub fn matrix_uninit(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols, false))
    }

    /// `xᵀ` backed by pooled storage (the shared tiled transpose writes
    /// every element, so the uninit checkout is safe).
    pub fn transposed(&mut self, x: &Matrix) -> Matrix {
        let mut t = self.matrix_uninit(x.cols, x.rows);
        x.transpose_into(&mut t);
        t
    }

    /// Return a matrix's storage to the pool.
    pub fn recycle(&mut self, m: Matrix) {
        self.free.push(m.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn workspace_recycles_instead_of_allocating() {
        let mut ws = Workspace::new();
        let a = ws.matrix(8, 16);
        assert_eq!(ws.alloc_count(), 1);
        ws.recycle(a);
        let b = ws.matrix(4, 8); // smaller: must reuse the pooled buffer
        assert_eq!(ws.alloc_count(), 1);
        assert_eq!(ws.reuse_count(), 1);
        assert!(b.data.iter().all(|&v| v == 0.0), "recycled buffer must be zeroed");
        ws.recycle(b);
        let c = ws.matrix(32, 32); // larger than anything pooled: fresh alloc
        assert_eq!(ws.alloc_count(), 2);
        ws.recycle(c);
    }

    #[test]
    fn workspace_best_fit_prefers_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let big = ws.matrix(64, 64);
        let small = ws.matrix(4, 4);
        ws.recycle(big);
        ws.recycle(small);
        let got = ws.matrix(2, 2);
        assert!(got.data.capacity() <= 16, "best fit must pick the small buffer");
        // The big buffer is still pooled for the next big request.
        let big2 = ws.matrix(64, 64);
        assert_eq!(ws.alloc_count(), 2, "64×64 must come from the pool");
        ws.recycle(got);
        ws.recycle(big2);
    }

    #[test]
    fn matrix_uninit_skips_the_zero_fill_but_matrix_still_zeroes() {
        let mut ws = Workspace::new();
        let mut a = ws.matrix(2, 2);
        a.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        ws.recycle(a);
        // The uninit checkout hands back the recycled storage as-is —
        // stale contents are the documented contract (callers overwrite).
        let b = ws.matrix_uninit(2, 2);
        assert_eq!(b.data, vec![1.0, 2.0, 3.0, 4.0]);
        ws.recycle(b);
        let c = ws.matrix(2, 2);
        assert!(c.data.iter().all(|&v| v == 0.0), "zeroed variant must still zero");
        ws.recycle(c);
        // A larger pooled buffer shrinks to the requested length with its
        // stale prefix intact — no fill beyond what resize must write.
        let mut e = ws.matrix(2, 4);
        e.data.copy_from_slice(&[9.0; 8]);
        ws.recycle(e);
        let d = ws.matrix_uninit(3, 2);
        assert_eq!(d.data, vec![9.0; 6]);
        ws.recycle(d);
    }

    #[test]
    fn workspace_transpose_matches_matrix_transpose() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(37, 23, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let t = ws.transposed(&x);
        assert_eq!(t, x.transpose());
    }

    #[test]
    fn isa_override_downgrades_and_restores() {
        let before = active_isa();
        let inside = with_isa(Isa::Generic, active_isa);
        assert_eq!(inside, Isa::Generic);
        assert_eq!(active_isa(), before, "override must restore");
        // An override can never upgrade past detection.
        let forced = with_isa(Isa::Avx2Fma, active_isa);
        assert_eq!(forced, detected_isa());
    }

    /// Naive reference: acc[col] += scale · Σ_i v_i · xt[c_i][col].
    fn naive_fold(vals: &[f32], cols: &[u16], base: usize, xt: &Matrix, scale: f32) -> Vec<f32> {
        let b = xt.cols;
        let mut acc = vec![0.0f32; b];
        for (a, colv) in acc.iter_mut().enumerate() {
            let mut s = 0.0f32;
            for (&v, &c) in vals.iter().zip(cols) {
                s += v * xt.row(base + c as usize)[a];
            }
            *colv += scale * s;
        }
        acc
    }

    #[test]
    fn lane_fold_matches_naive_across_widths() {
        let mut rng = Rng::new(9);
        for b in 1..=19 {
            let xt = Matrix::randn(12, b, 1.0, &mut rng);
            let vals: Vec<f32> = (0..7).map(|i| (i as f32 * 0.7).sin()).collect();
            let cols: Vec<u16> = vec![0, 2, 3, 5, 7, 9, 11];
            let run = F32TileRun { values: &vals, cols: &cols, base: 0 };
            let mut acc = vec![0.0f32; b];
            fold_f32_tile(active_isa(), run, &xt, &mut acc, 1.0);
            let want = naive_fold(&vals, &cols, 0, &xt, 1.0);
            for (g, w) in acc.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "b={b}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn simd_and_generic_folds_are_bit_identical() {
        let mut rng = Rng::new(11);
        let xt = Matrix::randn(30, 17, 1.0, &mut rng);
        let vals: Vec<f32> = (0..30).map(|i| (i as f32).cos()).collect();
        let cols: Vec<u16> = (0..30).collect();
        let run = F32TileRun { values: &vals, cols: &cols, base: 0 };
        let mut fast = vec![0.0f32; 17];
        fold_f32_tile(active_isa(), run, &xt, &mut fast, 0.5);
        let mut slow = vec![0.0f32; 17];
        fold_f32_tile(Isa::Generic, run, &xt, &mut slow, 0.5);
        assert_eq!(fast, slow, "SIMD clone must be bit-identical to the generic path");
    }
}
