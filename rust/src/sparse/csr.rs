//! Compressed-sparse-row matrices: the scalar, row-at-a-time baseline
//! kernel. The tiled [`crate::sparse::Bcsr`] format supersedes it on the
//! batched hot path; CSR remains the portable on-disk format
//! (`model/compressed_io.rs`) and the dispatch choice for small layers.

use super::microkernel::{self, GlobalCsrRun, Isa, TileWalk};
use crate::tensor::Matrix;

/// Output rows per parallel stripe of the batched CSR kernel.
const CSR_ROW_TILE: usize = 64;

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,  // rows+1
    pub indices: Vec<u32>, // nnz column ids
    pub values: Vec<f32>,  // nnz
}

impl Csr {
    /// Convert from dense, dropping exact zeros.
    pub fn from_dense(m: &Matrix) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                m.data[r * self.cols + self.indices[i] as usize] = self.values[i];
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// In-memory footprint of the packed representation (u32 indptr + u32
    /// column ids + f32 values).
    pub fn memory_bytes(&self) -> usize {
        4 * self.indptr.len() + 4 * self.indices.len() + 4 * self.values.len()
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// y = A·x (sparse matvec).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let mut acc = 0.0f32;
            for i in lo..hi {
                acc += self.values[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
    }

    /// C = X · Aᵀ for activations X [b × cols], routed through the shared
    /// [`microkernel`] tile-walk engine: the activation block is transposed
    /// once and each A row's nonzeros fold through the register-blocked
    /// lane kernels — the same Xᵀ-panel layout as the tiled formats, with
    /// global u32 column indices instead of tile-local u16 offsets.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        microkernel::fused_forward(self, None, x)
    }
}

/// The CSR side of the shared tile-walk engine: one global-index run per
/// output row. Parallelism, the fused low-rank pass, and the output
/// scatter live in [`microkernel::fused_tile_walk`].
impl TileWalk for Csr {
    fn out_rows(&self) -> usize {
        self.rows
    }

    fn in_cols(&self) -> usize {
        self.cols
    }

    fn walk_row_tile(&self) -> usize {
        CSR_ROW_TILE
    }

    fn nnz_count(&self) -> usize {
        self.values.len()
    }

    fn fold_tile(&self, r0: usize, r1: usize, xt: &Matrix, acc: &mut [f32], isa: Isa) {
        let b = xt.cols;
        for (lr, r) in (r0..r1).enumerate() {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            if lo == hi {
                continue;
            }
            let run = GlobalCsrRun { values: &self.values[lo..hi], cols: &self.indices[lo..hi] };
            microkernel::fold_global_csr(isa, run, xt, &mut acc[lr * b..(lr + 1) * b], 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    #[test]
    fn csr_roundtrip_prop() {
        check("csr dense roundtrip", 30, |g| {
            let rows = g.usize_range(1, 30);
            let cols = g.usize_range(1, 30);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.7, &mut rng);
            let csr = Csr::from_dense(&m);
            assert_eq!(csr.to_dense(), m);
            assert_eq!(csr.nnz(), m.nnz());
        });
    }

    #[test]
    fn csr_matvec_matches_dense() {
        check("csr matvec == dense", 30, |g| {
            let rows = g.usize_range(1, 40);
            let cols = g.usize_range(1, 40);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let x = g.vec_normal(cols, 1.0);
            let csr = Csr::from_dense(&m);
            let mut y = vec![0.0; rows];
            csr.matvec(&x, &mut y);
            let yd = crate::tensor::matvec(&m, &x);
            for (a, b) in y.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn csr_matmul_xt_matches_dense() {
        let mut rng = Rng::new(2);
        let w = random_sparse(17, 23, 0.7, &mut rng);
        let x = Matrix::randn(5, 23, 1.0, &mut rng);
        let csr = Csr::from_dense(&w);
        let got = csr.matmul_xt(&x);
        let want = crate::tensor::matmul_bt(&x, &w);
        assert!(got.fro_dist(&want) < 1e-4);
    }
}
