//! Compressed-sparse-row matrices: the scalar, row-at-a-time baseline
//! kernel. The tiled [`crate::sparse::Bcsr`] format supersedes it on the
//! batched hot path; CSR remains the portable on-disk format
//! (`model/compressed_io.rs`) and the dispatch choice for small layers.

use crate::tensor::Matrix;
use crate::util::threadpool::{parallel_for, SendPtr};

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,  // rows+1
    pub indices: Vec<u32>, // nnz column ids
    pub values: Vec<f32>,  // nnz
}

impl Csr {
    /// Convert from dense, dropping exact zeros.
    pub fn from_dense(m: &Matrix) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                m.data[r * self.cols + self.indices[i] as usize] = self.values[i];
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// y = A·x (sparse matvec).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let lo = self.indptr[r] as usize;
            let hi = self.indptr[r + 1] as usize;
            let mut acc = 0.0f32;
            for i in lo..hi {
                acc += self.values[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
    }

    /// C = X · Aᵀ for activations X [b × cols]: each output row c_i gets the
    /// sparse dot of A's rows against x_i. This is the layout linear layers
    /// use (W stored out×in, activations row-major), so A-row values stream
    /// sequentially while X rows stay cache-resident.
    pub fn matmul_xt(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols, "csr matmul_xt dim mismatch");
        let mut out = Matrix::zeros(x.rows, self.rows);
        let threads = if x.rows * self.nnz() >= (1 << 20) {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            1
        };
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let n_out = self.rows;
        parallel_for(threads, x.rows, |b| {
            let xrow = x.row(b);
            let op = out_ptr;
            // SAFETY: each b writes a disjoint output row.
            let orow = unsafe { std::slice::from_raw_parts_mut(op.0.add(b * n_out), n_out) };
            for r in 0..n_out {
                let lo = self.indptr[r] as usize;
                let hi = self.indptr[r + 1] as usize;
                let mut acc = 0.0f32;
                let idx = &self.indices[lo..hi];
                let val = &self.values[lo..hi];
                for (&c, &v) in idx.iter().zip(val) {
                    acc += v * xrow[c as usize];
                }
                orow[r] = acc;
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    #[test]
    fn csr_roundtrip_prop() {
        check("csr dense roundtrip", 30, |g| {
            let rows = g.usize_range(1, 30);
            let cols = g.usize_range(1, 30);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.7, &mut rng);
            let csr = Csr::from_dense(&m);
            assert_eq!(csr.to_dense(), m);
            assert_eq!(csr.nnz(), m.nnz());
        });
    }

    #[test]
    fn csr_matvec_matches_dense() {
        check("csr matvec == dense", 30, |g| {
            let rows = g.usize_range(1, 40);
            let cols = g.usize_range(1, 40);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let m = random_sparse(rows, cols, 0.6, &mut rng);
            let x = g.vec_normal(cols, 1.0);
            let csr = Csr::from_dense(&m);
            let mut y = vec![0.0; rows];
            csr.matvec(&x, &mut y);
            let yd = crate::tensor::matvec(&m, &x);
            for (a, b) in y.iter().zip(&yd) {
                assert!((a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn csr_matmul_xt_matches_dense() {
        let mut rng = Rng::new(2);
        let w = random_sparse(17, 23, 0.7, &mut rng);
        let x = Matrix::randn(5, 23, 1.0, &mut rng);
        let csr = Csr::from_dense(&w);
        let got = csr.matmul_xt(&x);
        let want = crate::tensor::matmul_bt(&x, &w);
        assert!(got.fro_dist(&want) < 1e-4);
    }
}
