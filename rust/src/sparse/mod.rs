//! Sparse and structured weight formats for the compressed serving engine,
//! plus the kernel-dispatch layer that picks between them.
//!
//! This module is the DeepSparse substitute (DESIGN.md §3): Table 7's CPU
//! speedups are reproduced by executing compressed layers through these
//! kernels instead of dense GEMM. The tree:
//!
//! * [`csr`] — scalar compressed-sparse-row baseline (row-at-a-time).
//! * [`bcsr`] — tiled block-CSR: cache-sized row/column tiles with a
//!   batch-vectorized `X·Aᵀ` kernel that streams the weight values once per
//!   batch instead of once per activation row.
//! * [`nm`] — N:M semi-structured patterns and their packed kernel.
//! * [`lowrank`] — `U·Vᵀ` factor pairs.
//! * [`spl`] — the OATS `S + U·Vᵀ` composite, including the fused
//!   sparse-plus-low-rank kernel.
//! * [`quant`] — [`QBcsr`]: i8-quantized BCSR tiles with per-tile f32
//!   scales, the opt-in compression axis the planner gates on measured
//!   quantization error.
//! * [`microkernel`] — the shared tile-walk engine (row-tile parallel
//!   loop, fused low-rank pass, the single unsafe output scatter, and the
//!   `b·nnz` thread gate) plus the register-blocked SIMD lane kernels
//!   every batched format above folds through, and the recycled-buffer
//!   [`Workspace`] the serve decode loop reuses across steps.
//! * [`plan`] — [`KernelPlan`]: picks dense/CSR/BCSR/QBcsr/N:M per layer
//!   from measured nnz density, shape, and (for the i8 upgrade) per-tile
//!   quantization error, and [`PackedLinear`], the pre-packed executable
//!   form the serving engine runs.

pub mod bcsr;
pub mod csr;
pub mod lowrank;
pub mod microkernel;
pub mod nm;
pub mod plan;
pub mod quant;
pub mod spl;

pub use bcsr::Bcsr;
pub use csr::Csr;
pub use lowrank::LowRank;
pub use microkernel::{Isa, Workspace};
pub use nm::{NmPacked, NmPattern};
pub use plan::{KernelChoice, KernelPlan, PackedLinear, PackedSparse, SliceMeta};
pub use plan::{PackOptions, QuantGate, QBCSR_MAX_REL_ERROR};
pub use quant::QBcsr;
pub use spl::SparsePlusLowRank;

/// Cost model used for the N:M / acceleration analyses (Figure 2, DESIGN.md
/// §5): effective FLOPs + bytes moved for one application of the layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerCost {
    pub flops: f64,
    pub bytes: f64,
}

/// Dense layer cost for a single token.
pub fn dense_cost(dout: usize, din: usize) -> LayerCost {
    LayerCost { flops: 2.0 * dout as f64 * din as f64, bytes: 4.0 * (dout * din) as f64 }
}

/// Sparse+low-rank cost for a single token: CSR nnz MACs (with index
/// overhead) plus two dense skinny products.
pub fn spl_cost(nnz: usize, dout: usize, din: usize, rank: usize) -> LayerCost {
    let lr_flops = 2.0 * rank as f64 * (dout + din) as f64;
    LayerCost {
        flops: 2.0 * nnz as f64 + lr_flops,
        bytes: 8.0 * nnz as f64 + 4.0 * (rank * (dout + din)) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_orders_correctly() {
        // At 50% unstructured sparsity vs 25% sparse + rank putting same params,
        // the low-rank variant should do fewer raw bytes per useful FLOP... we
        // just sanity check monotonicity here.
        let d = dense_cost(1024, 1024);
        let s = spl_cost(524_288, 1024, 1024, 0);
        assert!(s.flops < d.flops);
        let s2 = spl_cost(262_144, 1024, 1024, 128);
        assert!(s2.flops < d.flops);
    }
}
