//! Low-rank factor pairs L = U · Vᵀ, stored factored exactly as the paper
//! does (Section 2.1) to cut memory.

use crate::tensor::{matmul, Matrix};

/// Low-rank factor pair L = U · Vt (U: out×r, Vt: r×in).
#[derive(Clone, Debug, PartialEq)]
pub struct LowRank {
    pub u: Matrix,  // out × r
    pub vt: Matrix, // r × in
}

impl LowRank {
    pub fn rank(&self) -> usize {
        self.u.cols
    }

    pub fn to_dense(&self) -> Matrix {
        matmul(&self.u, &self.vt)
    }

    /// Parameter count of the factorization.
    pub fn params(&self) -> usize {
        self.u.rows * self.u.cols + self.vt.rows * self.vt.cols
    }

    /// y += U (Vt x): two skinny matvecs, O((out+in)·r).
    pub fn apply_accumulate(&self, x: &[f32], y: &mut [f32]) {
        let r = self.rank();
        let mut t = vec![0.0f32; r];
        for i in 0..r {
            let vrow = self.vt.row(i);
            let mut acc = 0.0f32;
            for (a, b) in vrow.iter().zip(x) {
                acc += a * b;
            }
            t[i] = acc;
        }
        for (row, yv) in y.iter_mut().enumerate() {
            let urow = self.u.row(row);
            let mut acc = 0.0f32;
            for (a, b) in urow.iter().zip(&t) {
                acc += a * b;
            }
            *yv += acc;
        }
    }

    /// C += X·(U Vt)ᵀ = (X·Vtᵀ)·Uᵀ — batched form, two dense skinny GEMMs.
    pub fn apply_batch_accumulate(&self, x: &Matrix, out: &mut Matrix) {
        // t = X · Vtᵀ : [b × r]
        let t = crate::tensor::matmul_bt(x, &self.vt);
        // out += t · Uᵀ : [b × out]
        let contrib = crate::tensor::matmul_bt(&t, &self.u);
        out.axpy(1.0, &contrib);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn lowrank_apply_matches_dense() {
        let mut rng = Rng::new(3);
        let lr = LowRank {
            u: Matrix::randn(12, 3, 1.0, &mut rng),
            vt: Matrix::randn(3, 9, 1.0, &mut rng),
        };
        let x: Vec<f32> = (0..9).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0; 12];
        lr.apply_accumulate(&x, &mut y);
        let dense = lr.to_dense();
        let want = crate::tensor::matvec(&dense, &x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lowrank_batch_matches_single() {
        let mut rng = Rng::new(4);
        let lr = LowRank {
            u: Matrix::randn(8, 2, 1.0, &mut rng),
            vt: Matrix::randn(2, 6, 1.0, &mut rng),
        };
        let x = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut batch = Matrix::zeros(4, 8);
        lr.apply_batch_accumulate(&x, &mut batch);
        for b in 0..4 {
            let mut y = vec![0.0; 8];
            lr.apply_accumulate(x.row(b), &mut y);
            for (a, &bv) in y.iter().zip(batch.row(b)) {
                assert!((a - bv).abs() < 1e-4);
            }
        }
    }
}
