//! The kernel-dispatch layer: picks the execution format for each compressed
//! layer from its measured nnz density and shape, and owns the pre-packed
//! executable form ([`PackedLinear`]) the serving engine runs.
//!
//! Selection policy (see README "Kernel dispatch" for the rationale):
//!
//! 1. **Dense** when density > [`DENSE_DENSITY_CUTOFF`] — index-carrying
//!    formats pay ≥ 2–8 bytes of index per nonzero, so near-dense layers run
//!    faster through plain GEMM.
//! 2. **N:M packed** when the weight exactly satisfies a known N:M pattern
//!    *and* the slots would be well utilized (≥ [`NM_MIN_UTILIZATION`]) —
//!    a 90 %-sparse matrix trivially validates 2:4 but would waste most of
//!    its slots.
//! 3. **BCSR** when the layer is big enough to tile
//!    (≥ [`BCSR_MIN_ELEMENTS`] entries) *and* the expected batch is
//!    ≥ [`BCSR_MIN_BATCH`] — the batched cache-tiled kernel (its edge over
//!    scalar CSR is amortizing weight streaming across the batch).
//! 4. **CSR** otherwise (small layers or single-stream decode).
//!
//! On top of the layout ladder sits the first accuracy/speed arbitration:
//! when packing opts into i8 tiles ([`PackOptions::quantize`]), a
//! BCSR-planned layer is quantized and upgraded to **QBcsr** only if its
//! measured per-tile relative quantization error stays within the
//! configured bound ([`QuantGate`]); otherwise the plan falls back to f32
//! BCSR and records the rejected error for telemetry.

use super::bcsr::Bcsr;
use super::csr::Csr;
use super::lowrank::LowRank;
use super::microkernel::{self, Workspace};
use super::nm::{NmPacked, NmPattern};
use super::quant::QBcsr;
use super::spl::SparsePlusLowRank;
use crate::compress::slice::SliceMap;
use crate::tensor::Matrix;
use crate::util::trace;

/// Arg tags for a `kernel_*` dispatch span.
fn kernel_tags(nnz: usize, batch: usize, bytes: usize) -> [(&'static str, f64); 3] {
    [("nnz", nnz as f64), ("batch", batch as f64), ("bytes", bytes as f64)]
}

/// Above this density the dense GEMM path wins over index-based formats.
pub const DENSE_DENSITY_CUTOFF: f64 = 0.7;
/// Minimum `density / pattern_density` for the N:M packed format (slot
/// utilization; below this CSR/BCSR carry fewer wasted slots).
pub const NM_MIN_UTILIZATION: f64 = 0.7;
/// Minimum rows·cols for BCSR — smaller layers stay CSR.
pub const BCSR_MIN_ELEMENTS: usize = 1 << 14;
/// Minimum expected batch for BCSR — its win over scalar CSR is amortizing
/// weight streaming over the batch; single-stream decode keeps CSR.
pub const BCSR_MIN_BATCH: usize = 2;
/// Default per-tile relative Frobenius quantization-error bound for the i8
/// upgrade: above this the plan keeps f32 BCSR.
pub const QBCSR_MAX_REL_ERROR: f64 = 0.05;

/// N:M patterns the planner probes, tightest (sparsest) first.
const NM_CANDIDATES: [NmPattern; 2] = [NmPattern::TWO_EIGHT, NmPattern::TWO_FOUR];

/// Which kernel family a layer executes through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    Dense,
    Csr,
    Bcsr,
    /// i8-quantized BCSR tiles with per-tile f32 scales.
    QBcsr,
    Nm { n: usize, m: usize },
    /// Structurally sliced dense weight (rotate-and-slice): plain GEMM on a
    /// SMALLER matrix. Never chosen by the density ladder — it enters only
    /// through [`PackedLinear::from_sliced`], because the win is the shrunken
    /// shape, not the storage format.
    SlicedDense,
}

impl KernelChoice {
    pub fn name(&self) -> String {
        match self {
            KernelChoice::Dense => "dense".into(),
            KernelChoice::Csr => "csr".into(),
            KernelChoice::Bcsr => "bcsr".into(),
            KernelChoice::QBcsr => "qbcsr".into(),
            KernelChoice::Nm { n, m } => format!("{n}:{m}"),
            KernelChoice::SlicedDense => "sliced".into(),
        }
    }
}

/// The i8-upgrade arbitration input: the measured per-tile relative
/// quantization error of the candidate [`QBcsr`] packing, against the
/// configured bound. Only a BCSR-planned layer consults the gate.
#[derive(Clone, Copy, Debug)]
pub struct QuantGate {
    /// Worst per-tile relative Frobenius error, measured at pack time.
    pub rel_error: f64,
    /// Maximum acceptable error; above it the plan keeps f32 BCSR.
    pub bound: f64,
}

/// How to pack a layer: the expected batch shape plus the (opt-in) i8
/// quantization policy.
#[derive(Clone, Copy, Debug)]
pub struct PackOptions {
    /// Expected batch size (1 = decode-only).
    pub batch_hint: usize,
    /// Quantize BCSR-planned layers to i8 tiles (gated on measured error).
    pub quantize: bool,
    /// Per-tile relative error bound for the quantization gate.
    pub max_quant_rel_error: f64,
}

impl Default for PackOptions {
    fn default() -> Self {
        PackOptions { batch_hint: 1, quantize: false, max_quant_rel_error: QBCSR_MAX_REL_ERROR }
    }
}

impl PackOptions {
    /// f32-only packing for the given batch shape (the historical default).
    pub fn for_batch(batch_hint: usize) -> PackOptions {
        PackOptions { batch_hint, ..Default::default() }
    }

    /// i8-opt-in packing with the default error gate.
    pub fn quantized(batch_hint: usize) -> PackOptions {
        PackOptions { batch_hint, quantize: true, ..Default::default() }
    }
}

/// A per-layer execution plan, derived at load/pack time.
#[derive(Clone, Debug)]
pub struct KernelPlan {
    pub choice: KernelChoice,
    /// Measured nnz density of the sparse term.
    pub density: f64,
    pub rows: usize,
    pub cols: usize,
    /// Expected batch size the plan was made for (1 = decode-only).
    pub batch_hint: usize,
    /// Measured per-tile relative quantization error, when i8 quantization
    /// was evaluated — recorded whether the gate accepted (choice QBcsr) or
    /// rejected it (choice stays Bcsr), for telemetry.
    pub quant_rel_error: Option<f64>,
}

impl KernelPlan {
    /// Decide a format from measured shape + density (+ optional exact N:M
    /// structure detected by the caller). When `quant` carries a measured
    /// quantization error, a BCSR choice upgrades to QBcsr only if the
    /// error is within the gate's bound — the dispatch layer's first
    /// accuracy/speed arbitration.
    pub fn choose(
        rows: usize,
        cols: usize,
        nnz: usize,
        nm: Option<NmPattern>,
        batch_hint: usize,
        quant: Option<QuantGate>,
    ) -> KernelPlan {
        let elems = (rows * cols).max(1);
        let density = nnz as f64 / elems as f64;
        let mut choice = if density > DENSE_DENSITY_CUTOFF {
            KernelChoice::Dense
        } else if let Some(p) = nm.filter(|p| {
            let pattern_density = p.n as f64 / p.m as f64;
            density / pattern_density >= NM_MIN_UTILIZATION
        }) {
            KernelChoice::Nm { n: p.n, m: p.m }
        } else if elems >= BCSR_MIN_ELEMENTS && batch_hint >= BCSR_MIN_BATCH {
            KernelChoice::Bcsr
        } else {
            KernelChoice::Csr
        };
        let mut quant_rel_error = None;
        if let (KernelChoice::Bcsr, Some(g)) = (choice, quant) {
            quant_rel_error = Some(g.rel_error);
            if g.rel_error <= g.bound {
                choice = KernelChoice::QBcsr;
            }
        }
        KernelPlan { choice, density, rows, cols, batch_hint, quant_rel_error }
    }

    /// One-line human-readable summary (serving startup logs). Includes the
    /// measured quantization error whenever the i8 gate was consulted, so
    /// gate rejections are visible.
    pub fn describe(&self) -> String {
        let qerr = match self.quant_rel_error {
            Some(e) => format!(" qerr {e:.4}"),
            None => String::new(),
        };
        format!(
            "{}x{} density {:.2} batch {} -> {}{qerr}",
            self.rows,
            self.cols,
            self.density,
            self.batch_hint,
            self.choice.name()
        )
    }
}

/// Probe a dense view for an exactly-satisfied, well-utilized N:M pattern.
fn detect_nm(w: &Matrix, nnz: usize) -> Option<NmPattern> {
    let density = nnz as f64 / (w.rows * w.cols).max(1) as f64;
    NM_CANDIDATES
        .iter()
        .copied()
        .find(|p| density / (p.n as f64 / p.m as f64) >= NM_MIN_UTILIZATION && p.validates(w))
}

/// [`detect_nm`] on CSR structure: the cheap density gate runs first, and
/// the full scan is `validates_csr` (O(nnz), no dense temporary).
fn detect_nm_csr(csr: &Csr) -> Option<NmPattern> {
    let density = csr.nnz() as f64 / (csr.rows * csr.cols).max(1) as f64;
    NM_CANDIDATES.iter().copied().find(|p| {
        density / (p.n as f64 / p.m as f64) >= NM_MIN_UTILIZATION && p.validates_csr(csr)
    })
}

/// Evaluate the i8 upgrade for a BCSR-planned layer: quantize, measure the
/// per-tile error, and let [`KernelPlan::choose`] arbitrate through a
/// [`QuantGate`]. Returns the quantized tiles when the gate accepts;
/// re-derives `plan` either way so the measured error lands in telemetry.
fn quantize_gated(
    bcsr: &Bcsr,
    nm: Option<NmPattern>,
    opts: &PackOptions,
    plan: &mut KernelPlan,
) -> Option<QBcsr> {
    if !opts.quantize {
        return None;
    }
    let q = QBcsr::quantize(bcsr);
    let gate = QuantGate { rel_error: q.max_tile_rel_error(), bound: opts.max_quant_rel_error };
    *plan = KernelPlan::choose(plan.rows, plan.cols, bcsr.nnz(), nm, opts.batch_hint, Some(gate));
    (plan.choice == KernelChoice::QBcsr).then_some(q)
}

/// The packed sparse term, in whichever format the plan selected.
#[derive(Clone, Debug)]
pub enum PackedSparse {
    Dense(Matrix),
    Csr(Csr),
    Bcsr(Bcsr),
    QBcsr(QBcsr),
    Nm(NmPacked),
}

/// Slice metadata carried by a packed sliced-dense layer: the index maps
/// from the sliced dims back into the original dense dims. The kernel never
/// consults them (it runs plain GEMM in the sliced shape); they exist for
/// re-serialization and original-shape rate accounting.
#[derive(Clone, Debug)]
pub struct SliceMeta {
    pub in_map: SliceMap,
    pub out_map: SliceMap,
}

/// A linear layer packed for execution: the planned sparse-term format plus
/// the (optional) low-rank term. This is what compressed checkpoints load
/// into and what the serving engine's batched decode runs.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub plan: KernelPlan,
    sparse: PackedSparse,
    low_rank: Option<LowRank>,
    slice: Option<SliceMeta>,
}

impl PackedLinear {
    /// Pack an OATS sparse-plus-low-rank layer.
    pub fn from_spl(spl: &SparsePlusLowRank, batch_hint: usize) -> PackedLinear {
        Self::from_spl_with(spl, &PackOptions::for_batch(batch_hint))
    }

    /// [`PackedLinear::from_spl`] with explicit packing options (the i8
    /// quantization opt-in path).
    pub fn from_spl_with(spl: &SparsePlusLowRank, opts: &PackOptions) -> PackedLinear {
        Self::from_csr_parts(&spl.sparse, spl.low_rank.clone(), opts)
    }

    /// Pack a sparse-only layer (Wanda/SparseGPT/magnitude outputs).
    pub fn from_csr(csr: &Csr, batch_hint: usize) -> PackedLinear {
        Self::from_csr_with(csr, &PackOptions::for_batch(batch_hint))
    }

    /// [`PackedLinear::from_csr`] with explicit packing options.
    pub fn from_csr_with(csr: &Csr, opts: &PackOptions) -> PackedLinear {
        Self::from_csr_parts(csr, None, opts)
    }

    fn from_csr_parts(csr: &Csr, low_rank: Option<LowRank>, opts: &PackOptions) -> PackedLinear {
        // Plan and pack straight from the CSR structure: the density-gated
        // N:M probe and the BCSR re-tiling are O(nnz); a dense temporary is
        // materialized only on the (rare) Dense / N:M plans that need one.
        let nm = detect_nm_csr(csr);
        let mut plan =
            KernelPlan::choose(csr.rows, csr.cols, csr.nnz(), nm, opts.batch_hint, None);
        let sparse = match plan.choice {
            KernelChoice::Dense => PackedSparse::Dense(csr.to_dense()),
            KernelChoice::Csr => PackedSparse::Csr(csr.clone()),
            KernelChoice::Bcsr => {
                let bcsr = Bcsr::from_csr(csr);
                match quantize_gated(&bcsr, nm, opts, &mut plan) {
                    Some(q) => PackedSparse::QBcsr(q),
                    None => PackedSparse::Bcsr(bcsr),
                }
            }
            // The base ladder never emits QBcsr directly; it only appears
            // via the gate above. SlicedDense only enters via from_sliced.
            KernelChoice::QBcsr => unreachable!("qbcsr requires the quantization gate"),
            KernelChoice::SlicedDense => unreachable!("sliced enters via from_sliced"),
            KernelChoice::Nm { n, m } => {
                match NmPacked::pack(&csr.to_dense(), NmPattern { n, m }) {
                    Some(packed) => PackedSparse::Nm(packed),
                    // Defensive: probe and packer disagreeing means a
                    // malformed checkpoint — degrade to the always-correct
                    // CSR form rather than panicking in the load path.
                    None => {
                        plan.choice = KernelChoice::Csr;
                        PackedSparse::Csr(csr.clone())
                    }
                }
            }
        };
        PackedLinear { plan, sparse, low_rank, slice: None }
    }

    /// Pack from a dense weight, sparsifying if the zero structure warrants.
    pub fn from_dense(w: &Matrix, batch_hint: usize) -> PackedLinear {
        Self::from_dense_with(w, &PackOptions::for_batch(batch_hint))
    }

    /// [`PackedLinear::from_dense`] with explicit packing options.
    pub fn from_dense_with(w: &Matrix, opts: &PackOptions) -> PackedLinear {
        let nnz = w.nnz();
        let nm = detect_nm(w, nnz);
        let mut plan = KernelPlan::choose(w.rows, w.cols, nnz, nm, opts.batch_hint, None);
        let sparse = match plan.choice {
            KernelChoice::Dense => PackedSparse::Dense(w.clone()),
            KernelChoice::Csr => PackedSparse::Csr(Csr::from_dense(w)),
            KernelChoice::Bcsr => {
                let bcsr = Bcsr::from_dense(w);
                match quantize_gated(&bcsr, nm, opts, &mut plan) {
                    Some(q) => PackedSparse::QBcsr(q),
                    None => PackedSparse::Bcsr(bcsr),
                }
            }
            KernelChoice::QBcsr => unreachable!("qbcsr requires the quantization gate"),
            KernelChoice::SlicedDense => unreachable!("sliced enters via from_sliced"),
            KernelChoice::Nm { n, m } => {
                let packed = NmPacked::pack(w, NmPattern { n, m })
                    .expect("detect_nm validated the pattern");
                PackedSparse::Nm(packed)
            }
        };
        PackedLinear { plan, sparse, low_rank: None, slice: None }
    }

    /// Pack a rotate-and-slice layer: a dense weight already in the SLICED
    /// shape plus the index maps back to the original dims. Bypasses the
    /// density ladder — the format is dense GEMM by construction; the win
    /// is the smaller shape (smaller Xᵀ panel, fewer output rows).
    pub fn from_sliced(
        w: &Matrix,
        in_map: SliceMap,
        out_map: SliceMap,
        batch_hint: usize,
    ) -> PackedLinear {
        Self::from_sliced_with(w, in_map, out_map, &PackOptions::for_batch(batch_hint))
    }

    /// [`PackedLinear::from_sliced`] with explicit packing options (only
    /// `batch_hint` applies — a sliced layer never quantizes).
    pub fn from_sliced_with(
        w: &Matrix,
        in_map: SliceMap,
        out_map: SliceMap,
        opts: &PackOptions,
    ) -> PackedLinear {
        assert_eq!(w.rows, out_map.len(), "weight rows vs out_map");
        assert_eq!(w.cols, in_map.len(), "weight cols vs in_map");
        let plan = KernelPlan {
            choice: KernelChoice::SlicedDense,
            density: w.nnz() as f64 / (w.rows * w.cols).max(1) as f64,
            rows: w.rows,
            cols: w.cols,
            batch_hint: opts.batch_hint,
            quant_rel_error: None,
        };
        PackedLinear {
            plan,
            sparse: PackedSparse::Dense(w.clone()),
            low_rank: None,
            slice: Some(SliceMeta { in_map, out_map }),
        }
    }

    pub fn sparse(&self) -> &PackedSparse {
        &self.sparse
    }

    pub fn low_rank(&self) -> Option<&LowRank> {
        self.low_rank.as_ref()
    }

    /// Slice metadata, present iff this layer was packed via `from_sliced`.
    pub fn slice(&self) -> Option<&SliceMeta> {
        self.slice.as_ref()
    }

    /// The shape the kernel executes (sliced dims for a sliced layer).
    pub fn shape(&self) -> (usize, usize) {
        (self.plan.rows, self.plan.cols)
    }

    /// The pre-compression dense shape — the rate-accounting denominator.
    pub fn original_shape(&self) -> (usize, usize) {
        match &self.slice {
            Some(s) => (s.out_map.full, s.in_map.full),
            None => self.shape(),
        }
    }

    /// Nonzero-parameter count (same accounting as the unpacked layer —
    /// a Dense-planned sparse layer still counts only its nonzeros, while
    /// a sliced layer stores and counts its full sliced dense block).
    pub fn param_count(&self) -> usize {
        if self.slice.is_some() {
            return self.plan.rows * self.plan.cols;
        }
        let sparse = match &self.sparse {
            PackedSparse::Dense(w) => w.nnz(),
            PackedSparse::Csr(c) => c.nnz(),
            PackedSparse::Bcsr(b) => b.nnz(),
            PackedSparse::QBcsr(q) => q.nnz(),
            PackedSparse::Nm(n) => n.nnz(),
        };
        sparse + self.low_rank.as_ref().map_or(0, |lr| lr.params())
    }

    /// Dense reconstruction (evaluation / re-serialization). A QBcsr term
    /// dequantizes — the round-off it carries is exactly what the plan gate
    /// bounded at pack time.
    pub fn to_dense(&self) -> Matrix {
        let mut d = match &self.sparse {
            PackedSparse::Dense(w) => w.clone(),
            PackedSparse::Csr(c) => c.to_dense(),
            PackedSparse::Bcsr(b) => b.to_dense(),
            PackedSparse::QBcsr(q) => q.to_dense(),
            PackedSparse::Nm(n) => n.to_dense(),
        };
        if let Some(lr) = &self.low_rank {
            d.axpy(1.0, &lr.to_dense());
        }
        d
    }

    /// Batched apply `C = X·Wᵀ` through the planned kernel.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.forward_ws(x, &mut Workspace::new())
    }

    /// [`PackedLinear::forward`] against a caller-owned [`Workspace`] —
    /// the serve decode path. The Xᵀ panel, the rank-space projection, and
    /// the output all come from the pool, so steady-state decode steps pay
    /// no fresh `transpose()`/`Matrix::zeros` heap allocations. Every
    /// sparse plan (CSR included) runs the fused tile-walk engine, so the
    /// low-rank term is folded in the same accumulator pass.
    pub fn forward_ws(&self, x: &Matrix, ws: &mut Workspace) -> Matrix {
        let lr = self.low_rank.as_ref();
        // Kernel spans are gated up front so a disabled dispatch pays one
        // relaxed load and never touches the size accessors.
        let traced = trace::enabled();
        match &self.sparse {
            PackedSparse::Bcsr(b) => {
                let _k = traced.then(|| {
                    trace::span_args("kernel_bcsr", &kernel_tags(b.nnz(), x.rows, b.memory_bytes()))
                });
                microkernel::fused_forward_ws(b, lr, x, ws)
            }
            PackedSparse::QBcsr(q) => {
                let _k = traced.then(|| {
                    trace::span_args(
                        "kernel_qbcsr",
                        &kernel_tags(q.nnz(), x.rows, q.memory_bytes()),
                    )
                });
                microkernel::fused_forward_ws(q, lr, x, ws)
            }
            PackedSparse::Csr(c) => {
                let _k = traced.then(|| {
                    trace::span_args("kernel_csr", &kernel_tags(c.nnz(), x.rows, c.memory_bytes()))
                });
                microkernel::fused_forward_ws(c, lr, x, ws)
            }
            PackedSparse::Nm(nm) => {
                let _k = traced.then(|| {
                    trace::span_args("kernel_nm", &kernel_tags(nm.nnz(), x.rows, nm.memory_bytes()))
                });
                microkernel::fused_forward_ws(nm, lr, x, ws)
            }
            PackedSparse::Dense(w) => {
                let _k = traced.then(|| {
                    // Stored-element count, not true nonzeros: counting
                    // zeros in a dense weight would scan it per dispatch.
                    let stored = w.rows * w.cols;
                    let tags = kernel_tags(stored, x.rows, 4 * stored);
                    // Sliced layers run the same GEMM but report their own
                    // span so per-kernel serve telemetry separates them.
                    if self.slice.is_some() {
                        trace::span_args("kernel_sliced", &tags)
                    } else {
                        trace::span_args("kernel_dense", &tags)
                    }
                });
                // Uninit is safe: matmul_bt_into overwrites every element.
                let mut out = ws.matrix_uninit(x.rows, w.rows);
                crate::tensor::matmul_bt_into(x, w, &mut out);
                if let Some(lr) = lr {
                    lr.apply_batch_accumulate(x, &mut out);
                }
                out
            }
        }
    }

    /// Single-row apply for the decode hot path.
    pub fn forward_vec(&self, x: &[f32], y: &mut [f32]) {
        match &self.sparse {
            PackedSparse::Dense(w) => {
                for (r, out) in y.iter_mut().enumerate() {
                    *out = crate::tensor::dot(w.row(r), x);
                }
            }
            PackedSparse::Csr(c) => c.matvec(x, y),
            PackedSparse::Bcsr(b) => b.matvec(x, y),
            PackedSparse::QBcsr(q) => q.matvec(x, y),
            PackedSparse::Nm(nm) => nm.matvec(x, y),
        }
        if let Some(lr) = &self.low_rank {
            lr.apply_accumulate(x, y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityPattern;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, random_sparse};

    #[test]
    fn plan_picks_dense_for_dense_layers() {
        let p = KernelPlan::choose(128, 128, 128 * 128, None, 8, None);
        assert_eq!(p.choice, KernelChoice::Dense);
        let p = KernelPlan::choose(128, 128, (128 * 128 * 9) / 10, None, 8, None);
        assert_eq!(p.choice, KernelChoice::Dense);
    }

    #[test]
    fn plan_picks_bcsr_for_large_sparse() {
        let p = KernelPlan::choose(256, 256, 256 * 256 / 2, None, 8, None);
        assert_eq!(p.choice, KernelChoice::Bcsr);
        assert!((p.density - 0.5).abs() < 1e-9);
    }

    #[test]
    fn plan_picks_csr_for_small_layers() {
        let p = KernelPlan::choose(32, 32, 300, None, 8, None);
        assert_eq!(p.choice, KernelChoice::Csr);
    }

    #[test]
    fn plan_picks_csr_for_single_stream_decode() {
        // Large + sparse, but batch 1: BCSR's batch amortization is gone.
        let p = KernelPlan::choose(256, 256, 256 * 256 / 2, None, 1, None);
        assert_eq!(p.choice, KernelChoice::Csr);
        assert_eq!(p.batch_hint, 1);
    }

    #[test]
    fn plan_prefers_nm_when_tight() {
        // Exactly 2:4-pruned layer: density 0.5, utilization 1.0.
        let p = KernelPlan::choose(256, 256, 256 * 256 / 2, Some(NmPattern::TWO_FOUR), 8, None);
        assert_eq!(p.choice, KernelChoice::Nm { n: 2, m: 4 });
        // 90 % sparse would waste slots: not N:M even though it validates.
        let p = KernelPlan::choose(256, 256, 256 * 256 / 10, Some(NmPattern::TWO_FOUR), 8, None);
        assert_eq!(p.choice, KernelChoice::Bcsr);
    }

    #[test]
    fn plan_quant_gate_arbitrates_bcsr_upgrade() {
        let nnz = 256 * 256 / 2;
        // Within the bound: the BCSR plan upgrades to i8 tiles.
        let ok = QuantGate { rel_error: 0.01, bound: QBCSR_MAX_REL_ERROR };
        let p = KernelPlan::choose(256, 256, nnz, None, 8, Some(ok));
        assert_eq!(p.choice, KernelChoice::QBcsr);
        assert_eq!(p.quant_rel_error, Some(0.01));
        // Over the bound: fall back to f32 BCSR, error still recorded.
        let bad = QuantGate { rel_error: 0.2, bound: QBCSR_MAX_REL_ERROR };
        let p = KernelPlan::choose(256, 256, nnz, None, 8, Some(bad));
        assert_eq!(p.choice, KernelChoice::Bcsr);
        assert_eq!(p.quant_rel_error, Some(0.2));
        assert!(p.describe().contains("qerr"));
        // The gate only applies to BCSR-planned layers: a small layer stays
        // CSR even with a passing gate.
        let p = KernelPlan::choose(32, 32, 300, None, 8, Some(ok));
        assert_eq!(p.choice, KernelChoice::Csr);
        assert_eq!(p.quant_rel_error, None);
    }

    #[test]
    fn packed_quantized_upgrades_and_gates() {
        // Well-behaved random weights quantize within the default bound.
        let mut rng = Rng::new(12);
        let w = random_sparse(128, 256, 0.45, &mut rng);
        let q = PackedLinear::from_csr_with(&Csr::from_dense(&w), &PackOptions::quantized(8));
        assert_eq!(q.plan.choice, KernelChoice::QBcsr);
        assert!(q.plan.quant_rel_error.unwrap() <= QBCSR_MAX_REL_ERROR);
        assert!(q.plan.describe().contains("qbcsr"));
        assert_eq!(q.param_count(), w.nnz());

        // Outlier-dominated weights trip the per-tile gate: one huge value
        // makes the i8 step so coarse the 0.3s collapse to zero (see
        // `prop::outlier_dominated`).
        let w = crate::util::prop::outlier_dominated(128, 256);
        let g = PackedLinear::from_csr_with(&Csr::from_dense(&w), &PackOptions::quantized(8));
        assert_eq!(g.plan.choice, KernelChoice::Bcsr, "error gate must fall back to f32");
        assert!(g.plan.quant_rel_error.unwrap() > QBCSR_MAX_REL_ERROR);

        // Opt-out default never quantizes.
        let w2 = random_sparse(128, 256, 0.45, &mut rng);
        let p = PackedLinear::from_csr(&Csr::from_dense(&w2), 8);
        assert_eq!(p.plan.choice, KernelChoice::Bcsr);
        assert_eq!(p.plan.quant_rel_error, None);
    }

    #[test]
    fn packed_quantized_forward_matches_dequantized_reference() {
        let mut rng = Rng::new(13);
        let s = random_sparse(200, 200, 0.6, &mut rng);
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(200, 8, 0.3, &mut rng),
                vt: Matrix::randn(8, 200, 0.3, &mut rng),
            }),
        };
        let packed = PackedLinear::from_spl_with(&spl, &PackOptions::quantized(6));
        assert_eq!(packed.plan.choice, KernelChoice::QBcsr);
        let x = Matrix::randn(6, 200, 1.0, &mut rng);
        // The kernel must reproduce dense math on its OWN dequantized
        // weights exactly (quantization error lives in the weights, not the
        // kernel).
        let want = crate::tensor::matmul_bt(&x, &packed.to_dense());
        let got = packed.forward(&x);
        assert!(got.fro_dist(&want) < 1e-3, "dist {}", got.fro_dist(&want));

        let mut y = vec![0.0; 200];
        packed.forward_vec(x.row(0), &mut y);
        for (a, b) in y.iter().zip(got.row(0)) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_from_nm_pruned_selects_nm_kernel() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(64, 256, 1.0, &mut rng);
        let pruned = crate::compress::threshold::hard_threshold(
            &w,
            &w,
            0,
            SparsityPattern::Nm { n: 2, m: 4 },
        );
        let packed = PackedLinear::from_csr(&Csr::from_dense(&pruned), 8);
        assert_eq!(packed.plan.choice, KernelChoice::Nm { n: 2, m: 4 });
        assert!(packed.to_dense().fro_dist(&pruned) < 1e-12);
    }

    #[test]
    fn packed_forward_matches_unpacked_prop() {
        check("packed forward == spl apply_batch", 15, |g| {
            let rows = g.usize_range(2, 90);
            let cols = g.usize_range(2, 90);
            let b = g.usize_range(1, 9);
            let r = g.usize_range(1, 6);
            let mut rng = Rng::new(g.usize_range(0, 1 << 20) as u64);
            let s = random_sparse(rows, cols, 0.6, &mut rng);
            let spl = SparsePlusLowRank {
                sparse: Csr::from_dense(&s),
                low_rank: Some(LowRank {
                    u: Matrix::randn(rows, r, 1.0, &mut rng),
                    vt: Matrix::randn(r, cols, 1.0, &mut rng),
                }),
            };
            let packed = PackedLinear::from_spl(&spl, b);
            let x = Matrix::randn(b, cols, 1.0, &mut rng);
            let got = packed.forward(&x);
            let want = spl.apply_batch(&x);
            assert!(got.fro_dist(&want) < 1e-3, "dist {}", got.fro_dist(&want));

            let mut y1 = vec![0.0; rows];
            let mut y2 = vec![0.0; rows];
            packed.forward_vec(x.row(0), &mut y1);
            spl.apply(x.row(0), &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn packed_param_count_matches_logical() {
        let mut rng = Rng::new(8);
        let s = random_sparse(200, 200, 0.65, &mut rng);
        let spl = SparsePlusLowRank {
            sparse: Csr::from_dense(&s),
            low_rank: Some(LowRank {
                u: Matrix::randn(200, 12, 1.0, &mut rng),
                vt: Matrix::randn(12, 200, 1.0, &mut rng),
            }),
        };
        let packed = PackedLinear::from_spl(&spl, 8);
        assert_eq!(packed.plan.choice, KernelChoice::Bcsr);
        assert_eq!(packed.param_count(), spl.param_count());
        assert_eq!(packed.shape(), (200, 200));
    }

    #[test]
    fn packed_from_dense_keeps_dense() {
        let mut rng = Rng::new(9);
        let w = Matrix::randn(40, 40, 1.0, &mut rng);
        let packed = PackedLinear::from_dense(&w, 4);
        assert_eq!(packed.plan.choice, KernelChoice::Dense);
        let x = Matrix::randn(2, 40, 1.0, &mut rng);
        let want = crate::tensor::matmul_bt(&x, &w);
        assert!(packed.forward(&x).fro_dist(&want) < 1e-5);
    }

    #[test]
    fn plan_describe_mentions_choice() {
        let p = KernelPlan::choose(256, 256, 100, None, 8, None);
        assert!(p.describe().contains("csr") || p.describe().contains("bcsr"));
    }

    #[test]
    fn packed_sliced_runs_plain_gemm_in_sliced_shape() {
        let mut rng = Rng::new(21);
        // 12-of-16 output channels kept, input dim untouched.
        let w = Matrix::randn(12, 8, 1.0, &mut rng);
        let out_map = SliceMap { kept: (0..12).map(|i| (15 - i) as u32).collect(), full: 16 };
        let packed = PackedLinear::from_sliced(&w, SliceMap::identity(8), out_map, 4);
        assert_eq!(packed.plan.choice, KernelChoice::SlicedDense);
        assert_eq!(packed.plan.choice.name(), "sliced");
        assert!(packed.plan.describe().contains("sliced"));
        assert_eq!(packed.shape(), (12, 8));
        assert_eq!(packed.original_shape(), (16, 8));
        assert_eq!(packed.param_count(), 12 * 8);
        assert!(packed.slice().is_some());

        let x = Matrix::randn(4, 8, 1.0, &mut rng);
        let want = crate::tensor::matmul_bt(&x, &w);
        assert!(packed.forward(&x).fro_dist(&want) < 1e-6);
        let mut y = vec![0.0; 12];
        packed.forward_vec(x.row(0), &mut y);
        for (a, b) in y.iter().zip(want.row(0)) {
            assert!((a - b).abs() < 1e-5);
        }
        // Non-sliced layers report no slice metadata and identical shapes.
        let plain = PackedLinear::from_dense(&w, 4);
        assert!(plain.slice().is_none());
        assert_eq!(plain.original_shape(), plain.shape());
    }
}
