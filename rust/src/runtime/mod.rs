//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python is never on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`, with a
//! per-artifact compile cache and Literal⇄Matrix plumbing.

use crate::json::{self, Json};
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled-artifact registry bound to one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Json,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Open the artifact directory for one preset (e.g. `artifacts/tiny`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        let manifest = json::parse(
            &std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?,
        )?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Engine { client, dir: dir.to_path_buf(), manifest, cache: HashMap::new() })
    }

    /// True if a preset's artifacts exist (used by tests to self-skip).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(to_anyhow)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given input literals; returns the
    /// decomposed output tuple. Accepts owned or borrowed literals, so
    /// long-lived state (e.g. training parameters) is passed by reference
    /// with no per-call copy (§Perf: cut small-preset step time ~in half).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<L>(inputs).map_err(to_anyhow)?;
        let out = result
            .into_iter()
            .next()
            .context("no replica output")?
            .into_iter()
            .next()
            .context("no device output")?
            .to_literal_sync()
            .map_err(to_anyhow)?;
        // aot.py lowers with return_tuple=True: always a tuple.
        out.to_tuple().map_err(to_anyhow)
    }

    /// Model config recorded in the manifest.
    pub fn model_config(&self) -> Result<crate::config::ModelConfig> {
        let c = self.manifest.get("config").context("manifest missing config")?;
        Ok(crate::config::ModelConfig {
            name: self.manifest.req_str("preset")?.to_string(),
            vocab: c.req_usize("vocab")?,
            d_model: c.req_usize("d_model")?,
            n_heads: c.req_usize("n_heads")?,
            n_layers: c.req_usize("n_layers")?,
            d_ff: c.req_usize("d_ff")?,
            seq_len: c.req_usize("seq_len")?,
        })
    }

    /// Training batch size baked into the artifacts.
    pub fn train_batch(&self) -> Result<usize> {
        self.manifest
            .get("train")
            .context("manifest missing train")?
            .req_usize("batch")
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

// ───────────────────── Literal ⇄ native conversions ─────────────────────

/// Row-major f32 matrix → 2-D literal.
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(to_anyhow)
}

/// 1-D f32 literal.
pub fn literal_from_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Tokens [B][S] → int32 [B, S] literal.
pub fn literal_from_tokens(tokens: &[Vec<usize>]) -> Result<xla::Literal> {
    let s = tokens[0].len();
    let flat: Vec<i32> = tokens.iter().flat_map(|row| row.iter().map(|&t| t as i32)).collect();
    xla::Literal::vec1(&flat)
        .reshape(&[tokens.len() as i64, s as i64])
        .map_err(to_anyhow)
}

/// Labels → int32 [n] literal.
pub fn literal_from_labels(labels: &[usize]) -> xla::Literal {
    let flat: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
    xla::Literal::vec1(&flat)
}

/// Scalar i32 literal.
pub fn literal_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Literal → Matrix with the given expected shape (flattens ≥2-D).
pub fn matrix_from_literal(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
    anyhow::ensure!(
        data.len() == rows * cols,
        "literal has {} elements, expected {rows}x{cols}",
        data.len()
    );
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Scalar f32 from a literal.
pub fn f32_from_literal(lit: &xla::Literal) -> Result<f32> {
    let v: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
    v.first().copied().context("empty literal")
}

/// Scalar i32 from a literal.
pub fn i32_from_literal(lit: &xla::Literal) -> Result<i32> {
    let v: Vec<i32> = lit.to_vec().map_err(to_anyhow)?;
    v.first().copied().context("empty literal")
}

/// Convenience: flatten a named tensor list into literals (canonical order).
pub fn literals_from_tensors(tensors: &[(String, Matrix)]) -> Result<Vec<xla::Literal>> {
    tensors
        .iter()
        .map(|(name, m)| {
            if m.rows == 1 && name_is_vector(name) {
                Ok(literal_from_vec(&m.data))
            } else {
                literal_from_matrix(m)
            }
        })
        .collect()
}

/// LN gains/biases and the CLS token are rank-1 in the JAX model.
fn name_is_vector(name: &str) -> bool {
    name.ends_with("_g") || name.ends_with("_b") || name == "cls"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_name_detection() {
        assert!(name_is_vector("block0.ln1_g"));
        assert!(name_is_vector("lnf_b"));
        assert!(name_is_vector("cls"));
        assert!(!name_is_vector("block0.wq"));
        assert!(!name_is_vector("head"));
    }

    // PJRT-dependent behaviour is exercised by rust/tests/runtime_integration.rs
    // (self-skipping when artifacts are absent).
}
