//! Evaluation harness: perplexity (WikiText-2 proxy) and the synthetic task
//! suites that proxy the paper's MMLU / zero-shot benchmarks (DESIGN.md §3).
//!
//! * **ppl** — held-out next-token perplexity.
//! * **hard suite** (MMLU proxy) — long-range fact recall: the model must
//!   emit the planted answer token Δ steps after its trigger.
//! * **easy suite** (zero-shot proxy) — local structure: top-successor
//!   bigram completion plus unigram-frequency discrimination.

use crate::data::SyntheticCorpus;
use crate::model::TransformerLM;
use crate::tensor;

/// Perplexity of the model on `n_batches` held-out batches.
pub fn perplexity(
    model: &TransformerLM,
    corpus: &SyntheticCorpus,
    n_batches: usize,
    batch_size: usize,
    seq_len: usize,
    stream: u64,
) -> f64 {
    let mut rng = corpus.stream(0xE7A1 ^ stream);
    let mut total_nats = 0.0;
    let mut total_tokens = 0usize;
    for _ in 0..n_batches {
        let b = corpus.batch(batch_size, seq_len, &mut rng);
        let loss = model.loss(&b.inputs, &b.targets);
        let n = b.inputs.len() * seq_len;
        total_nats += loss * n as f64;
        total_tokens += n;
    }
    (total_nats / total_tokens as f64).exp()
}

/// Accuracy on (context, answer) probes via greedy next-token prediction.
pub fn probe_accuracy(model: &TransformerLM, probes: &[(Vec<usize>, usize)]) -> f64 {
    if probes.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    // Group probes by context length so each forward is one rectangular batch.
    let mut by_len: std::collections::BTreeMap<usize, Vec<&(Vec<usize>, usize)>> =
        std::collections::BTreeMap::new();
    for p in probes {
        by_len.entry(p.0.len()).or_default().push(p);
    }
    for (_, group) in by_len {
        for chunk in group.chunks(16) {
            let ctxs: Vec<Vec<usize>> = chunk.iter().map(|p| p.0.clone()).collect();
            let preds = model.predict_next(&ctxs);
            for (pred, p) in preds.iter().zip(chunk) {
                if *pred == p.1 {
                    correct += 1;
                }
            }
        }
    }
    correct as f64 / probes.len() as f64
}

/// The "hard" (MMLU-proxy) score: fact-recall accuracy (%).
pub fn hard_suite(model: &TransformerLM, corpus: &SyntheticCorpus, n: usize, stream: u64) -> f64 {
    let seq = model.cfg.seq_len.min(64);
    let probes = corpus.fact_probes(n, seq, &mut corpus.stream(0xFAC7 ^ stream));
    100.0 * probe_accuracy(model, &probes)
}

/// The "easy" (zero-shot-proxy) score: mean of the easy sub-tasks (%).
pub fn easy_suite(model: &TransformerLM, corpus: &SyntheticCorpus, n: usize, stream: u64) -> f64 {
    let bigram = corpus.bigram_probes(n, 16, &mut corpus.stream(0xB16A ^ stream));
    let acc_bigram = probe_accuracy(model, &bigram);
    // Second sub-task: same completion at a longer context (tests stability).
    let bigram_long = corpus.bigram_probes(n, 32, &mut corpus.stream(0xB16B ^ stream));
    let acc_long = probe_accuracy(model, &bigram_long);
    100.0 * (acc_bigram + acc_long) / 2.0
}

/// A full evaluation row (one model, all metrics) — the unit every table
/// harness emits.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub label: String,
    pub ppl: f64,
    pub hard: f64,
    pub easy: f64,
}

/// Standard evaluation bundle used by the table regenerators.
pub fn evaluate(
    model: &TransformerLM,
    corpus: &SyntheticCorpus,
    label: &str,
    n_eval_batches: usize,
    n_probes: usize,
) -> EvalRow {
    EvalRow {
        label: label.to_string(),
        ppl: perplexity(model, corpus, n_eval_batches, 8, model.cfg.seq_len.min(64), 1),
        hard: hard_suite(model, corpus, n_probes, 1),
        easy: easy_suite(model, corpus, n_probes, 1),
    }
}

/// Per-block excess kurtosis of each linear layer's input activations —
/// the outlier-feature probe (paper §2.3 premise: large transformers have
/// heavy-tailed activations; D-scaling exists to protect them). Gaussian
/// activations → ≈0; heavy outlier features → large positive values.
pub fn activation_kurtosis(
    model: &TransformerLM,
    corpus: &SyntheticCorpus,
    n_seq: usize,
) -> Vec<(crate::model::LinearId, f64)> {
    let seq = model.cfg.seq_len.min(64);
    let batch = corpus.batch(n_seq, seq, &mut corpus.stream(0x0A11));
    let mut hidden = model.embed(&batch.inputs);
    let mut out = Vec::new();
    for b in 0..model.blocks.len() {
        let mut cap = crate::model::ForwardCapture::default();
        let next =
            model.block_forward(b, &hidden, batch.inputs.len(), seq, Some(&mut cap), None);
        for name in crate::model::LINEAR_NAMES {
            let x = &cap.inputs[name];
            out.push((
                crate::model::LinearId { block: b, name },
                crate::util::stats::excess_kurtosis(&x.data),
            ));
        }
        hidden = next;
    }
    out
}

/// Logit-level agreement between two models (compression fidelity probe).
pub fn logit_divergence(a: &TransformerLM, b: &TransformerLM, tokens: &[Vec<usize>]) -> f64 {
    let la = a.forward(tokens);
    let lb = b.forward(tokens);
    la.fro_dist(&lb) / la.fro_norm().max(1e-12)
}

/// Top-1 agreement rate between two models' next-token predictions.
pub fn prediction_agreement(
    a: &TransformerLM,
    b: &TransformerLM,
    tokens: &[Vec<usize>],
) -> f64 {
    let s = tokens[0].len();
    let la = a.forward(tokens);
    let lb = b.forward(tokens);
    let mut same = 0usize;
    let mut total = 0usize;
    for r in 0..tokens.len() {
        for t in 0..s {
            let row = r * s + t;
            if tensor::argmax(la.row(row)) == tensor::argmax(lb.row(row)) {
                same += 1;
            }
            total += 1;
        }
    }
    same as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::CorpusConfig;

    fn setup() -> (TransformerLM, SyntheticCorpus) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 11);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 13));
        (model, corpus)
    }

    #[test]
    fn perplexity_near_vocab_at_init() {
        // An untrained model is ~uniform ⇒ ppl ≈ vocab.
        let (m, c) = setup();
        let ppl = perplexity(&m, &c, 2, 4, 32, 0);
        assert!(ppl > 100.0 && ppl < 600.0, "ppl={ppl}");
    }

    #[test]
    fn perplexity_deterministic() {
        let (m, c) = setup();
        assert_eq!(perplexity(&m, &c, 1, 2, 16, 7), perplexity(&m, &c, 1, 2, 16, 7));
    }

    #[test]
    fn probe_accuracy_bounds() {
        let (m, c) = setup();
        let probes = c.bigram_probes(10, 8, &mut c.stream(1));
        let acc = probe_accuracy(&m, &probes);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn suites_run() {
        let (m, c) = setup();
        let hard = hard_suite(&m, &c, 8, 0);
        let easy = easy_suite(&m, &c, 8, 0);
        assert!((0.0..=100.0).contains(&hard));
        assert!((0.0..=100.0).contains(&easy));
    }

    #[test]
    fn identical_models_agree() {
        let (m, _) = setup();
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        assert!(logit_divergence(&m, &m, &toks) < 1e-9);
        assert_eq!(prediction_agreement(&m, &m, &toks), 1.0);
    }

    #[test]
    fn different_models_disagree() {
        let (m, _) = setup();
        let m2 = TransformerLM::init(&m.cfg, 999);
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        assert!(logit_divergence(&m, &m2, &toks) > 0.01);
    }
}
