//! Small shared substrates: deterministic PRNG, summary statistics, timing,
//! a std-thread worker pool, and a miniature property-testing framework.
//!
//! These stand in for `rand`, `rayon`, and `proptest`, which are not part of
//! the vendored dependency set (see DESIGN.md §3).

pub mod prng;
pub mod prop;
pub mod stats;
pub mod threadpool;
pub mod time;
pub mod trace;

pub use prng::Rng;
pub use stats::Summary;
pub use threadpool::ThreadPool;
pub use time::Stopwatch;
