//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple stopwatch that records named laps; used by the coordinator's
/// progress reporting and the §Perf iteration logs.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, f64)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Record a lap: seconds since the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((name.to_string(), dt));
        dt
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps.len(), 2);
        assert!(sw.elapsed() >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
