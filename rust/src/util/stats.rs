//! Summary statistics used by the bench harness, the evaluation suite, and
//! the OWL outlier-ratio computation.

/// Summary of a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `xs` need not be sorted. Empty input yields
    /// zeros.
    ///
    /// NaN placement is explicit: NaN observations are **dropped** (`n`
    /// counts the kept samples), so one degenerate measurement — e.g. a
    /// NaN latency sample — cannot poison every statistic or make the
    /// JSON emitters produce unparseable output. (The previous
    /// `partial_cmp(..).unwrap()` sort panicked mid-run instead.) An
    /// all-NaN sample yields the same zero summary as an empty one;
    /// infinities are legitimate ordered values and are kept.
    pub fn of(xs: &[f64]) -> Summary {
        let mut sorted: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
        if sorted.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    /// Machine-readable form — the shape shared by `SERVE_*.json` summary
    /// blocks (mean + the p50/p95/p99 tail, not just mean/max).
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::{num, Json};
        let mut o = Json::obj();
        o.set("n", num(self.n as f64))
            .set("mean", num(self.mean))
            .set("std", num(self.std))
            .set("min", num(self.min))
            .set("max", num(self.max))
            .set("p50", num(self.p50))
            .set("p95", num(self.p95))
            .set("p99", num(self.p99));
        o
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Mean of a slice of f32s (as f64 to avoid cancellation).
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Excess kurtosis of a slice — the outlier probe used to verify that
/// trained activations exhibit the heavy-tailed "outlier feature" structure
/// the paper's scaling step targets (Section 2.3).
pub fn excess_kurtosis(xs: &[f32]) -> f64 {
    if xs.len() < 4 {
        return 0.0;
    }
    let n = xs.len() as f64;
    let mean = mean_f32(xs);
    let m2 = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let m4 = xs.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>() / n;
    if m2 <= 1e-300 {
        return 0.0;
    }
    m4 / (m2 * m2) - 3.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 5.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12);
        assert!(s.p99 >= s.p95 && s.p95 >= s.p50, "percentiles must be ordered");
        assert!(s.p99 <= s.max);
    }

    #[test]
    fn p99_tracks_the_tail() {
        // 99 fast observations and one slow outlier: p50 stays low, p99
        // lands near the outlier (tail latency visible, mean diluted).
        let mut xs = vec![1.0; 99];
        xs.push(100.0);
        let s = Summary::of(&xs);
        assert_eq!(s.p50, 1.0);
        assert!(s.p99 > 10.0, "p99 {} must expose the outlier", s.p99);
        assert!(s.mean < 3.0);
    }

    #[test]
    fn summary_json_has_percentiles() {
        let j = Summary::of(&[1.0, 2.0, 3.0]).to_json();
        assert_eq!(j.req_f64("n").unwrap(), 3.0);
        assert!(j.req_f64("p99").unwrap() >= j.req_f64("p50").unwrap());
    }

    #[test]
    fn nan_observations_are_dropped_not_fatal() {
        // Regression: a single NaN used to panic the partial_cmp sort in
        // the middle of the stats/JSON emit path.
        let s = Summary::of(&[3.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 2, "NaN is dropped from the sample");
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!(s.p99.is_finite());
        let j = s.to_json();
        assert!(j.req_f64("p50").unwrap().is_finite());
        // All-NaN degenerates to the zero summary, like empty input.
        let all = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(all.n, 0);
        assert_eq!(all.max, 0.0);
        // Infinities are ordered values and survive.
        let inf = Summary::of(&[1.0, f64::INFINITY]);
        assert_eq!(inf.n, 2);
        assert_eq!(inf.max, f64::INFINITY);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn kurtosis_of_uniform_negative_of_spike_positive() {
        // Uniform has excess kurtosis -1.2; a heavy-outlier sample is positive.
        let uniform: Vec<f32> = (0..10_000).map(|i| i as f32 / 10_000.0).collect();
        assert!(excess_kurtosis(&uniform) < -1.0);
        let mut spiky = vec![0.0f32; 1000];
        spiky.extend_from_slice(&[100.0; 3]);
        // small noise so m2 > 0
        for (i, v) in spiky.iter_mut().enumerate().take(1000) {
            *v = (i % 7) as f32 * 0.01;
        }
        assert!(excess_kurtosis(&spiky) > 10.0);
    }
}
