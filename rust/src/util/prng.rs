//! Deterministic, splittable PRNG (xoshiro256** seeded via SplitMix64).
//!
//! All randomness in the library flows through [`Rng`] so that experiments
//! are reproducible from a single seed recorded in the experiment config.

/// xoshiro256** generator. Not cryptographic; fast and statistically solid
/// for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker seeding).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with i.i.d. N(0, std²) values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(123);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
