//! `oats-trace`: always-compiled, cheap-when-off structured tracing.
//!
//! The serve stack (engine step phases, request lifecycle, KV pool, kernel
//! dispatch) and the compression pipeline emit **spans** (RAII begin/end
//! pairs collapsed into one complete event at drop), **instants** (point
//! events), and **counters** (sampled values) into per-thread lock-free
//! ring buffers. A single global enable flag gates every site: when
//! tracing is off, a span/instant/counter call costs one relaxed atomic
//! load and allocates nothing, so instrumentation stays in release builds
//! permanently (the `trace_overhead` bench comparison in CI keeps both
//! claims honest — tracing-off free, tracing-on < 5 % on decode).
//!
//! Architecture:
//!
//! * **One SPSC ring per thread** ([`Ring`]): the owning thread is the
//!   only producer, and the drain side — serialized through the global
//!   registry mutex — is the only consumer, so both sides are a handful
//!   of atomic loads/stores with no CAS loop. A full ring drops the
//!   *newest* event (and counts it) rather than blocking or reallocating:
//!   tracing observes, never stalls.
//! * **Monotonic timeline**: every timestamp is nanoseconds since a
//!   process-wide [`Instant`] epoch, so events from different threads
//!   order correctly and the Chrome export needs no clock reconciliation.
//! * **`'static` names**: span/instant/counter names are `&'static str`
//!   literals from the committed registry
//!   (`ci/analysis/trace_registry.json`, enforced by the `trace-hygiene`
//!   oats-tidy rule) — events never own or hash strings on the hot path,
//!   and the Chrome export / `ci/gates/trace_gate.py` stay stable.
//!
//! Export is Chrome trace-event JSON (`chrome://tracing`, or
//! <https://ui.perfetto.dev> — "Open trace file"): `ph:"X"` complete
//! spans with microsecond `ts`/`dur`, `ph:"i"` instants, `ph:"C"`
//! counters. `oats serve-load --trace <path>` and the micro bench write
//! it; `ci/gates/trace_gate.py` validates well-formedness, span nesting,
//! and per-request lifecycle completeness.
//!
//! The numerics contract is untouched by design: tracing *observes* the
//! serve stack — it never reorders, batches, or drops work, so engine
//! outputs are bit-identical with tracing on or off (property-tested in
//! `rust/tests/serve_engine.rs`).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::json::{self, Json};

/// Per-thread ring capacity (events). Power of two; at ~80 B/event this
/// is ≈2.6 MiB per *traced* thread, allocated lazily on its first event.
/// Sized so a quick-mode traced serve-load fits without drops.
const RING_CAPACITY: usize = 1 << 15;

/// What an [`Event`] records beyond its name/timestamp/thread.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A completed span: begin at `ts_ns`, lasting `dur_ns`.
    Span { dur_ns: u64 },
    /// A point event.
    Instant,
    /// A sampled value (rendered as a counter track in Perfetto).
    Counter { value: f64 },
}

/// One trace event, as drained from the rings.
#[derive(Clone, Debug)]
pub struct Event {
    /// `'static` snake_case name from the committed registry.
    pub name: &'static str,
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    /// Sequential trace-thread id (1-based, assigned at first event).
    pub tid: u64,
    pub kind: EventKind,
    /// Numeric key/value annotations (request id, nnz, batch, ...).
    pub args: Vec<(&'static str, f64)>,
}

// ---------------------------------------------------------------------------
// SPSC ring buffer
// ---------------------------------------------------------------------------

/// Lock-free single-producer/single-consumer ring of [`Event`]s.
///
/// The owning thread pushes; the global drain — serialized by the
/// registry mutex — consumes. `head` and `tail` are *monotonic* event
/// counts (never wrapped); slot index is `count & mask`. Full ring ⇒ the
/// incoming event is dropped and counted, the producer never waits.
pub struct Ring {
    slots: Box<[UnsafeCell<Option<Event>>]>,
    mask: usize,
    /// Next write position (monotonic). Written by the producer only.
    head: AtomicUsize,
    /// Next read position (monotonic). Written by the consumer only.
    tail: AtomicUsize,
    /// Events discarded because the ring was full.
    dropped: AtomicUsize,
}

// SAFETY: the SPSC discipline makes the UnsafeCell slots data-race free:
// the producer writes only slots in [tail, head) that the Release store
// of `head` has not yet published, and the consumer reads only slots in
// [tail, head) after Acquire-loading `head` — each slot is therefore
// accessed by at most one thread between a matching Release/Acquire
// pair. Single-consumer is enforced by draining only under the REGISTRY
// lock; single-producer by the ring being reachable for pushes only via
// its owning thread's thread-local handle.
unsafe impl Sync for Ring {}

impl Ring {
    /// A ring holding `capacity` events (rounded up to a power of two).
    pub fn with_capacity(capacity: usize) -> Ring {
        let cap = capacity.next_power_of_two().max(2);
        let slots: Vec<UnsafeCell<Option<Event>>> =
            (0..cap).map(|_| UnsafeCell::new(None)).collect();
        Ring {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    /// Producer side: append one event, dropping it (and counting the
    /// drop) when the ring is full. Only the owning thread may call this.
    pub fn push(&self, ev: Event) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: slot `head & mask` is outside the consumer's visible
        // [tail, head) window until the Release store below publishes
        // it, so the producer holds exclusive access here (see the Sync
        // impl's protocol note).
        unsafe {
            *self.slots[head & self.mask].get() = Some(ev);
        }
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side: move every published event into `out`, in push
    /// order. Only one thread may drain at a time (the global drain
    /// holds the registry lock).
    pub fn drain_into(&self, out: &mut Vec<Event>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail != head {
            // SAFETY: slot `tail & mask` is inside [tail, head): the
            // Acquire load of `head` synchronized with the producer's
            // Release store, so the write to this slot happens-before
            // this read, and the producer will not touch it again until
            // the Release store of `tail` below hands it back.
            if let Some(ev) = unsafe { (*self.slots[tail & self.mask].get()).take() } {
                out.push(ev);
            }
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
    }

    /// Events discarded because the ring was full.
    pub fn dropped(&self) -> usize {
        self.dropped.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Global state: enable flag, epoch, thread registry
// ---------------------------------------------------------------------------

/// The one flag every instrumentation site checks. Relaxed is enough:
/// the flag only gates *whether* to record — event visibility is ordered
/// by the rings' own Release/Acquire pairs.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Process-wide monotonic epoch all timestamps are relative to.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Every thread's ring, registered at its first event; kept alive here
/// even after the thread exits so late drains still see its events.
static REGISTRY: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());

/// Sequential trace-thread ids (1-based).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: (Arc<Ring>, u64) = {
        let ring = Arc::new(Ring::with_capacity(RING_CAPACITY));
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(ring.clone());
        (ring, tid)
    };
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn push_event(name: &'static str, ts_ns: u64, kind: EventKind, args: Vec<(&'static str, f64)>) {
    // try_with: a drop-glue event during thread teardown is silently
    // discarded instead of panicking on the dead thread-local.
    let _ = LOCAL.try_with(|(ring, tid)| {
        ring.push(Event { name, ts_ns, tid: *tid, kind, args });
    });
}

/// Turn global tracing on or off. Off is the default; every span site
/// then costs one relaxed atomic load.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is tracing currently enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drain every thread's ring into one timestamp-sorted event list.
pub fn drain() -> Vec<Event> {
    let registry = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = Vec::new();
    for ring in registry.iter() {
        ring.drain_into(&mut out);
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Total events dropped across all rings since process start.
pub fn dropped_events() -> usize {
    let registry = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    registry.iter().map(|r| r.dropped()).sum()
}

// ---------------------------------------------------------------------------
// Recording API: spans, instants, counters
// ---------------------------------------------------------------------------

/// RAII span: created by [`span`]/[`span_args`], emits one complete
/// event covering its lifetime when dropped. Inert (no clock read, no
/// allocation) when tracing was off at creation.
pub struct SpanGuard {
    name: &'static str,
    start_ns: Option<u64>,
    args: Vec<(&'static str, f64)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start_ns.take() {
            let dur = now_ns().saturating_sub(start);
            push_event(
                self.name,
                start,
                EventKind::Span { dur_ns: dur },
                std::mem::take(&mut self.args),
            );
        }
    }
}

/// Begin a span; it ends (and records) when the guard drops.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, start_ns: None, args: Vec::new() };
    }
    SpanGuard { name, start_ns: Some(now_ns()), args: Vec::new() }
}

/// [`span`] with numeric annotations (copied only when tracing is on).
#[inline]
pub fn span_args(name: &'static str, args: &[(&'static str, f64)]) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, start_ns: None, args: Vec::new() };
    }
    SpanGuard { name, start_ns: Some(now_ns()), args: args.to_vec() }
}

/// Record a point event.
#[inline]
pub fn instant(name: &'static str) {
    if enabled() {
        push_event(name, now_ns(), EventKind::Instant, Vec::new());
    }
}

/// [`instant`] with numeric annotations.
#[inline]
pub fn instant_args(name: &'static str, args: &[(&'static str, f64)]) {
    if enabled() {
        push_event(name, now_ns(), EventKind::Instant, args.to_vec());
    }
}

/// Record a counter sample (a value-over-time track in Perfetto).
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if enabled() {
        push_event(name, now_ns(), EventKind::Counter { value }, Vec::new());
    }
}

/// A span that *always* measures wall-clock — for call sites (the
/// compression pipeline, the walltime tables) that need the duration for
/// their own reports regardless of tracing. The trace event itself is
/// still emitted only when tracing is on.
#[must_use = "call finish() to obtain the measured seconds"]
pub struct TimedSpan {
    name: &'static str,
    start_ns: u64,
}

/// Begin an always-measuring span; [`TimedSpan::finish`] returns seconds.
#[inline]
pub fn timed(name: &'static str) -> TimedSpan {
    TimedSpan { name, start_ns: now_ns() }
}

impl TimedSpan {
    /// End the span, returning its duration in seconds (and emitting the
    /// trace event when tracing is enabled).
    pub fn finish(self) -> f64 {
        let dur = now_ns().saturating_sub(self.start_ns);
        if enabled() {
            push_event(self.name, self.start_ns, EventKind::Span { dur_ns: dur }, Vec::new());
        }
        dur as f64 / 1e9
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

/// Render events as a Chrome trace-event JSON document (Perfetto-loadable).
///
/// Timestamps and durations are microseconds (the format's unit), kept
/// as fractional values so nanosecond ordering survives. `dropped` is
/// surfaced as a top-level `droppedEvents` count (extra top-level keys
/// are legal in the format and ignored by viewers).
pub fn chrome_trace(events: &[Event], dropped: usize) -> Json {
    let mut rows = Vec::with_capacity(events.len());
    for e in events {
        let ph = match e.kind {
            EventKind::Span { .. } => "X",
            EventKind::Instant => "i",
            EventKind::Counter { .. } => "C",
        };
        let mut o = Json::obj();
        o.set("name", json::s(e.name))
            .set("ph", json::s(ph))
            .set("ts", json::num(e.ts_ns as f64 / 1e3))
            .set("pid", json::num(1.0))
            .set("tid", json::num(e.tid as f64));
        match e.kind {
            EventKind::Span { dur_ns } => {
                o.set("dur", json::num(dur_ns as f64 / 1e3));
            }
            // "t" = thread-scoped instant (the viewer draws it on its tid).
            EventKind::Instant => {
                o.set("s", json::s("t"));
            }
            EventKind::Counter { .. } => {}
        }
        let value = match e.kind {
            EventKind::Counter { value } => Some(value),
            _ => None,
        };
        if !e.args.is_empty() || value.is_some() {
            let mut a = Json::obj();
            for (k, v) in &e.args {
                a.set(k, json::num(*v));
            }
            if let Some(v) = value {
                a.set("value", json::num(v));
            }
            o.set("args", a);
        }
        rows.push(o);
    }
    let mut doc = Json::obj();
    doc.set("schema", json::s("oats-trace-v1"))
        .set("displayTimeUnit", json::s("ms"))
        .set("droppedEvents", json::num(dropped as f64))
        .set("traceEvents", json::arr(rows));
    doc
}

/// Write a Chrome trace file for `events` (creating parent directories),
/// stamping the process-wide dropped-event count.
pub fn write_chrome_trace(path: &std::path::Path, events: &[Event]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, chrome_trace(events, dropped_events()).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that touch the global enable flag / registry —
    /// they would otherwise steal each other's drained events.
    static GLOBAL: Mutex<()> = Mutex::new(());

    fn lock_global() -> std::sync::MutexGuard<'static, ()> {
        GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn ev(name: &'static str, ts_ns: u64, kind: EventKind) -> Event {
        Event { name, ts_ns, tid: 1, kind, args: Vec::new() }
    }

    #[test]
    fn ring_preserves_order_across_wraparound() {
        let ring = Ring::with_capacity(4);
        let mut out = Vec::new();
        // Three full cycles through a 4-slot ring: indices wrap, order
        // and content survive.
        for cycle in 0..3u64 {
            for i in 0..4u64 {
                ring.push(ev("unit_probe", cycle * 4 + i, EventKind::Instant));
            }
            ring.drain_into(&mut out);
        }
        assert_eq!(out.len(), 12);
        for (i, e) in out.iter().enumerate() {
            assert_eq!(e.ts_ns, i as u64);
        }
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn full_ring_drops_newest_and_counts() {
        let ring = Ring::with_capacity(2);
        for i in 0..5u64 {
            ring.push(ev("unit_probe", i, EventKind::Instant));
        }
        assert_eq!(ring.dropped(), 3);
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        // The two *oldest* events survive; newest were dropped.
        assert_eq!(out.iter().map(|e| e.ts_ns).collect::<Vec<_>>(), vec![0, 1]);
        // After the drain the ring accepts events again.
        ring.push(ev("unit_probe", 9, EventKind::Instant));
        out.clear();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn disabled_sites_record_nothing() {
        let _g = lock_global();
        set_enabled(false);
        drain(); // flush anything a prior test left behind
        {
            let _s = span("unit_probe");
            instant("unit_probe");
            counter("unit_probe", 1.0);
        }
        let got: Vec<_> = drain().into_iter().filter(|e| e.name == "unit_probe").collect();
        assert!(got.is_empty(), "disabled tracing must record nothing: {got:?}");
    }

    #[test]
    fn span_guard_records_duration_and_args() {
        let _g = lock_global();
        set_enabled(true);
        drain();
        {
            let _s = span_args("unit_probe_span", &[("id", 7.0)]);
            instant("unit_probe_inner");
        }
        set_enabled(false);
        let events = drain();
        let s = events.iter().find(|e| e.name == "unit_probe_span").expect("span recorded");
        let i = events.iter().find(|e| e.name == "unit_probe_inner").expect("instant recorded");
        let dur = match s.kind {
            EventKind::Span { dur_ns } => dur_ns,
            k => panic!("expected span, got {k:?}"),
        };
        assert_eq!(s.args, vec![("id", 7.0)]);
        // The inner instant falls inside the span's [ts, ts+dur] window.
        assert!(s.ts_ns <= i.ts_ns && i.ts_ns <= s.ts_ns + dur);
    }

    #[test]
    fn timed_span_measures_even_when_disabled() {
        let _g = lock_global();
        set_enabled(false);
        drain();
        let t = timed("unit_probe_timed");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let secs = t.finish();
        assert!(secs >= 0.001, "timed() must measure with tracing off: {secs}");
        let got: Vec<_> = drain().into_iter().filter(|e| e.name == "unit_probe_timed").collect();
        assert!(got.is_empty(), "no event may be emitted while disabled");
    }

    #[test]
    fn multi_thread_events_drain_ordered_per_thread() {
        let _g = lock_global();
        set_enabled(true);
        drain();
        const PER_THREAD: usize = 100;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..PER_THREAD {
                        instant_args("unit_probe_mt", &[("i", i as f64)]);
                    }
                });
            }
        });
        set_enabled(false);
        let events: Vec<_> = drain().into_iter().filter(|e| e.name == "unit_probe_mt").collect();
        assert_eq!(events.len(), 4 * PER_THREAD);
        // Per-thread sequence numbers arrive in push order, and the
        // global sort by timestamp is non-decreasing.
        let mut per_tid: std::collections::BTreeMap<u64, Vec<f64>> = Default::default();
        for e in &events {
            per_tid.entry(e.tid).or_default().push(e.args[0].1);
        }
        assert_eq!(per_tid.len(), 4);
        for (_, seq) in per_tid {
            let want: Vec<f64> = (0..PER_THREAD).map(|i| i as f64).collect();
            assert_eq!(seq, want);
        }
        for w in events.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns);
        }
    }

    #[test]
    fn chrome_export_shapes_all_three_phases() {
        let events = vec![
            Event {
                name: "unit_probe_span",
                ts_ns: 1_500,
                tid: 3,
                kind: EventKind::Span { dur_ns: 2_500 },
                args: vec![("nnz", 64.0)],
            },
            ev("unit_probe_i", 2_000, EventKind::Instant),
            Event {
                name: "unit_probe_c",
                ts_ns: 3_000,
                tid: 1,
                kind: EventKind::Counter { value: 5.0 },
                args: Vec::new(),
            },
        ];
        let doc = chrome_trace(&events, 2);
        // Round-trip through the parser: the export is valid JSON with
        // the Chrome trace-event shape.
        let parsed = json::parse(&doc.to_string()).expect("export parses");
        assert_eq!(parsed.get("schema").and_then(|v| v.as_str()), Some("oats-trace-v1"));
        assert_eq!(parsed.get("droppedEvents").and_then(|v| v.as_f64()), Some(2.0));
        let rows = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
        assert_eq!(rows.len(), 3);
        let s = &rows[0];
        assert_eq!(s.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(s.get("ts").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(s.get("dur").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(
            s.get("args").and_then(|a| a.get("nnz")).and_then(|v| v.as_f64()),
            Some(64.0)
        );
        let i = &rows[1];
        assert_eq!(i.get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(i.get("s").and_then(|v| v.as_str()), Some("t"));
        let c = &rows[2];
        assert_eq!(c.get("ph").and_then(|v| v.as_str()), Some("C"));
        assert_eq!(
            c.get("args").and_then(|a| a.get("value")).and_then(|v| v.as_f64()),
            Some(5.0)
        );
    }
}
