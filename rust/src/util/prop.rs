//! A miniature property-based testing framework (stands in for `proptest`,
//! which is not in the vendored dependency set).
//!
//! Usage:
//! ```no_run
//! use oats::util::prop::{check, Gen};
//! check("addition commutes", 100, |g| {
//!     let a = g.i64_range(-1000, 1000);
//!     let b = g.i64_range(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a generator derived deterministically from the case index,
//! so failures are reproducible; on panic the failing case index and seed are
//! reported.

use super::prng::Rng;
use crate::tensor::Matrix;

/// N(0,1) matrix with ~`sparsity` fraction of entries zeroed — the shared
/// generator for the kernel tests and benches. Convention: the third
/// argument is the ZERO fraction (not the keep fraction).
pub fn random_sparse(rows: usize, cols: usize, sparsity: f64, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::randn(rows, cols, 1.0, rng);
    for v in &mut m.data {
        if rng.f64() < sparsity {
            *v = 0.0;
        }
    }
    m
}

/// A weight matrix whose first tile is dominated by one huge value in a sea
/// of small ones (2 of 3 columns at 0.3, plus a single 127.0 at (0, 1)):
/// the symmetric-i8 step collapses the small values to zero, so the
/// per-tile relative quantization error is large. This is the fixture the
/// QBcsr plan-gate tests share; the 2-of-3 column pattern also defeats the
/// 2:4 / 2:8 probes, keeping the base plan BCSR. Requires `cols ≥ 2`.
pub fn outlier_dominated(rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            if c % 3 != 0 {
                *m.at_mut(r, c) = 0.3;
            }
        }
    }
    *m.at_mut(0, 1) = 127.0;
    m
}

/// Value generator handed to each property case.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        self.rng.range(lo, hi)
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.rng.next_u64() % ((hi - lo).max(1) as u64)) as i64
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of normals with the given std.
    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// A matrix (rows*cols) with occasional large-magnitude "outlier" columns,
    /// mimicking the activation structure the paper targets.
    pub fn outlier_matrix(&mut self, rows: usize, cols: usize, outlier_frac: f64) -> Vec<f32> {
        let mut m = self.vec_normal(rows * cols, 1.0);
        let n_out = ((cols as f64) * outlier_frac).ceil() as usize;
        for _ in 0..n_out {
            let c = self.rng.below(cols.max(1));
            let scale = 10.0 + self.rng.f32() * 40.0;
            for r in 0..rows {
                m[r * cols + c] *= scale;
            }
        }
        m
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` instances of the property `f`; panics with the case seed on
/// the first failure.
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Gen)) {
    for case in 0..cases {
        let seed = 0x0A75_0000u64 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            f(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, |g| {
            let n = g.usize_range(0, 32);
            let v: Vec<f32> = g.vec_normal(n, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 3, |_| panic!("nope"));
    }

    #[test]
    fn outlier_matrix_has_outliers() {
        let mut g = Gen::new(1);
        let m = g.outlier_matrix(16, 64, 0.05);
        let max = m.iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(max > 5.0);
        assert_eq!(m.len(), 16 * 64);
    }
}
