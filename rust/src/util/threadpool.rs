//! A small fixed-size worker pool over std threads + channels.
//!
//! Stands in for `rayon` in the two places the paper's pipeline is
//! embarrassingly parallel: compressing the linear layers of one transformer
//! block (paper §A.2 notes per-block parallelism) and the blocked GEMM in
//! `tensor::matmul`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

/// Cached `available_parallelism()` probe — one syscall per process.
/// `None` when the platform cannot report it; callers pick their own
/// fallback (the kernels go serial, the pool keeps its historical 4).
fn detected_parallelism() -> Option<usize> {
    static THREADS: OnceLock<Option<usize>> = OnceLock::new();
    *THREADS.get_or_init(|| thread::available_parallelism().ok().map(|n| n.get()))
}

/// Cached thread count for the kernel thread gates (the sparse tile walk
/// and the blocked GEMMs); 1 — serial — when detection fails, matching
/// the kernels' historical per-call fallback. Re-querying per call showed
/// up in the serve decode profile: each engine step runs dozens of
/// batched products, each of which used to pay the syscall.
pub fn available_threads() -> usize {
    detected_parallelism().unwrap_or(1)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `n` threads (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Pool sized to available parallelism (4 when detection fails —
    /// this pool's historical fallback).
    pub fn with_default_size() -> Self {
        Self::new(detected_parallelism().unwrap_or(4))
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().expect("pool closed").send(Box::new(job)).expect("workers alive");
    }

    /// Run `f(i)` for `i in 0..n`, blocking until all complete.
    pub fn scope_for(&self, n: usize, f: impl Fn(usize) + Send + Sync + 'static) {
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.execute(move || {
                f(i);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Raw-pointer wrapper for disjoint-write parallelism with [`parallel_for`]:
/// the caller guarantees each worker writes a disjoint address set. Shared
/// by the GEMM and sparse kernels so the unsafe surface lives in one place.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);
// SAFETY: SendPtr is a bare address with no aliasing claims of its own.
// Every user (the GEMM stripes, the microkernel scatter) splits the target
// buffer into disjoint per-worker regions and writes only inside its own
// region, and `parallel_for`/`thread::scope` joins all workers before the
// buffer is read — so no two threads ever touch the same element and no
// access outlives the borrow.
unsafe impl Send for SendPtr {}
// SAFETY: same disjoint-region contract as `Send` above — shared
// references to SendPtr only ever copy the address out.
unsafe impl Sync for SendPtr {}

/// Run `f(i)` for `i in 0..n` on transient scoped threads, collecting no
/// output. Unlike [`ThreadPool::scope_for`] this allows borrowing from the
/// caller's stack (used by the blocked GEMM hot path).
pub fn parallel_for(n_threads: usize, n: usize, f: impl Fn(usize) + Send + Sync) {
    if n == 0 {
        return;
    }
    let n_threads = n_threads.max(1).min(n);
    if n_threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        pool.scope_for(100, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_indices() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, 64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_zero_items_ok() {
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn pool_drop_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
