//! The compressed-inference serving engine (the DeepSparse stand-in for
//! Table 7 / Table 14).
//!
//! Architecture: a request queue feeds a *dynamic batcher* (pure, testable
//! [`Batcher`]) which releases batches when either the batch-size cap or the
//! wait deadline is hit; each batch prefills per-sequence across a worker
//! fan-out, then generates in lockstep through the batched planned kernels
//! ([`generate_batch`]); per-request latency and aggregate token throughput
//! are recorded in [`ServeStats`].

use crate::model::{KvCache, TransformerLM};
use crate::sparse::PackOptions;
use crate::tensor::argmax;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Dynamic batch cap.
    pub max_batch: usize,
    /// Max time the first queued request waits before dispatch.
    pub max_wait: Duration,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// Prefill worker threads (generation itself runs lockstep-batched;
    /// its parallelism comes from the kernels).
    pub workers: usize,
    /// Pre-pack compressed layers into their planned kernel formats
    /// (BCSR/N:M/CSR per `sparse::KernelPlan`) at server startup.
    pub prepack: bool,
    /// Opt-in i8 tile quantization while pre-packing: BCSR-planned layers
    /// upgrade to QBcsr when their per-tile quantization error passes the
    /// plan gate (`sparse::QBCSR_MAX_REL_ERROR`); checkpoints on disk stay
    /// f32.
    pub quantize: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            gen_tokens: 16,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            prepack: true,
            quantize: false,
        }
    }
}

impl ServeConfig {
    /// The packing policy this serving configuration implies.
    pub fn pack_options(&self) -> PackOptions {
        PackOptions { batch_hint: self.max_batch, quantize: self.quantize, ..Default::default() }
    }
}

/// An inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub enqueued: Instant,
}

/// A completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub latency: Duration,
}

/// Pure dynamic-batching policy: FIFO, size- and deadline-triggered.
#[derive(Default)]
pub struct Batcher {
    queue: std::collections::VecDeque<Request>,
}

impl Batcher {
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Release a batch if the policy triggers: the queue has `max_batch`
    /// requests, or the oldest request has waited past `max_wait`.
    pub fn ready(
        &mut self,
        now: Instant,
        max_batch: usize,
        max_wait: Duration,
    ) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let deadline_hit = now.duration_since(self.queue.front().unwrap().enqueued) >= max_wait;
        if self.queue.len() >= max_batch || deadline_hit {
            let n = self.queue.len().min(max_batch);
            Some(self.queue.drain(..n).collect())
        } else {
            None
        }
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self, max_batch: usize) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(max_batch);
            out.push(self.queue.drain(..n).collect());
        }
        out
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub n_requests: usize,
    pub tokens_generated: usize,
    pub wall_seconds: f64,
    pub latency: Summary,
    pub batch_sizes: Summary,
}

impl ServeStats {
    /// End-to-end generated-token throughput.
    pub fn tokens_per_second(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_seconds.max(1e-12)
    }
}

/// Greedy-generate `n` tokens from `prompt` (single-stream decode). An
/// empty prompt yields an empty completion: there are no logits to decode
/// from (the buffer would stay all-zero and argmax would emit token 0
/// forever).
pub fn generate(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    if prompt.is_empty() {
        return Vec::new();
    }
    let mut cache = KvCache::new(&model.cfg);
    let mut logits = vec![0.0f32; model.cfg.vocab];
    let budget = model.cfg.seq_len;
    for &t in prompt.iter().take(budget) {
        logits = model.decode_step(t, &mut cache);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        logits = model.decode_step(next, &mut cache);
    }
    out
}

/// Greedy-generate `n` tokens for a whole batch: per-sequence prefill
/// (ragged prompt lengths, fanned across `workers` threads), then lockstep
/// batched decode — each step runs the six linears and the head as
/// [b × d] products, which is the shape the planned BCSR/fused kernels are
/// packed for. Per-sequence results are independent of how requests are
/// batched (every output element accumulates in a fixed order), so
/// `generate_batch(m, &[p], n, 1)[0]` is the canonical reference for any
/// batching of `p`.
pub fn generate_batch(
    model: &TransformerLM,
    prompts: &[Vec<usize>],
    n: usize,
    workers: usize,
) -> Vec<Vec<usize>> {
    let b = prompts.len();
    if b == 0 {
        return Vec::new();
    }
    let budget = model.cfg.seq_len;
    // Phase 1: prefill. Each sequence owns its KV cache, so chunks of the
    // state vector fan out across scoped threads.
    let mut states: Vec<(KvCache, Vec<f32>)> = prompts
        .iter()
        .map(|_| (KvCache::new(&model.cfg), vec![0.0f32; model.cfg.vocab]))
        .collect();
    let chunk = b.div_ceil(workers.max(1)).max(1);
    std::thread::scope(|s| {
        for (chunk_states, chunk_prompts) in states.chunks_mut(chunk).zip(prompts.chunks(chunk)) {
            s.spawn(move || {
                for ((cache, logits), p) in chunk_states.iter_mut().zip(chunk_prompts) {
                    for &t in p.iter().take(budget) {
                        *logits = model.decode_step(t, cache);
                    }
                }
            });
        }
    });
    // Phase 2: lockstep batched generation over the still-active sequences.
    // Empty prompts never activate (matching `generate`: no logits to
    // decode from), so they return empty completions.
    let mut out: Vec<Vec<usize>> = (0..b).map(|_| Vec::with_capacity(n)).collect();
    for _ in 0..n {
        let active: Vec<usize> = (0..b)
            .filter(|&i| !prompts[i].is_empty() && states[i].0.len < budget)
            .collect();
        if active.is_empty() {
            break;
        }
        let tokens: Vec<usize> = active.iter().map(|&i| argmax(&states[i].1)).collect();
        for (&i, &t) in active.iter().zip(&tokens) {
            out[i].push(t);
        }
        let logits = {
            let mut next = 0usize;
            let mut cache_refs: Vec<&mut KvCache> = Vec::with_capacity(active.len());
            for (i, st) in states.iter_mut().enumerate() {
                if next < active.len() && active[next] == i {
                    cache_refs.push(&mut st.0);
                    next += 1;
                }
            }
            model.decode_step_batch(&tokens, &mut cache_refs)
        };
        for (r, &i) in active.iter().enumerate() {
            states[i].1.clear();
            states[i].1.extend_from_slice(logits.row(r));
        }
    }
    out
}

/// One queued submission: the request plus its response channel.
type Submission = (Request, mpsc::Sender<Response>);

/// Pull requests into the batcher: block up to `poll` for the first one,
/// then drain everything already queued with `try_recv`, so a burst enters
/// the batcher in ONE pump. (Pulling a single request per poll cycle made a
/// burst of N requests take N cycles to assemble, splintering
/// deadline-triggered dispatch into undersized batches.) Returns true once
/// the request channel has disconnected.
fn pump_requests(
    rx: &mpsc::Receiver<Submission>,
    poll: Duration,
    batcher: &mut Batcher,
    resp_txs: &mut HashMap<u64, mpsc::Sender<Response>>,
) -> bool {
    match rx.recv_timeout(poll) {
        Ok((req, tx)) => {
            resp_txs.insert(req.id, tx);
            batcher.push(req);
        }
        Err(mpsc::RecvTimeoutError::Timeout) => return false,
        Err(mpsc::RecvTimeoutError::Disconnected) => return true,
    }
    loop {
        match rx.try_recv() {
            Ok((req, tx)) => {
                resp_txs.insert(req.id, tx);
                batcher.push(req);
            }
            Err(mpsc::TryRecvError::Empty) => return false,
            Err(mpsc::TryRecvError::Disconnected) => return true,
        }
    }
}

/// The server: owns the batcher thread and the batched-decode executor.
pub struct Server {
    req_tx: Option<mpsc::Sender<Submission>>,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    pub observed_batches: Arc<Mutex<Vec<usize>>>,
}

impl Server {
    pub fn start(model: Arc<TransformerLM>, cfg: ServeConfig) -> Server {
        // Kernel-dispatch step: decode batches are `max_batch`-sized at most,
        // so pre-pack each compressed layer for that batch shape once, up
        // front, instead of running scalar CSR per request.
        let model = if cfg.prepack && model.needs_packing() {
            Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
        } else {
            model
        };
        let (req_tx, req_rx) = mpsc::channel::<Submission>();
        let observed_batches = Arc::new(Mutex::new(Vec::new()));
        let observed = Arc::clone(&observed_batches);

        let handle = std::thread::spawn(move || {
            let mut batcher = Batcher::default();
            let mut resp_txs: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
            let mut closed = false;
            loop {
                // Pull requests (with a short poll so deadlines fire),
                // draining any queued burst in one pump.
                let poll = Duration::from_micros(200);
                if pump_requests(&req_rx, poll, &mut batcher, &mut resp_txs) {
                    closed = true;
                }
                let now = Instant::now();
                let batches: Vec<Vec<Request>> = if closed {
                    batcher.drain_all(cfg.max_batch)
                } else {
                    batcher.ready(now, cfg.max_batch, cfg.max_wait).into_iter().collect()
                };
                for batch in batches {
                    observed.lock().unwrap().push(batch.len());
                    // Batched decode: prefill fans across workers, then the
                    // whole batch generates in lockstep so the linears run
                    // as [b × d] products through the planned kernels (this
                    // is the shape prepack chose formats for).
                    let txs: Vec<(Request, mpsc::Sender<Response>)> = batch
                        .into_iter()
                        .map(|r| {
                            let tx = resp_txs.remove(&r.id).expect("response channel");
                            (r, tx)
                        })
                        .collect();
                    let prompts: Vec<Vec<usize>> =
                        txs.iter().map(|(r, _)| r.prompt.clone()).collect();
                    let outs = generate_batch(&model, &prompts, cfg.gen_tokens, cfg.workers);
                    for ((req, tx), tokens) in txs.into_iter().zip(outs) {
                        let _ = tx.send(Response {
                            id: req.id,
                            tokens,
                            latency: req.enqueued.elapsed(),
                        });
                    }
                }
                if closed && batcher.is_empty() {
                    break;
                }
            }
        });

        Server { req_tx: Some(req_tx), batcher_handle: Some(handle), observed_batches }
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.req_tx
            .as_ref()
            .expect("server stopped")
            .send((Request { id, prompt, enqueued: Instant::now() }, tx))
            .expect("batcher alive");
        rx
    }

    /// Stop accepting requests and wait for in-flight work.
    pub fn shutdown(mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
    }
}

/// Closed-loop load test: submit `n_requests` prompts, wait for all, and
/// report stats. This is the Table 7 / Table 14 measurement harness.
pub fn run_load(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    prompts: Vec<Vec<usize>>,
) -> ServeStats {
    // Pack before starting the clock: packing is one-time startup cost and
    // must not bias the measured throughput of compressed models (the dense
    // baseline pays no equivalent cost).
    let model = if cfg.prepack && model.needs_packing() {
        Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
    } else {
        model
    };
    let t0 = Instant::now();
    let server = Server::start(model, cfg.clone());
    let rxs: Vec<mpsc::Receiver<Response>> = prompts
        .into_iter()
        .enumerate()
        .map(|(i, p)| server.submit(i as u64, p))
        .collect();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let n = rxs.len();
    for rx in rxs {
        let resp = rx.recv().expect("response");
        latencies.push(resp.latency.as_secs_f64());
        tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let batch_sizes: Vec<f64> = server
        .observed_batches
        .lock()
        .unwrap()
        .iter()
        .map(|&b| b as f64)
        .collect();
    server.shutdown();
    ServeStats {
        n_requests: n,
        tokens_generated: tokens,
        wall_seconds: wall,
        latency: Summary::of(&latencies),
        batch_sizes: Summary::of(&batch_sizes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::TransformerLM;
    use crate::util::prop::check;

    fn tiny() -> Arc<TransformerLM> {
        Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 5))
    }

    #[test]
    fn batcher_never_exceeds_cap_prop() {
        check("batcher cap", 50, |g| {
            let mut b = Batcher::default();
            let cap = g.usize_range(1, 8);
            let n = g.usize_range(0, 40);
            let t0 = Instant::now();
            let mut released = 0;
            for i in 0..n {
                b.push(Request { id: i as u64, prompt: vec![], enqueued: t0 });
                if let Some(batch) = b.ready(t0, cap, Duration::from_secs(999)) {
                    assert!(batch.len() <= cap);
                    assert_eq!(batch.len(), cap); // only size-triggered here
                    released += batch.len();
                }
            }
            for batch in b.drain_all(cap) {
                assert!(batch.len() <= cap);
                released += batch.len();
            }
            assert_eq!(released, n, "no request lost");
        });
    }

    #[test]
    fn batcher_deadline_triggers() {
        let mut b = Batcher::default();
        let old = Instant::now() - Duration::from_millis(50);
        b.push(Request { id: 0, prompt: vec![], enqueued: old });
        let batch = b.ready(Instant::now(), 100, Duration::from_millis(10));
        assert!(batch.is_some());
        assert_eq!(batch.unwrap().len(), 1);
    }

    #[test]
    fn batcher_fifo_order() {
        let mut b = Batcher::default();
        let t0 = Instant::now();
        for i in 0..5 {
            b.push(Request { id: i, prompt: vec![], enqueued: t0 });
        }
        let batch = b.ready(t0, 3, Duration::from_secs(999)).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn pump_drains_queued_burst_in_one_call() {
        // The serve loop must not need one poll cycle per request: a burst
        // already sitting in the channel enters the batcher in one pump.
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for i in 0..5u64 {
            let (rtx, _rrx) = mpsc::channel();
            tx.send((Request { id: i, prompt: vec![1], enqueued: t0 }, rtx)).unwrap();
        }
        let mut b = Batcher::default();
        let mut txs = HashMap::new();
        let closed = pump_requests(&rx, Duration::from_millis(10), &mut b, &mut txs);
        assert!(!closed);
        assert_eq!(b.len(), 5, "burst must enter the batcher in one pump");
        assert_eq!(txs.len(), 5);
        // Disconnect is reported once the senders are gone.
        drop(tx);
        assert!(pump_requests(&rx, Duration::from_millis(1), &mut b, &mut txs));
    }

    #[test]
    fn generate_respects_budget() {
        let m = tiny();
        let out = generate(&m, &[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| t < m.cfg.vocab));
        // Over-long generation stops at seq_len.
        let out2 = generate(&m, &[1, 2, 3], 10_000);
        assert!(out2.len() <= m.cfg.seq_len);
    }

    #[test]
    fn generate_deterministic() {
        let m = tiny();
        assert_eq!(generate(&m, &[4, 5], 8), generate(&m, &[4, 5], 8));
    }

    #[test]
    fn generate_batch_matches_scalar_generate() {
        // Dense model: the batched lockstep path is arithmetically identical
        // to per-sequence scalar decode, ragged prompt lengths included —
        // and an empty prompt yields an empty completion in both paths
        // (decoding from the all-zero logits buffer would emit token 0).
        let m = tiny();
        let prompts = vec![vec![1usize, 2, 3], vec![], vec![4usize, 5], vec![9usize]];
        let batch = generate_batch(&m, &prompts, 6, 2);
        assert_eq!(batch.len(), 4);
        for (p, got) in prompts.iter().zip(&batch) {
            assert_eq!(got, &generate(&m, p, 6), "prompt {p:?}");
        }
        assert!(batch[1].is_empty(), "empty prompt must yield empty completion");
        assert!(generate(&m, &[], 5).is_empty());
        assert!(generate_batch(&m, &[], 4, 2).is_empty());
    }

    #[test]
    fn generate_batch_respects_budget() {
        let m = tiny();
        let long: Vec<usize> = (0..m.cfg.seq_len - 2).map(|i| i % 16).collect();
        let outs = generate_batch(&m, &[long.clone(), vec![1, 2]], 10_000, 2);
        assert_eq!(outs[0].len(), 2, "near-full cache generates to the cap");
        assert!(outs[1].len() <= m.cfg.seq_len);
    }

    #[test]
    fn server_round_trip() {
        let m = tiny();
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            gen_tokens: 4,
            workers: 2,
            prepack: true,
            quantize: false,
        };
        let stats = run_load(m, cfg, (0..10).map(|i| vec![i % 16, 1, 2]).collect());
        assert_eq!(stats.n_requests, 10);
        assert_eq!(stats.tokens_generated, 40);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.latency.max >= stats.latency.min);
    }

    #[test]
    fn prepacked_server_matches_unpacked_outputs() {
        // Compress a model, then serve it with and without kernel pre-packing:
        // generated tokens must be identical.
        let base = TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 21);
        let corpus = crate::data::SyntheticCorpus::new(crate::data::CorpusConfig::for_vocab(
            base.cfg.vocab,
            2,
        ));
        let calib = crate::calib::CalibSet::sample(&corpus, 4, 16, 4);
        let ccfg = crate::config::CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 2,
            ..Default::default()
        };
        let (cm, _) =
            crate::coordinator::pipeline::compress_clone(&base, &calib, &ccfg, 2).unwrap();
        assert!(cm.needs_packing());
        let prompts: Vec<Vec<usize>> = (0..6).map(|i| vec![i % 16, 3, 5]).collect();
        let run = |prepack: bool| -> Vec<Vec<usize>> {
            let cfg = ServeConfig { max_batch: 4, gen_tokens: 6, prepack, ..Default::default() };
            let server = Server::start(Arc::new(cm.clone()), cfg);
            let rxs: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| server.submit(i as u64, p.clone()))
                .collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect()
        };
        // Each server mode must reproduce direct batched decode through the
        // same kernels bit-for-bit. (Packed vs unpacked numerics only agree
        // to ~1e-4, so cross-mode token equality would be tie-dependent;
        // per-sequence results are independent of batch grouping, so the
        // dynamic batcher's splits don't matter.)
        let want_packed = generate_batch(&cm.packed_for_serving(4), &prompts, 6, 1);
        assert_eq!(run(true), want_packed);
        let want_unpacked = generate_batch(&cm, &prompts, 6, 1);
        assert_eq!(run(false), want_unpacked);
    }

    #[test]
    fn server_batches_under_cap() {
        let m = tiny();
        let cfg = ServeConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
            gen_tokens: 2,
            workers: 2,
            prepack: true,
            quantize: false,
        };
        let server = Server::start(m, cfg);
        let rxs: Vec<_> = (0..7).map(|i| server.submit(i, vec![1, 2])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = server.observed_batches.lock().unwrap().clone();
        assert!(batches.iter().all(|&b| b <= 3), "{batches:?}");
        assert_eq!(batches.iter().sum::<usize>(), 7);
        drop(server);
    }

    #[test]
    fn server_dispatches_prequeued_burst_as_one_batch() {
        // A burst of exactly max_batch requests must assemble into ONE
        // size-triggered batch: the pump drains the queued burst and the
        // generous deadline never fires first.
        let m = tiny();
        let cfg = ServeConfig {
            max_batch: 6,
            max_wait: Duration::from_secs(30),
            gen_tokens: 2,
            workers: 2,
            prepack: true,
            quantize: false,
        };
        let server = Server::start(m, cfg);
        let rxs: Vec<_> = (0..6).map(|i| server.submit(i, vec![1, 2])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = server.observed_batches.lock().unwrap().clone();
        assert_eq!(batches, vec![6], "burst must dispatch as a single full batch");
    }
}
