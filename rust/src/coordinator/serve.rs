//! The compressed-inference serving engine (the DeepSparse stand-in for
//! Table 7 / Table 14).
//!
//! Architecture: a request queue feeds a *dynamic batcher* (pure, testable
//! [`Batcher`]) which releases batches when either the batch-size cap or the
//! wait deadline is hit; a worker pool executes each batch member's
//! KV-cached decode loop; per-request latency and aggregate token
//! throughput are recorded in [`ServeStats`].

use crate::model::{KvCache, TransformerLM};
use crate::tensor::argmax;
use crate::util::stats::Summary;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Dynamic batch cap.
    pub max_batch: usize,
    /// Max time the first queued request waits before dispatch.
    pub max_wait: Duration,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// Executor threads.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            gen_tokens: 16,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

/// An inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub enqueued: Instant,
}

/// A completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub latency: Duration,
}

/// Pure dynamic-batching policy: FIFO, size- and deadline-triggered.
#[derive(Default)]
pub struct Batcher {
    queue: std::collections::VecDeque<Request>,
}

impl Batcher {
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Release a batch if the policy triggers: the queue has `max_batch`
    /// requests, or the oldest request has waited past `max_wait`.
    pub fn ready(&mut self, now: Instant, max_batch: usize, max_wait: Duration) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let deadline_hit =
            now.duration_since(self.queue.front().unwrap().enqueued) >= max_wait;
        if self.queue.len() >= max_batch || deadline_hit {
            let n = self.queue.len().min(max_batch);
            Some(self.queue.drain(..n).collect())
        } else {
            None
        }
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self, max_batch: usize) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(max_batch);
            out.push(self.queue.drain(..n).collect());
        }
        out
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub n_requests: usize,
    pub tokens_generated: usize,
    pub wall_seconds: f64,
    pub latency: Summary,
    pub batch_sizes: Summary,
}

impl ServeStats {
    /// End-to-end generated-token throughput.
    pub fn tokens_per_second(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_seconds.max(1e-12)
    }
}

/// Greedy-generate `n` tokens from `prompt` (the executor inner loop).
pub fn generate(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    let mut cache = KvCache::new(&model.cfg);
    let mut logits = vec![0.0f32; model.cfg.vocab];
    let budget = model.cfg.seq_len;
    for &t in prompt.iter().take(budget) {
        logits = model.decode_step(t, &mut cache);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        logits = model.decode_step(next, &mut cache);
    }
    out
}

/// The server: owns the batcher thread and executor pool.
pub struct Server {
    req_tx: Option<mpsc::Sender<(Request, mpsc::Sender<Response>)>>,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    pub observed_batches: Arc<Mutex<Vec<usize>>>,
}

impl Server {
    pub fn start(model: Arc<TransformerLM>, cfg: ServeConfig) -> Server {
        let (req_tx, req_rx) = mpsc::channel::<(Request, mpsc::Sender<Response>)>();
        let observed_batches = Arc::new(Mutex::new(Vec::new()));
        let observed = Arc::clone(&observed_batches);

        let handle = std::thread::spawn(move || {
            let mut batcher = Batcher::default();
            let mut resp_txs: std::collections::HashMap<u64, mpsc::Sender<Response>> =
                std::collections::HashMap::new();
            let mut closed = false;
            loop {
                // Pull requests (with a short poll so deadlines fire).
                match req_rx.recv_timeout(Duration::from_micros(200)) {
                    Ok((req, tx)) => {
                        resp_txs.insert(req.id, tx);
                        batcher.push(req);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                }
                let now = Instant::now();
                let batches: Vec<Vec<Request>> = if closed {
                    batcher.drain_all(cfg.max_batch)
                } else {
                    batcher.ready(now, cfg.max_batch, cfg.max_wait).into_iter().collect()
                };
                for batch in batches {
                    observed.lock().unwrap().push(batch.len());
                    // Fan the batch out over scoped worker threads.
                    let model = Arc::clone(&model);
                    let txs: Vec<(Request, mpsc::Sender<Response>)> = batch
                        .into_iter()
                        .map(|r| {
                            let tx = resp_txs.remove(&r.id).expect("response channel");
                            (r, tx)
                        })
                        .collect();
                    let n_workers = cfg.workers.min(txs.len()).max(1);
                    let items = Arc::new(Mutex::new(txs));
                    std::thread::scope(|s| {
                        for _ in 0..n_workers {
                            let items = Arc::clone(&items);
                            let model = Arc::clone(&model);
                            s.spawn(move || loop {
                                let next = items.lock().unwrap().pop();
                                let Some((req, tx)) = next else { break };
                                let tokens = generate(&model, &req.prompt, cfg.gen_tokens);
                                let _ = tx.send(Response {
                                    id: req.id,
                                    tokens,
                                    latency: req.enqueued.elapsed(),
                                });
                            });
                        }
                    });
                }
                if closed && batcher.is_empty() {
                    break;
                }
            }
        });

        Server { req_tx: Some(req_tx), batcher_handle: Some(handle), observed_batches }
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.req_tx
            .as_ref()
            .expect("server stopped")
            .send((Request { id, prompt, enqueued: Instant::now() }, tx))
            .expect("batcher alive");
        rx
    }

    /// Stop accepting requests and wait for in-flight work.
    pub fn shutdown(mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
    }
}

/// Closed-loop load test: submit `n_requests` prompts, wait for all, and
/// report stats. This is the Table 7 / Table 14 measurement harness.
pub fn run_load(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    prompts: Vec<Vec<usize>>,
) -> ServeStats {
    let t0 = Instant::now();
    let server = Server::start(model, cfg.clone());
    let rxs: Vec<mpsc::Receiver<Response>> = prompts
        .into_iter()
        .enumerate()
        .map(|(i, p)| server.submit(i as u64, p))
        .collect();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let n = rxs.len();
    for rx in rxs {
        let resp = rx.recv().expect("response");
        latencies.push(resp.latency.as_secs_f64());
        tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let batch_sizes: Vec<f64> = server
        .observed_batches
        .lock()
        .unwrap()
        .iter()
        .map(|&b| b as f64)
        .collect();
    server.shutdown();
    ServeStats {
        n_requests: n,
        tokens_generated: tokens,
        wall_seconds: wall,
        latency: Summary::of(&latencies),
        batch_sizes: Summary::of(&batch_sizes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::TransformerLM;
    use crate::util::prop::check;

    fn tiny() -> Arc<TransformerLM> {
        Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 5))
    }

    #[test]
    fn batcher_never_exceeds_cap_prop() {
        check("batcher cap", 50, |g| {
            let mut b = Batcher::default();
            let cap = g.usize_range(1, 8);
            let n = g.usize_range(0, 40);
            let t0 = Instant::now();
            let mut released = 0;
            for i in 0..n {
                b.push(Request { id: i as u64, prompt: vec![], enqueued: t0 });
                if let Some(batch) = b.ready(t0, cap, Duration::from_secs(999)) {
                    assert!(batch.len() <= cap);
                    assert_eq!(batch.len(), cap); // only size-triggered here
                    released += batch.len();
                }
            }
            for batch in b.drain_all(cap) {
                assert!(batch.len() <= cap);
                released += batch.len();
            }
            assert_eq!(released, n, "no request lost");
        });
    }

    #[test]
    fn batcher_deadline_triggers() {
        let mut b = Batcher::default();
        let old = Instant::now() - Duration::from_millis(50);
        b.push(Request { id: 0, prompt: vec![], enqueued: old });
        let batch = b.ready(Instant::now(), 100, Duration::from_millis(10));
        assert!(batch.is_some());
        assert_eq!(batch.unwrap().len(), 1);
    }

    #[test]
    fn batcher_fifo_order() {
        let mut b = Batcher::default();
        let t0 = Instant::now();
        for i in 0..5 {
            b.push(Request { id: i, prompt: vec![], enqueued: t0 });
        }
        let batch = b.ready(t0, 3, Duration::from_secs(999)).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn generate_respects_budget() {
        let m = tiny();
        let out = generate(&m, &[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| t < m.cfg.vocab));
        // Over-long generation stops at seq_len.
        let out2 = generate(&m, &[1, 2, 3], 10_000);
        assert!(out2.len() <= m.cfg.seq_len);
    }

    #[test]
    fn generate_deterministic() {
        let m = tiny();
        assert_eq!(generate(&m, &[4, 5], 8), generate(&m, &[4, 5], 8));
    }

    #[test]
    fn server_round_trip() {
        let m = tiny();
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            gen_tokens: 4,
            workers: 2,
        };
        let stats = run_load(m, cfg, (0..10).map(|i| vec![i % 16, 1, 2]).collect());
        assert_eq!(stats.n_requests, 10);
        assert_eq!(stats.tokens_generated, 40);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.latency.max >= stats.latency.min);
    }

    #[test]
    fn server_batches_under_cap() {
        let m = tiny();
        let cfg = ServeConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
            gen_tokens: 2,
            workers: 2,
        };
        let server = Server::start(m, cfg);
        let rxs: Vec<_> = (0..7).map(|i| server.submit(i, vec![1, 2])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = server.observed_batches.lock().unwrap().clone();
        assert!(batches.iter().all(|&b| b <= 3), "{batches:?}");
        assert_eq!(batches.iter().sum::<usize>(), 7);
        drop(server);
    }
}
