//! The compressed-inference serving layer (the DeepSparse stand-in for
//! Table 7 / Table 14).
//!
//! Architecture: a request channel feeds the admission queue ([`Batcher`]);
//! the **continuous-batching engine** ([`crate::coordinator::engine`])
//! owns a fixed **paged** KV arena and, every step, admits queued requests
//! into free slots (gated on each joiner's worst-case page reservation),
//! runs chunked prefill for joiners, decodes all resident sequences in
//! lockstep through the batched planned kernels, and retires finished
//! sequences — returning their pages to the free list and backfilling
//! their slots from the queue in the same step. With `page_size <
//! seq_len`, short sequences hold only the pages their length needs, so
//! mixed-length traffic fits more concurrent sequences into the same KV
//! bytes. Requests join and leave mid-flight; nothing waits for a batch to
//! drain. Per-token streaming, per-request latency (completion and first
//! token), and per-step engine telemetry are reported via [`ServeStats`].
//!
//! **Overload behavior.** The engine-level knobs ride through
//! [`ServeConfig`]: `preemption` lets the engine evict a resident victim
//! when a strictly higher-priority request is blocked, `slo_first_token_steps`
//! + `shed_policy` drop lowest-priority queued work once the predicted
//! queue wait exceeds the SLO ([`ResponseStatus::Shed`]), and
//! [`ArrivalPlan`] drives *open-loop* request injection (poisson / burst /
//! ramp storms) through the deterministic synchronous driver
//! [`run_load_open`], so overload scenarios reproduce step-for-step from a
//! seed.

use crate::coordinator::engine::{Engine, EngineConfig, EngineTelemetry, SeqEvent};
use crate::json::{self, Json};
use crate::model::{KvCache, TransformerLM};
use crate::sparse::PackOptions;
use crate::tensor::argmax;
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::coordinator::engine::{
    AdmissionPolicy, Batcher, Priority, Request, ResponseStatus, ShedPolicy,
};

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// KV-slot arena size: the bound on resident sequences, decode batch
    /// width, and KV memory (`slots` preallocated caches).
    pub slots: usize,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// Max prompt tokens a joining sequence prefills per engine step
    /// (higher = faster first token for joiners, chunkier interleaving
    /// with resident decodes).
    pub prefill_chunk: usize,
    /// Order in which queued requests claim freed slots.
    pub admission: AdmissionPolicy,
    /// Pre-pack compressed layers into their planned kernel formats
    /// (BCSR/N:M/CSR per `sparse::KernelPlan`) at server startup.
    pub prepack: bool,
    /// Opt-in i8 tile quantization while pre-packing: BCSR-planned layers
    /// upgrade to QBcsr when their per-tile quantization error passes the
    /// plan gate (`sparse::QBCSR_MAX_REL_ERROR`); checkpoints on disk stay
    /// f32.
    pub quantize: bool,
    /// KV positions per page. `0` ⇒ whole-sequence pages (`seq_len`): the
    /// contiguous pre-paging layout. Smaller pages let short sequences
    /// hold only the KV bytes they use, so more of them fit per byte.
    pub page_size: usize,
    /// Total KV pages in the arena. `0` ⇒ `slots` full sequences' worth
    /// (byte-equivalent to the whole-cache arena).
    pub kv_pages: usize,
    /// Let requests reuse shared prefix KV pages (the engine's prefix
    /// index). `false` stamps every submitted request with the per-request
    /// opt-out — the A/B switch the CI byte-identity gate flips.
    pub share_prefix: bool,
    /// Max entries the prefix index keeps resident (`0` ⇒ unbounded).
    /// Overflow LRU-evicts unreferenced entries deterministically and
    /// reports them as `prefix_evictions_cap`.
    pub prefix_cap: usize,
    /// Let the engine evict a resident victim (releasing its pages and
    /// re-queuing it with generated tokens saved) when a strictly
    /// higher-priority request is blocked on slots or pages.
    pub preemption: bool,
    /// First-token SLO in engine steps of queue wait (`0` ⇒ no SLO).
    /// Feeds both `goodput_under_slo` accounting and the shed predicate.
    pub slo_first_token_steps: usize,
    /// What to drop when the predicted queue wait blows through the SLO.
    pub shed_policy: ShedPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 8,
            gen_tokens: 16,
            prefill_chunk: 8,
            admission: AdmissionPolicy::Fcfs,
            prepack: true,
            quantize: false,
            page_size: 0,
            kv_pages: 0,
            share_prefix: true,
            prefix_cap: 0,
            preemption: false,
            slo_first_token_steps: 0,
            shed_policy: ShedPolicy::Off,
        }
    }
}

impl ServeConfig {
    /// The packing policy this serving configuration implies: decode
    /// batches are at most `slots` wide, so layers pack for that shape.
    pub fn pack_options(&self) -> PackOptions {
        PackOptions { batch_hint: self.slots, quantize: self.quantize, ..Default::default() }
    }

    /// The engine knobs this configuration implies.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            slots: self.slots.max(1),
            prefill_chunk: self.prefill_chunk.max(1),
            gen_tokens: self.gen_tokens,
            admission: self.admission,
            page_size: self.page_size,
            kv_pages: self.kv_pages,
            prefix_cap: self.prefix_cap,
            preemption: self.preemption,
            slo_first_token_steps: self.slo_first_token_steps,
            shed_policy: self.shed_policy,
        }
    }
}

/// When each request of an open-loop workload enters the admission queue,
/// measured on the engine's step clock — a seeded deterministic stand-in
/// for wall-clock arrival processes, so storm scenarios replay exactly.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalPlan {
    /// Every request queued up front; the engine drains the backlog (the
    /// closed-loop measurement harness).
    Closed,
    /// Open loop: i.i.d. exponential inter-arrival gaps at `rate` requests
    /// per engine step, drawn from the seeded [`Rng`] stream.
    Poisson { rate: f64 },
    /// Open loop: bursts of `n` back-to-back arrivals separated by `gap`
    /// idle steps — the overload-spike shape the CI gate leans on.
    Burst { n: usize, gap: usize },
    /// Open loop: inter-arrival gaps shrink linearly across the workload,
    /// ramping a lazy trickle up into saturation.
    Ramp,
}

impl ArrivalPlan {
    /// Parse `closed` | `poisson:RATE` | `burst:N:GAP` | `ramp`.
    pub fn parse(s: &str) -> anyhow::Result<ArrivalPlan> {
        let bad = || {
            anyhow::anyhow!("unknown arrival plan '{s}' (closed|poisson:RATE|burst:N:GAP|ramp)")
        };
        match s.split(':').collect::<Vec<_>>().as_slice() {
            ["closed"] => Ok(ArrivalPlan::Closed),
            ["ramp"] => Ok(ArrivalPlan::Ramp),
            ["poisson", rate] => {
                let rate: f64 = rate.parse().map_err(|_| bad())?;
                anyhow::ensure!(rate > 0.0 && rate.is_finite(), "poisson rate must be positive");
                Ok(ArrivalPlan::Poisson { rate })
            }
            ["burst", n, gap] => {
                let n: usize = n.parse().map_err(|_| bad())?;
                let gap: usize = gap.parse().map_err(|_| bad())?;
                anyhow::ensure!(n > 0, "burst size must be positive");
                Ok(ArrivalPlan::Burst { n, gap })
            }
            _ => Err(bad()),
        }
    }

    /// Canonical label, `parse`-round-trippable and echoed into SERVE json.
    pub fn label(&self) -> String {
        match self {
            ArrivalPlan::Closed => "closed".to_string(),
            ArrivalPlan::Poisson { rate } => format!("poisson:{rate}"),
            ArrivalPlan::Burst { n, gap } => format!("burst:{n}:{gap}"),
            ArrivalPlan::Ramp => "ramp".to_string(),
        }
    }

    /// Arrival step for each of `n` requests, non-decreasing. Only the
    /// Poisson shape consumes the seed; the rest are seed-independent.
    pub fn schedule(&self, n: usize, seed: u64) -> Vec<u64> {
        match *self {
            ArrivalPlan::Closed => vec![0; n],
            ArrivalPlan::Poisson { rate } => {
                let mut rng = Rng::new(seed ^ 0x4A55_4C49_4152_5249);
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        // Inverse-CDF exponential gap; 1 − u ∈ (0, 1] keeps
                        // ln finite.
                        t += -(1.0 - rng.f64()).ln() / rate;
                        t as u64
                    })
                    .collect()
            }
            ArrivalPlan::Burst { n: burst, gap } => {
                (0..n).map(|i| ((i / burst) * gap) as u64).collect()
            }
            ArrivalPlan::Ramp => {
                let mut t = 0u64;
                (0..n)
                    .map(|i| {
                        let at = t;
                        // Gaps shrink toward back-to-back as i → n.
                        t += ((n - i) as u64).div_ceil(4).max(1);
                        at
                    })
                    .collect()
            }
        }
    }
}

/// A completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Enqueue → completion.
    pub latency: Duration,
    /// Enqueue → admission into a KV slot (for slot-free answers: to the
    /// answering step) — the queueing share of `first_token_latency`.
    pub queue_wait: Duration,
    /// Enqueue → first generated token (`None` if nothing was generated).
    pub first_token_latency: Option<Duration>,
    /// [`ResponseStatus::Truncated`] marks a prompt that exceeded the
    /// model's `seq_len` and was rejected rather than silently cut;
    /// [`ResponseStatus::CapacityStopped`] marks generation cut short by
    /// KV capacity (fewer tokens than the budget, by memory not choice);
    /// [`ResponseStatus::StoppedAtToken`] marks generation ended by one of
    /// the request's stop tokens (which is the last token returned);
    /// [`ResponseStatus::Shed`] marks queued work dropped by the SLO shed
    /// policy (tokens hold whatever a prior preempted residency generated).
    pub status: ResponseStatus,
    /// Priority tier the request ran under (feeds the per-tier latency
    /// summaries).
    pub priority: Priority,
}

/// One event on a streaming response channel.
#[derive(Debug)]
pub enum StreamEvent {
    /// A generated token, sent as soon as the engine emits it.
    Token { token: usize, first: bool },
    /// Terminal event: the full response (tokens repeated in order).
    Done(Response),
}

/// How a submission wants its results delivered.
enum ResponseSink {
    Unary(mpsc::Sender<Response>),
    Stream(mpsc::Sender<StreamEvent>),
}

/// One queued submission: the request plus its response channel.
type Submission = (Request, ResponseSink);

/// Aggregate serving statistics: request-level latencies plus the engine's
/// per-step telemetry.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub n_requests: usize,
    pub tokens_generated: usize,
    pub wall_seconds: f64,
    /// Enqueue → completion, per request (seconds).
    pub latency: Summary,
    /// Enqueue → admission, per request (seconds) — how long requests sat
    /// in the queue before the engine took them, reported separately from
    /// `first_token_latency` (which it is a component of).
    pub queue_wait: Summary,
    /// Enqueue → first generated token, over requests that generated.
    pub first_token_latency: Summary,
    /// Decode-batch width per engine step.
    pub batch_sizes: Summary,
    /// Occupied-slot fraction per engine step (1.0 = arena full).
    pub slot_occupancy: Summary,
    /// Admission-queue depth per engine step.
    pub queue_depth: Summary,
    /// Held-page fraction per engine step (1.0 = every KV page attached).
    pub page_occupancy: Summary,
    /// Pages attached to resident sequences, per engine step.
    pub pages_in_use: Summary,
    /// Sequences admitted into / retired from KV slots.
    pub joins: usize,
    pub leaves: usize,
    /// Requests rejected for oversized prompts.
    pub truncated: usize,
    /// Requests stopped by KV capacity before their generation budget.
    pub capacity_stopped: usize,
    /// Residents evicted mid-flight for higher-priority work.
    pub preemptions: usize,
    /// Queued requests dropped by the SLO shed policy.
    pub shed: usize,
    /// Already-computed tokens re-prefilled when preempted victims
    /// readmitted (the KV recompute bill preemption pays).
    pub victim_recompute_tokens: usize,
    /// Fraction of submitted requests whose first token landed within
    /// `slo_first_token_steps` of queue wait (all first tokens when no SLO
    /// was configured).
    pub goodput_under_slo: f64,
    /// Arrival plan the workload ran under (e.g. `closed`, `burst:8:4`).
    pub arrivals: String,
    /// First-token latency split by priority tier (seconds; empty tiers
    /// summarize to zero).
    pub ftl_interactive: Summary,
    pub ftl_batch: Summary,
    pub ftl_background: Summary,
    /// Engine steps that did work.
    pub steps: usize,
    /// Configured KV-slot arena size.
    pub slots: usize,
    /// KV positions per page / total pages in the arena.
    pub page_size: usize,
    pub kv_pages: usize,
    /// Pages still attached when the run drained (0 = nothing leaked).
    pub pages_in_use_at_drain: usize,
    /// Constant KV-arena footprint in bytes.
    pub kv_bytes: usize,
    /// Fresh heap buffers the decode workspace ever allocated — flat once
    /// decode reaches steady state (the xt/out-reuse regression check).
    pub ws_buffer_allocs: usize,
    /// Prompt tokens admission skipped because their KV already existed as
    /// shared prefix pages.
    pub prefill_tokens_saved: usize,
    /// Shared prefix page mappings attached to joiners at admission.
    pub shared_pages: usize,
    /// Copy-on-write forks of shared pages.
    pub cow_forks: usize,
    /// Prefix-index entries LRU-evicted by the capacity cap.
    pub prefix_evictions_cap: usize,
    /// Engine wall-clock by phase, lifetime totals in seconds (admission
    /// incl. same-step backfill / chunked prefill / lockstep decode /
    /// retirement / whole step). Always measured; the four phase totals
    /// sum to at most `time_step_s`.
    pub time_admit_s: f64,
    pub time_prefill_s: f64,
    pub time_decode_s: f64,
    pub time_retire_s: f64,
    pub time_step_s: f64,
    /// Per-kernel-format forward time in seconds, aggregated from
    /// `kernel_*` trace spans (e.g. `("bcsr", 1.2)`). Empty unless the run
    /// was traced — kernel spans only exist when tracing is enabled.
    pub kernel_time: Vec<(String, f64)>,
    /// Order-independent FNV-1a digest over every `(id, tokens)` pair,
    /// accumulated in request-id order. Two runs of the same workload with
    /// byte-identical completions produce the same digest — the handle the
    /// CI shared-vs-unshared identity gate compares. Zero when the harness
    /// didn't compute one (e.g. stats taken from a live server snapshot).
    pub completions_digest: u64,
}

impl ServeStats {
    /// End-to-end generated-token throughput.
    pub fn tokens_per_second(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_seconds.max(1e-12)
    }

    fn from_run(
        n_requests: usize,
        wall_seconds: f64,
        acc: &RunAccumulator,
        arrivals: String,
        t: &EngineTelemetry,
    ) -> ServeStats {
        ServeStats {
            n_requests,
            tokens_generated: acc.tokens,
            wall_seconds,
            latency: Summary::of(&acc.latencies),
            queue_wait: Summary::of(&acc.queue_waits),
            first_token_latency: Summary::of(&acc.first_token_latencies),
            batch_sizes: Summary::of(&t.decode_batch),
            slot_occupancy: Summary::of(&t.occupancy),
            queue_depth: Summary::of(&t.queue_depth),
            page_occupancy: Summary::of(&t.page_occupancy),
            pages_in_use: Summary::of(&t.pages_in_use),
            joins: t.joins,
            leaves: t.leaves,
            truncated: t.truncated,
            capacity_stopped: t.capacity_stopped,
            preemptions: t.preemptions,
            shed: t.shed,
            victim_recompute_tokens: t.victim_recompute_tokens,
            goodput_under_slo: t.slo_hits as f64 / n_requests.max(1) as f64,
            arrivals,
            ftl_interactive: Summary::of(&acc.ftl_by_tier[Priority::Interactive.rank() as usize]),
            ftl_batch: Summary::of(&acc.ftl_by_tier[Priority::Batch.rank() as usize]),
            ftl_background: Summary::of(&acc.ftl_by_tier[Priority::Background.rank() as usize]),
            steps: t.steps,
            slots: t.slots,
            page_size: t.page_size,
            kv_pages: t.total_pages,
            pages_in_use_at_drain: t.pages_in_use_now,
            kv_bytes: t.kv_bytes,
            ws_buffer_allocs: t.ws_buffer_allocs,
            prefill_tokens_saved: t.prefill_tokens_saved,
            shared_pages: t.shared_pages,
            cow_forks: t.cow_forks,
            prefix_evictions_cap: t.prefix_evictions_cap,
            time_admit_s: t.time_admit_s,
            time_prefill_s: t.time_prefill_s,
            time_decode_s: t.time_decode_s,
            time_retire_s: t.time_retire_s,
            time_step_s: t.time_step_s,
            kernel_time: Vec::new(),
            completions_digest: acc.digest,
        }
    }

    /// Machine-readable record (`oats-serve-v1`) — the serve analogue of
    /// the bench harness's `oats-bench-v1` document.
    pub fn to_json(&self, suite: &str) -> Json {
        let mut o = Json::obj();
        o.set("suite", json::s(suite))
            .set("schema", json::s("oats-serve-v1"))
            .set("requests", json::num(self.n_requests as f64))
            .set("tokens_generated", json::num(self.tokens_generated as f64))
            .set("wall_seconds", json::num(self.wall_seconds))
            .set("tokens_per_second", json::num(self.tokens_per_second()))
            .set("joins", json::num(self.joins as f64))
            .set("leaves", json::num(self.leaves as f64))
            .set("truncated", json::num(self.truncated as f64))
            .set("capacity_stopped", json::num(self.capacity_stopped as f64))
            .set("preemptions", json::num(self.preemptions as f64))
            .set("shed", json::num(self.shed as f64))
            .set("victim_recompute_tokens", json::num(self.victim_recompute_tokens as f64))
            .set("goodput_under_slo", json::num(self.goodput_under_slo))
            .set("arrivals", json::s(&self.arrivals))
            .set("steps", json::num(self.steps as f64))
            .set("slots", json::num(self.slots as f64))
            .set("page_size", json::num(self.page_size as f64))
            .set("kv_pages", json::num(self.kv_pages as f64))
            .set("pages_in_use_at_drain", json::num(self.pages_in_use_at_drain as f64))
            .set("kv_arena_bytes", json::num(self.kv_bytes as f64))
            .set("ws_buffer_allocs", json::num(self.ws_buffer_allocs as f64))
            .set("prefill_tokens_saved", json::num(self.prefill_tokens_saved as f64))
            .set("shared_pages", json::num(self.shared_pages as f64))
            .set("cow_forks", json::num(self.cow_forks as f64))
            .set("prefix_evictions_cap", json::num(self.prefix_evictions_cap as f64))
            .set("time_admit_s", json::num(self.time_admit_s))
            .set("time_prefill_s", json::num(self.time_prefill_s))
            .set("time_decode_s", json::num(self.time_decode_s))
            .set("time_retire_s", json::num(self.time_retire_s))
            .set("time_step_s", json::num(self.time_step_s))
            // u64 doesn't fit an f64 losslessly: the digest travels as hex.
            .set("completions_digest", json::s(&format!("{:016x}", self.completions_digest)))
            .set("latency_s", self.latency.to_json())
            .set("queue_wait", self.queue_wait.to_json())
            .set("first_token_latency_s", self.first_token_latency.to_json())
            .set("first_token_latency_interactive", self.ftl_interactive.to_json())
            .set("first_token_latency_batch", self.ftl_batch.to_json())
            .set("first_token_latency_background", self.ftl_background.to_json())
            .set("decode_batch", self.batch_sizes.to_json())
            .set("slot_occupancy", self.slot_occupancy.to_json())
            .set("queue_depth", self.queue_depth.to_json())
            .set("page_occupancy", self.page_occupancy.to_json())
            .set("pages_in_use", self.pages_in_use.to_json());
        let mut kt = Json::obj();
        for (fmt, secs) in &self.kernel_time {
            kt.set(fmt, json::num(*secs));
        }
        o.set("kernel_time", kt);
        o
    }

    /// Write `SERVE_<suite>.json` into `$OATS_BENCH_DIR` (default: cwd),
    /// alongside the `BENCH_*.json` family, so serve-perf history
    /// accumulates per CI run.
    pub fn write_json(&self, suite: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("OATS_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        let path = std::path::Path::new(&dir).join(format!("SERVE_{suite}.json"));
        std::fs::write(&path, self.to_json(suite).to_pretty())?;
        println!("serve json -> {}", path.display());
        Ok(path)
    }
}

/// Greedy-generate `n` tokens from `prompt` (single-stream decode). An
/// empty prompt yields an empty completion: there are no logits to decode
/// from (the buffer would stay all-zero and argmax would emit token 0
/// forever). This is the scalar reference the engine is property-tested
/// against; prompts beyond `seq_len` are truncated here (the serving path
/// rejects them with [`ResponseStatus::Truncated`] instead).
pub fn generate(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    if prompt.is_empty() {
        return Vec::new();
    }
    let mut cache = KvCache::new(&model.cfg);
    let mut logits = vec![0.0f32; model.cfg.vocab];
    let budget = model.cfg.seq_len;
    for &t in prompt.iter().take(budget) {
        logits = model.decode_step(t, &mut cache);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        logits = model.decode_step(next, &mut cache);
    }
    out
}

/// Single-sequence reference that routes EVERY step — prefill included —
/// through [`TransformerLM::decode_step_batch`] at batch 1: the engine's
/// exact compute path. Per-row results of the batched kernels are
/// independent of batch width, so this equals the continuous-batching
/// engine's output for any interleaving. For dense models it also equals
/// [`generate`] bit-for-bit; for packed/compressed models the batched
/// kernels' accumulation order can differ from the scalar `decode_step`
/// path in the last ulps (enough to flip an argmax near-tie), so
/// engine-parity tests on packed models must compare against this, not
/// against the scalar-prefill paths.
pub fn generate_lockstep(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    if prompt.is_empty() {
        return Vec::new();
    }
    let budget = model.cfg.seq_len;
    let mut cache = KvCache::new(&model.cfg);
    let mut logits: Vec<f32> = vec![0.0; model.cfg.vocab];
    let step = |tok: usize, cache: &mut KvCache, logits: &mut Vec<f32>| {
        let m = model.decode_step_batch(&[tok], &mut [cache]);
        logits.clear();
        logits.extend_from_slice(m.row(0));
    };
    for &t in prompt.iter().take(budget) {
        step(t, &mut cache, &mut logits);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        step(next, &mut cache, &mut logits);
    }
    out
}

/// Greedy-generate `n` tokens for a whole batch: per-sequence prefill
/// (ragged prompt lengths, fanned across `workers` threads), then lockstep
/// batched decode — each step runs the six linears and the head as
/// [b × d] products, which is the shape the planned BCSR/fused kernels are
/// packed for. Per-sequence results are independent of how requests are
/// grouped into batches here (every output element accumulates in a fixed
/// order), so `generate_batch(m, &[p], n, 1)[0]` is the reference for any
/// `generate_batch` grouping of `p`. It is NOT the engine reference: the
/// engine prefills through the batched kernels (use
/// [`generate_lockstep`]) and rejects oversized prompts instead of
/// truncating them.
pub fn generate_batch(
    model: &TransformerLM,
    prompts: &[Vec<usize>],
    n: usize,
    workers: usize,
) -> Vec<Vec<usize>> {
    let b = prompts.len();
    if b == 0 {
        return Vec::new();
    }
    let budget = model.cfg.seq_len;
    // Phase 1: prefill. Each sequence owns its KV cache, so chunks of the
    // state vector fan out across scoped threads.
    let mut states: Vec<(KvCache, Vec<f32>)> = prompts
        .iter()
        .map(|_| (KvCache::new(&model.cfg), vec![0.0f32; model.cfg.vocab]))
        .collect();
    let chunk = b.div_ceil(workers.max(1)).max(1);
    std::thread::scope(|s| {
        for (chunk_states, chunk_prompts) in states.chunks_mut(chunk).zip(prompts.chunks(chunk)) {
            s.spawn(move || {
                for ((cache, logits), p) in chunk_states.iter_mut().zip(chunk_prompts) {
                    for &t in p.iter().take(budget) {
                        *logits = model.decode_step(t, cache);
                    }
                }
            });
        }
    });
    // Phase 2: lockstep batched generation over the still-active sequences.
    // Empty prompts never activate (matching `generate`: no logits to
    // decode from), so they return empty completions.
    let mut out: Vec<Vec<usize>> = (0..b).map(|_| Vec::with_capacity(n)).collect();
    for _ in 0..n {
        let active: Vec<usize> = (0..b)
            .filter(|&i| !prompts[i].is_empty() && states[i].0.len < budget)
            .collect();
        if active.is_empty() {
            break;
        }
        let tokens: Vec<usize> = active.iter().map(|&i| argmax(&states[i].1)).collect();
        for (&i, &t) in active.iter().zip(&tokens) {
            out[i].push(t);
        }
        let logits = {
            let mut next = 0usize;
            let mut cache_refs: Vec<&mut KvCache> = Vec::with_capacity(active.len());
            for (i, st) in states.iter_mut().enumerate() {
                if next < active.len() && active[next] == i {
                    cache_refs.push(&mut st.0);
                    next += 1;
                }
            }
            model.decode_step_batch(&tokens, &mut cache_refs)
        };
        for (r, &i) in active.iter().enumerate() {
            states[i].1.clear();
            states[i].1.extend_from_slice(logits.row(r));
        }
    }
    out
}

/// Pull requests into the admission queue: block up to `poll` for the
/// first one, then drain everything already queued with `try_recv`, so a
/// burst enters the queue in ONE pump. Returns true once the request
/// channel has disconnected.
fn pump_requests(
    rx: &mpsc::Receiver<Submission>,
    poll: Duration,
    queue: &mut Batcher,
    sinks: &mut HashMap<u64, ResponseSink>,
) -> bool {
    match rx.recv_timeout(poll) {
        Ok((req, sink)) => {
            sinks.insert(req.id, sink);
            queue.push(req);
        }
        Err(mpsc::RecvTimeoutError::Timeout) => return false,
        Err(mpsc::RecvTimeoutError::Disconnected) => return true,
    }
    loop {
        match rx.try_recv() {
            Ok((req, sink)) => {
                sinks.insert(req.id, sink);
                queue.push(req);
            }
            Err(mpsc::TryRecvError::Empty) => return false,
            Err(mpsc::TryRecvError::Disconnected) => return true,
        }
    }
}

/// Route one engine event to its response channel.
fn dispatch(ev: SeqEvent, sinks: &mut HashMap<u64, ResponseSink>) {
    match ev {
        SeqEvent::Token { id, token, first } => {
            if let Some(ResponseSink::Stream(tx)) = sinks.get(&id) {
                let _ = tx.send(StreamEvent::Token { token, first });
            }
        }
        SeqEvent::Finished(f) => {
            let resp = Response {
                id: f.id,
                tokens: f.tokens,
                latency: f.enqueued.elapsed(),
                queue_wait: f.queue_wait,
                first_token_latency: f.first_token_latency,
                status: f.status,
                priority: f.priority,
            };
            match sinks.remove(&resp.id) {
                Some(ResponseSink::Unary(tx)) => {
                    let _ = tx.send(resp);
                }
                Some(ResponseSink::Stream(tx)) => {
                    let _ = tx.send(StreamEvent::Done(resp));
                }
                None => {}
            }
        }
    }
}

/// The server: owns the engine thread (admission queue + continuous-
/// batching decode loop) and the request channel into it.
pub struct Server {
    req_tx: Option<mpsc::Sender<Submission>>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
    telemetry: Arc<Mutex<EngineTelemetry>>,
}

impl Server {
    pub fn start(model: Arc<TransformerLM>, cfg: ServeConfig) -> Server {
        // Kernel-dispatch step: decode batches are at most `slots` wide,
        // so pre-pack each compressed layer for that batch shape once, up
        // front, instead of running scalar CSR per request.
        let model = if cfg.prepack && model.needs_packing() {
            Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
        } else {
            model
        };
        let (req_tx, req_rx) = mpsc::channel::<Submission>();
        let mut engine = Engine::new(model, cfg.engine_config());
        let telemetry = engine.telemetry();

        let handle = std::thread::spawn(move || {
            let mut queue = Batcher::default();
            let mut sinks: HashMap<u64, ResponseSink> = HashMap::new();
            let mut closed = false;
            loop {
                // While sequences are resident, only drain what's already
                // queued (zero-poll) so decode never stalls on arrivals;
                // when idle, block briefly so the loop doesn't spin.
                let poll = if engine.is_idle() {
                    Duration::from_micros(200)
                } else {
                    Duration::ZERO
                };
                if pump_requests(&req_rx, poll, &mut queue, &mut sinks) {
                    closed = true;
                }
                for ev in engine.step(&mut queue) {
                    dispatch(ev, &mut sinks);
                }
                if closed && engine.is_idle() && queue.is_empty() {
                    break;
                }
            }
        });

        Server { req_tx: Some(req_tx), engine_handle: Some(handle), telemetry }
    }

    /// Submit a request; returns the response receiver (one terminal
    /// [`Response`]).
    pub fn submit(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<Response> {
        self.submit_budgeted(id, prompt, None)
    }

    /// [`Server::submit`] with a per-request generation budget
    /// (`None` ⇒ the server-wide `gen_tokens` default). Short budgets also
    /// shrink the request's worst-case KV page reservation, so they admit
    /// alongside bigger requests on a tight paged arena.
    pub fn submit_budgeted(
        &self,
        id: u64,
        prompt: Vec<usize>,
        gen_tokens: Option<usize>,
    ) -> mpsc::Receiver<Response> {
        let mut req = Request::new(id, prompt);
        req.gen_tokens = gen_tokens;
        self.submit_request(req)
    }

    /// Submit a fully-specified [`Request`] — the entry point for the
    /// per-request knobs the shorthand submitters leave at their defaults
    /// ([`Request::with_stop_tokens`], [`Request::without_prefix_sharing`],
    /// [`Request::with_budget`]).
    pub fn submit_request(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.send(req, ResponseSink::Unary(tx));
        rx
    }

    /// Submit a request for per-token streaming: the receiver yields a
    /// [`StreamEvent::Token`] per generated token as the engine emits it,
    /// then [`StreamEvent::Done`] with the full response.
    pub fn submit_streaming(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<StreamEvent> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::new(id, prompt), ResponseSink::Stream(tx));
        rx
    }

    fn send(&self, req: Request, sink: ResponseSink) {
        self.req_tx.as_ref().expect("server stopped").send((req, sink)).expect("engine alive");
    }

    /// Snapshot of the engine's per-step telemetry so far.
    pub fn telemetry(&self) -> EngineTelemetry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Stop accepting requests and wait for in-flight work.
    pub fn shutdown(mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

/// Closed-loop load test: submit `n_requests` prompts, wait for all, and
/// report stats. This is the Table 7 / Table 14 measurement harness and
/// the `serve-load` smoke driver.
pub fn run_load(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    prompts: Vec<Vec<usize>>,
) -> ServeStats {
    run_load_mixed(model, cfg, prompts.into_iter().map(|p| (p, None)).collect())
}

/// One request of a load-driver workload: prompt plus the per-request
/// knobs the drivers expose (`None` budget ⇒ the server-wide default).
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub prompt: Vec<usize>,
    pub gen_tokens: Option<usize>,
    pub priority: Priority,
}

impl LoadSpec {
    pub fn new(prompt: Vec<usize>) -> LoadSpec {
        LoadSpec { prompt, gen_tokens: None, priority: Priority::default() }
    }
}

/// Request-level measurements a load driver accumulates as responses land.
#[derive(Default)]
struct RunAccumulator {
    latencies: Vec<f64>,
    queue_waits: Vec<f64>,
    first_token_latencies: Vec<f64>,
    /// Indexed by `Priority::rank()`.
    ftl_by_tier: [Vec<f64>; 3],
    tokens: usize,
    digest: u64,
}

impl RunAccumulator {
    fn new() -> RunAccumulator {
        RunAccumulator { digest: 0xcbf29ce484222325, ..Default::default() }
    }

    /// FNV-1a over (id, completion) in id order: the drivers absorb
    /// responses indexed by id, so the digest depends only on what each
    /// request got back — identical completions ⇒ identical digest,
    /// whatever the engine's step-by-step interleaving was. Shed responses
    /// are EXCLUDED: shed decisions legitimately differ across A/B runs
    /// (e.g. preemption on vs off), so the digest covers exactly the
    /// completions the bit-identity contract promises.
    fn fold(&mut self, x: u64) {
        self.digest = (self.digest ^ x).wrapping_mul(0x100000001b3);
    }

    fn absorb(&mut self, i: usize, resp: &Response) {
        self.latencies.push(resp.latency.as_secs_f64());
        self.queue_waits.push(resp.queue_wait.as_secs_f64());
        if let Some(ftl) = resp.first_token_latency {
            let s = ftl.as_secs_f64();
            self.first_token_latencies.push(s);
            self.ftl_by_tier[resp.priority.rank() as usize].push(s);
        }
        self.tokens += resp.tokens.len();
        if resp.status != ResponseStatus::Shed {
            self.fold(i as u64);
            self.fold(resp.tokens.len() as u64);
            for &t in &resp.tokens {
                self.fold(t as u64);
            }
        }
    }
}

/// [`run_load`] with per-request generation budgets: each entry is
/// `(prompt, gen_tokens)` where `None` takes the server-wide default —
/// the `oats serve-load --gen-tokens-mix` driver.
pub fn run_load_mixed(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    requests: Vec<(Vec<usize>, Option<usize>)>,
) -> ServeStats {
    let specs = requests
        .into_iter()
        .map(|(prompt, gen_tokens)| LoadSpec { gen_tokens, ..LoadSpec::new(prompt) })
        .collect();
    run_load_specs(model, cfg, specs)
}

/// Closed-loop driver over fully-specified [`LoadSpec`]s (budgets and
/// priorities), through the threaded [`Server`].
pub fn run_load_specs(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    specs: Vec<LoadSpec>,
) -> ServeStats {
    // Pack before starting the clock: packing is one-time startup cost and
    // must not bias the measured throughput of compressed models (the dense
    // baseline pays no equivalent cost).
    let model = if cfg.prepack && model.needs_packing() {
        Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
    } else {
        model
    };
    let share = cfg.share_prefix;
    let t0 = Instant::now();
    let server = Server::start(model, cfg);
    let rxs: Vec<mpsc::Receiver<Response>> = specs
        .into_iter()
        .enumerate()
        .map(|(i, spec)| {
            let mut req = Request::new(i as u64, spec.prompt).with_priority(spec.priority);
            req.gen_tokens = spec.gen_tokens;
            req.share_prefix = share;
            server.submit_request(req)
        })
        .collect();
    let mut acc = RunAccumulator::new();
    let n = rxs.len();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        acc.absorb(i, &resp);
    }
    let wall = t0.elapsed().as_secs_f64();
    let telemetry = server.telemetry();
    server.shutdown();
    ServeStats::from_run(n, wall, &acc, ArrivalPlan::Closed.label(), &telemetry)
}

/// Open-loop load driver: steps the engine synchronously on its logical
/// clock and injects each request at the step its [`ArrivalPlan`] schedule
/// dictates — so a storm run (arrival timing, admission order, preemption
/// and shed decisions included) replays step-for-step from `(plan, seed)`.
/// The closed plan degenerates to a prequeued backlog.
pub fn run_load_open(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    specs: Vec<LoadSpec>,
    plan: &ArrivalPlan,
    seed: u64,
) -> ServeStats {
    let model = if cfg.prepack && model.needs_packing() {
        Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
    } else {
        model
    };
    let share = cfg.share_prefix;
    let n = specs.len();
    let schedule = plan.schedule(n, seed);
    let label = plan.label();
    let t0 = Instant::now();
    let mut engine = Engine::new(model, cfg.engine_config());
    let telemetry = engine.telemetry();
    let mut queue = Batcher::default();
    let mut responses: Vec<Option<Response>> = (0..n).map(|_| None).collect();
    let mut arrivals = specs.into_iter().zip(schedule.iter().copied()).enumerate();
    let mut pending = arrivals.next();
    let mut done = 0usize;
    // Generous liveness fuse: the engine retires every admitted sequence in
    // bounded steps, so a run that outlives this has deadlocked.
    let horizon = schedule.last().copied().unwrap_or(0) + 10_000 * (n as u64 + 1);
    let mut step: u64 = 0;
    while done < n {
        while let Some((i, (spec, at))) = pending.take() {
            if at > step {
                pending = Some((i, (spec, at)));
                break;
            }
            let mut req = Request::new(i as u64, spec.prompt).with_priority(spec.priority);
            req.gen_tokens = spec.gen_tokens;
            req.share_prefix = share;
            queue.push(req);
            pending = arrivals.next();
        }
        for ev in engine.step(&mut queue) {
            if let SeqEvent::Finished(f) = ev {
                let resp = Response {
                    id: f.id,
                    tokens: f.tokens,
                    latency: f.enqueued.elapsed(),
                    queue_wait: f.queue_wait,
                    first_token_latency: f.first_token_latency,
                    status: f.status,
                    priority: f.priority,
                };
                responses[resp.id as usize] = Some(resp);
                done += 1;
            }
        }
        step += 1;
        assert!(step < horizon, "open-loop run failed to drain: {done}/{n} after {step} steps");
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut acc = RunAccumulator::new();
    for (i, resp) in responses.iter().enumerate() {
        acc.absorb(i, resp.as_ref().expect("every request finished"));
    }
    let t = telemetry.lock().unwrap().clone();
    ServeStats::from_run(n, wall, &acc, label, &t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::TransformerLM;

    fn tiny() -> Arc<TransformerLM> {
        Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 5))
    }

    #[test]
    fn pump_drains_queued_burst_in_one_call() {
        // The engine loop must not need one poll cycle per request: a burst
        // already sitting in the channel enters the queue in one pump.
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for i in 0..5u64 {
            let (rtx, _rrx) = mpsc::channel();
            let mut req = Request::new(i, vec![1]);
            req.enqueued = t0;
            tx.send((req, ResponseSink::Unary(rtx))).unwrap();
        }
        let mut b = Batcher::default();
        let mut sinks = HashMap::new();
        let closed = pump_requests(&rx, Duration::from_millis(10), &mut b, &mut sinks);
        assert!(!closed);
        assert_eq!(b.len(), 5, "burst must enter the queue in one pump");
        assert_eq!(sinks.len(), 5);
        // Disconnect is reported once the senders are gone.
        drop(tx);
        assert!(pump_requests(&rx, Duration::from_millis(1), &mut b, &mut sinks));
    }

    #[test]
    fn generate_respects_budget() {
        let m = tiny();
        let out = generate(&m, &[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| t < m.cfg.vocab));
        // Over-long generation stops at seq_len.
        let out2 = generate(&m, &[1, 2, 3], 10_000);
        assert!(out2.len() <= m.cfg.seq_len);
    }

    #[test]
    fn generate_deterministic() {
        let m = tiny();
        assert_eq!(generate(&m, &[4, 5], 8), generate(&m, &[4, 5], 8));
    }

    #[test]
    fn generate_batch_matches_scalar_generate() {
        // Dense model: the batched lockstep path is arithmetically identical
        // to per-sequence scalar decode, ragged prompt lengths included —
        // and an empty prompt yields an empty completion in both paths
        // (decoding from the all-zero logits buffer would emit token 0).
        let m = tiny();
        let prompts = vec![vec![1usize, 2, 3], vec![], vec![4usize, 5], vec![9usize]];
        let batch = generate_batch(&m, &prompts, 6, 2);
        assert_eq!(batch.len(), 4);
        for (p, got) in prompts.iter().zip(&batch) {
            assert_eq!(got, &generate(&m, p, 6), "prompt {p:?}");
        }
        assert!(batch[1].is_empty(), "empty prompt must yield empty completion");
        assert!(generate(&m, &[], 5).is_empty());
        assert!(generate_batch(&m, &[], 4, 2).is_empty());
    }

    #[test]
    fn generate_batch_respects_budget() {
        let m = tiny();
        let long: Vec<usize> = (0..m.cfg.seq_len - 2).map(|i| i % 16).collect();
        let outs = generate_batch(&m, &[long.clone(), vec![1, 2]], 10_000, 2);
        assert_eq!(outs[0].len(), 2, "near-full cache generates to the cap");
        assert!(outs[1].len() <= m.cfg.seq_len);
    }

    #[test]
    fn server_round_trip() {
        let m = tiny();
        let cfg = ServeConfig { slots: 4, gen_tokens: 4, ..Default::default() };
        let stats = run_load(m, cfg, (0..10).map(|i| vec![i % 16, 1, 2]).collect());
        assert_eq!(stats.n_requests, 10);
        assert_eq!(stats.tokens_generated, 40);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.latency.max >= stats.latency.min);
        assert_eq!(stats.joins, 10);
        assert_eq!(stats.leaves, 10);
        assert_eq!(stats.truncated, 0);
        assert_eq!(stats.capacity_stopped, 0);
        assert!(stats.steps > 0);
        assert!(stats.slot_occupancy.mean > 0.0);
        assert!(stats.kv_bytes > 0);
        assert_eq!(stats.first_token_latency.n, 10);
        // Default config is the whole-cache degenerate arena.
        assert_eq!(stats.kv_pages, stats.slots);
        assert!(stats.page_occupancy.mean > 0.0);
        assert_eq!(stats.pages_in_use_at_drain, 0, "pages leaked");
    }

    #[test]
    fn paged_server_matches_scalar_outputs_and_conserves_pages() {
        let m = tiny();
        let cfg = ServeConfig {
            slots: 6,
            gen_tokens: 5,
            page_size: 8,
            kv_pages: 18,
            ..Default::default()
        };
        let prompts: Vec<Vec<usize>> =
            (0..12).map(|i| (0..(1 + i % 5)).map(|j| (i * 7 + j) % 16).collect()).collect();
        let server = Server::start(Arc::clone(&m), cfg);
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens, generate(&m, p, 5), "prompt {p:?}");
            assert_eq!(resp.status, ResponseStatus::Complete);
        }
        let t = server.telemetry();
        assert_eq!(t.page_size, 8);
        assert_eq!(t.total_pages, 18);
        assert_eq!(t.pages_in_use_now, 0, "pages leaked after drain");
        assert!(t.pages_in_use.iter().all(|&p| p <= 18.0));
        drop(server);
    }

    #[test]
    fn budgeted_submissions_cap_generation_per_request() {
        let m = tiny();
        let cfg = ServeConfig { slots: 4, gen_tokens: 8, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let default_rx = server.submit(0, vec![1, 2, 3]);
        let short_rx = server.submit_budgeted(1, vec![1, 2, 3], Some(2));
        let zero_rx = server.submit_budgeted(2, vec![4, 5], Some(0));
        let default = default_rx.recv().unwrap();
        assert_eq!(default.tokens, generate(&m, &[1, 2, 3], 8));
        let short = short_rx.recv().unwrap();
        assert_eq!(short.tokens, generate(&m, &[1, 2, 3], 2));
        assert_eq!(short.status, ResponseStatus::Complete);
        let zero = zero_rx.recv().unwrap();
        assert!(zero.tokens.is_empty(), "zero budget must complete empty");
        assert_eq!(zero.status, ResponseStatus::Complete);
        drop(server);
    }

    #[test]
    fn run_load_mixed_applies_budgets() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 6, ..Default::default() };
        let reqs =
            vec![(vec![1usize, 2], None), (vec![3usize, 4], Some(3)), (vec![5usize], Some(1))];
        let stats = run_load_mixed(m, cfg, reqs);
        assert_eq!(stats.n_requests, 3);
        assert_eq!(stats.tokens_generated, 6 + 3 + 1);
        assert_eq!(stats.joins, 3);
        assert_eq!(stats.leaves, 3);
    }

    #[test]
    fn rejection_only_load_still_reports_steps_and_summaries() {
        // Regression: a run that produces nothing but slot-free rejections
        // used to emit SERVE json with steps == 0 and empty summaries,
        // which the CI smoke gates would read as a dead engine.
        let m = tiny();
        let cap = m.cfg.seq_len;
        let cfg = ServeConfig { slots: 2, gen_tokens: 4, ..Default::default() };
        let stats = run_load(m, cfg, vec![vec![1; cap + 1], vec![2; cap + 7]]);
        assert_eq!(stats.n_requests, 2);
        assert_eq!(stats.tokens_generated, 0);
        assert_eq!(stats.truncated, 2);
        assert!(stats.steps > 0, "rejections are worked steps");
        assert!(stats.queue_depth.n > 0, "telemetry sampled");
        assert_eq!(stats.latency.n, 2, "rejected requests still report latency");
    }

    #[test]
    fn server_matches_scalar_generate_per_request() {
        // Continuous batching must not change any request's tokens.
        let m = tiny();
        let prompts: Vec<Vec<usize>> =
            (0..9).map(|i| (0..(1 + i % 4)).map(|j| (i * 5 + j) % 16).collect()).collect();
        let cfg = ServeConfig { slots: 3, gen_tokens: 5, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens, generate(&m, p, 5), "prompt {p:?}");
            assert_eq!(resp.status, ResponseStatus::Complete);
        }
    }

    #[test]
    fn streaming_submission_yields_tokens_then_done() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 6, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rx = server.submit_streaming(7, vec![1, 2, 3]);
        let mut streamed = Vec::new();
        let mut done: Option<Response> = None;
        for ev in rx {
            match ev {
                StreamEvent::Token { token, first } => {
                    assert_eq!(first, streamed.is_empty(), "first flag on first token only");
                    streamed.push(token);
                }
                StreamEvent::Done(resp) => {
                    done = Some(resp);
                    break;
                }
            }
        }
        let resp = done.expect("terminal Done event");
        assert_eq!(resp.tokens, streamed, "stream must equal the final response");
        assert_eq!(resp.tokens, generate(&m, &[1, 2, 3], 6));
        let ftl = resp.first_token_latency.expect("first token seen");
        assert!(ftl <= resp.latency, "first token cannot be later than completion");
    }

    #[test]
    fn generate_lockstep_matches_generate_on_dense() {
        // Dense layers run identical arithmetic through decode_step and
        // decode_step_batch, so the two references coincide exactly.
        let m = tiny();
        for p in [vec![1usize, 2, 3], vec![], vec![9usize]] {
            assert_eq!(generate_lockstep(&m, &p, 7), generate(&m, &p, 7), "prompt {p:?}");
        }
    }

    #[test]
    fn prepacked_server_matches_unpacked_outputs() {
        // Compress a model, then serve it with and without kernel pre-packing:
        // generated tokens must be identical to batch-of-1 lockstep decode
        // through the same kernels (`generate_lockstep` — the engine prefills
        // through the batched kernels, so scalar-prefill references could
        // differ in the last ulps on packed layers). Packed vs unpacked
        // numerics only agree to ~1e-4, so cross-mode token equality would be
        // tie-dependent; per-sequence results are independent of how the
        // engine batches, so continuous batching's groupings don't matter.
        let base = TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 21);
        let corpus = crate::data::SyntheticCorpus::new(crate::data::CorpusConfig::for_vocab(
            base.cfg.vocab,
            2,
        ));
        let calib = crate::calib::CalibSet::sample(&corpus, 4, 16, 4);
        let ccfg = crate::config::CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 2,
            ..Default::default()
        };
        let (cm, _) =
            crate::coordinator::pipeline::compress_clone(&base, &calib, &ccfg, 2).unwrap();
        assert!(cm.needs_packing());
        let prompts: Vec<Vec<usize>> = (0..6).map(|i| vec![i % 16, 3, 5]).collect();
        let run = |prepack: bool| -> Vec<Vec<usize>> {
            let cfg = ServeConfig { slots: 4, gen_tokens: 6, prepack, ..Default::default() };
            let server = Server::start(Arc::new(cm.clone()), cfg);
            let rxs: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| server.submit(i as u64, p.clone()))
                .collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect()
        };
        let packed = cm.packed_for_serving(4);
        let want_packed: Vec<Vec<usize>> =
            prompts.iter().map(|p| generate_lockstep(&packed, p, 6)).collect();
        assert_eq!(run(true), want_packed);
        let want_unpacked: Vec<Vec<usize>> =
            prompts.iter().map(|p| generate_lockstep(&cm, p, 6)).collect();
        assert_eq!(run(false), want_unpacked);
    }

    #[test]
    fn oversized_prompt_surfaces_truncated_status() {
        let m = tiny();
        let cap = m.cfg.seq_len;
        let cfg = ServeConfig { slots: 2, gen_tokens: 4, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let ok_rx = server.submit(0, vec![1, 2, 3]);
        let over_rx = server.submit(1, vec![1; cap + 5]);
        let over = over_rx.recv().unwrap();
        assert_eq!(over.status, ResponseStatus::Truncated);
        assert!(over.tokens.is_empty());
        assert!(over.first_token_latency.is_none());
        let ok = ok_rx.recv().unwrap();
        assert_eq!(ok.status, ResponseStatus::Complete);
        assert_eq!(ok.tokens.len(), 4);
        drop(server);
    }

    #[test]
    fn decode_batches_never_exceed_slots() {
        let m = tiny();
        let cfg = ServeConfig { slots: 3, gen_tokens: 2, ..Default::default() };
        let server = Server::start(m, cfg);
        let rxs: Vec<_> = (0..7).map(|i| server.submit(i, vec![1, 2])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let t = server.telemetry();
        assert!(t.decode_batch.iter().all(|&b| b <= 3.0), "{:?}", t.decode_batch);
        assert_eq!(t.joins, 7);
        assert_eq!(t.leaves, 7);
        drop(server);
    }

    #[test]
    fn prequeued_burst_fills_the_arena() {
        // A queued burst of exactly `slots` requests must be admitted
        // together and decode at full width. Driven synchronously at the
        // engine level: through the threaded server the engine admits
        // whatever has *arrived*, so full-width there would race the
        // submitting thread.
        let m = tiny();
        let cfg = EngineConfig { slots: 6, gen_tokens: 2, ..Default::default() };
        let mut engine = Engine::new(m, cfg);
        let mut queue = Batcher::default();
        for i in 0..6u64 {
            queue.push(Request::new(i, vec![1, 2]));
        }
        let mut finished = 0;
        for _ in 0..100 {
            for ev in engine.step(&mut queue) {
                if matches!(ev, SeqEvent::Finished(_)) {
                    finished += 1;
                }
            }
            if finished == 6 {
                break;
            }
        }
        assert_eq!(finished, 6);
        let t = engine.telemetry().lock().unwrap().clone();
        let peak = t.occupancy.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(peak, 1.0, "burst must fill all slots: {:?}", t.occupancy);
        let widest = t.decode_batch.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(widest, 6.0, "full-width decode batch expected: {:?}", t.decode_batch);
    }

    #[test]
    fn shared_prefix_load_matches_unshared_digest_and_saves_prefill() {
        // The CI shared-prefix gate in miniature: the same workload run
        // with sharing on and off must produce byte-identical completions
        // (equal digests) at equal KV bytes, with the shared run actually
        // skipping prefill work and leaking nothing.
        let m = tiny();
        let head: Vec<usize> = (1..=12).collect();
        let prompts: Vec<Vec<usize>> = (0..8)
            .map(|i| head.iter().copied().chain([(i * 3) % 16, (i * 5 + 1) % 16]).collect())
            .collect();
        let cfg = |share: bool| ServeConfig {
            slots: 4,
            gen_tokens: 4,
            page_size: 4,
            kv_pages: 24,
            share_prefix: share,
            ..Default::default()
        };
        let shared = run_load(Arc::clone(&m), cfg(true), prompts.clone());
        let unshared = run_load(Arc::clone(&m), cfg(false), prompts.clone());
        assert_eq!(
            shared.completions_digest, unshared.completions_digest,
            "prefix sharing changed some completion"
        );
        assert_ne!(shared.completions_digest, 0);
        assert_eq!(shared.kv_bytes, unshared.kv_bytes, "A/B must compare equal arenas");
        assert!(shared.prefill_tokens_saved > 0, "no prefill was reused");
        assert!(shared.shared_pages > 0);
        assert_eq!(unshared.prefill_tokens_saved, 0);
        assert_eq!(unshared.shared_pages, 0);
        assert_eq!(shared.pages_in_use_at_drain, 0, "shared run leaked pages");
        assert_eq!(unshared.pages_in_use_at_drain, 0);
        // Per-request tokens also equal the scalar reference.
        let server = Server::start(Arc::clone(&m), cfg(true));
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            assert_eq!(rx.recv().unwrap().tokens, generate(&m, p, 4), "prompt {p:?}");
        }
        drop(server);
    }

    #[test]
    fn arrival_plans_parse_and_schedule_deterministically() {
        assert_eq!(ArrivalPlan::parse("closed").unwrap(), ArrivalPlan::Closed);
        assert_eq!(ArrivalPlan::parse("ramp").unwrap(), ArrivalPlan::Ramp);
        assert_eq!(ArrivalPlan::parse("burst:8:4").unwrap(), ArrivalPlan::Burst { n: 8, gap: 4 });
        assert_eq!(ArrivalPlan::parse("poisson:0.5").unwrap(), ArrivalPlan::Poisson { rate: 0.5 });
        assert!(ArrivalPlan::parse("avalanche").is_err());
        assert!(ArrivalPlan::parse("poisson:-1").is_err());
        assert!(ArrivalPlan::parse("burst:0:4").is_err());
        for s in ["closed", "poisson:0.5", "burst:8:4", "ramp"] {
            assert_eq!(ArrivalPlan::parse(s).unwrap().label(), s, "label round trip");
        }
        for plan in [
            ArrivalPlan::Closed,
            ArrivalPlan::Poisson { rate: 0.5 },
            ArrivalPlan::Burst { n: 3, gap: 5 },
            ArrivalPlan::Ramp,
        ] {
            let s = plan.schedule(16, 7);
            assert_eq!(s.len(), 16);
            assert!(s.windows(2).all(|w| w[0] <= w[1]), "{plan:?} schedule not sorted");
            assert_eq!(s, plan.schedule(16, 7), "{plan:?} schedule not deterministic");
        }
        let a = ArrivalPlan::Poisson { rate: 0.5 }.schedule(16, 7);
        let b = ArrivalPlan::Poisson { rate: 0.5 }.schedule(16, 8);
        assert_ne!(a, b, "poisson must move with the seed");
        // Burst shape: groups of n arrive together, gap steps apart.
        let s = ArrivalPlan::Burst { n: 2, gap: 3 }.schedule(5, 0);
        assert_eq!(s, vec![0, 0, 3, 3, 6]);
    }

    #[test]
    fn open_loop_burst_matches_closed_loop_digest() {
        // Same workload, closed loop (threaded server) vs open-loop burst
        // arrivals (synchronous driver): arrival timing must not change
        // any completion — the engine is bit-identical per request — only
        // the latency/telemetry profile.
        let m = tiny();
        let specs: Vec<LoadSpec> = (0..10)
            .map(|i| LoadSpec::new((0..(1 + i % 4)).map(|j| (i * 5 + j) % 16).collect()))
            .collect();
        let cfg = || ServeConfig { slots: 3, gen_tokens: 4, ..Default::default() };
        let closed = run_load_specs(Arc::clone(&m), cfg(), specs.clone());
        let open = run_load_open(m, cfg(), specs, &ArrivalPlan::Burst { n: 4, gap: 6 }, 0);
        assert_eq!(open.n_requests, 10);
        assert_eq!(open.arrivals, "burst:4:6");
        assert_eq!(closed.arrivals, "closed");
        assert_eq!(open.completions_digest, closed.completions_digest);
        assert_eq!(open.tokens_generated, closed.tokens_generated);
        assert_eq!(open.pages_in_use_at_drain, 0);
        assert!(open.steps > 0);
        assert_eq!(open.goodput_under_slo, 1.0, "no SLO set: every first token is goodput");
    }

    #[test]
    fn preemption_storm_is_digest_equal_to_preemption_off() {
        // The CI storm A/B in miniature: a burst of background work holds
        // the slots when interactive requests arrive; with preemption on
        // the engine evicts victims for them, and every completion must
        // still be bit-identical to the preemption-off run (shed off in
        // both arms, so nothing is dropped).
        let m = tiny();
        let specs: Vec<LoadSpec> = (0..12)
            .map(|i| LoadSpec {
                prompt: (0..(2 + i % 5)).map(|j| (i * 7 + j) % 16).collect(),
                gen_tokens: None,
                priority: if i < 8 { Priority::Background } else { Priority::Interactive },
            })
            .collect();
        let cfg = |preemption: bool| ServeConfig {
            slots: 2,
            gen_tokens: 6,
            preemption,
            ..Default::default()
        };
        let plan = ArrivalPlan::Burst { n: 4, gap: 2 };
        let on = run_load_open(Arc::clone(&m), cfg(true), specs.clone(), &plan, 3);
        let off = run_load_open(m, cfg(false), specs, &plan, 3);
        assert_eq!(on.completions_digest, off.completions_digest, "preemption changed a token");
        assert_eq!(on.kv_bytes, off.kv_bytes, "A/B must compare equal arenas");
        assert!(on.preemptions > 0, "interactive burst over resident background never preempted");
        assert!(on.victim_recompute_tokens > 0, "victims re-prefill their progress");
        assert_eq!(off.preemptions, 0);
        assert_eq!(on.shed + off.shed, 0, "shed stays off in the A/B");
        assert_eq!(on.joins, on.leaves, "every eviction pairs with a readmission");
        assert_eq!(on.pages_in_use_at_drain, 0, "preemption leaked pages");
        assert_eq!(off.pages_in_use_at_drain, 0);
        // The tiers the storm separates: interactive first tokens exist,
        // and the per-tier buckets partition the overall count.
        let n_tiers = on.ftl_interactive.n + on.ftl_batch.n + on.ftl_background.n;
        assert_eq!(n_tiers, on.first_token_latency.n);
        assert!(on.ftl_interactive.n > 0);
        assert_eq!(on.ftl_batch.n, 0, "no batch-tier requests in this storm");
    }

    #[test]
    fn shed_storm_drops_lowest_tier_and_reports_goodput() {
        // One slot, a long backlog, and a tight first-token SLO: the
        // shedder must drop background work (never the interactive
        // request), account for every request, and drain cleanly.
        let m = tiny();
        let mut specs: Vec<LoadSpec> = (0..10)
            .map(|i| LoadSpec {
                prompt: vec![(i % 16), 2],
                gen_tokens: None,
                priority: Priority::Background,
            })
            .collect();
        specs[1].priority = Priority::Interactive;
        let cfg = ServeConfig {
            slots: 1,
            gen_tokens: 6,
            slo_first_token_steps: 30,
            shed_policy: ShedPolicy::LowestPriority,
            ..Default::default()
        };
        let stats = run_load_open(m, cfg, specs, &ArrivalPlan::Closed, 0);
        assert_eq!(stats.n_requests, 10);
        assert!(stats.shed > 0, "backlog past the SLO must shed");
        assert!(stats.shed < 10, "shedding must stop once the backlog fits the SLO");
        // Every request left exactly once: sheds + retirements cover all.
        assert_eq!(stats.shed + stats.leaves, 10);
        assert_eq!(stats.joins, stats.leaves);
        assert!(stats.goodput_under_slo > 0.0, "admitted work kept its SLO");
        assert!(stats.ftl_interactive.n > 0, "the interactive request was served, not shed");
        assert_eq!(stats.pages_in_use_at_drain, 0);
    }

    #[test]
    fn stop_tokens_surface_stopped_status_through_the_server() {
        let m = tiny();
        let prompt = vec![1, 2, 3];
        let free = generate(&m, &prompt, 8);
        let stop = free[1];
        let cut = free.iter().position(|&t| t == stop).unwrap();
        let cfg = ServeConfig { slots: 2, gen_tokens: 8, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rx = server.submit_request(Request::new(0, prompt).with_stop_tokens(vec![stop]));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.status, ResponseStatus::StoppedAtToken);
        assert_eq!(resp.tokens, &free[..=cut]);
        assert_eq!(*resp.tokens.last().unwrap(), stop);
        drop(server);
    }

    #[test]
    fn serve_stats_json_round_trips() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 3, ..Default::default() };
        let stats = run_load(m, cfg, vec![vec![1, 2], vec![3], vec![4, 5, 6]]);
        let j = stats.to_json("unittest");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("oats-serve-v1"));
        assert!(j.req_f64("tokens_per_second").unwrap() > 0.0);
        assert_eq!(j.req_f64("joins").unwrap(), 3.0);
        let lat = j.get("latency_s").expect("latency summary");
        assert!(lat.req_f64("p95").unwrap() >= lat.req_f64("p50").unwrap());
        assert!(lat.req_f64("p99").unwrap() >= lat.req_f64("p95").unwrap());
        // Paged-arena telemetry rides along (the CI gates read these).
        assert_eq!(j.req_f64("capacity_stopped").unwrap(), 0.0);
        assert_eq!(j.req_f64("pages_in_use_at_drain").unwrap(), 0.0);
        // Queue wait is its own summary, distinct from first-token latency.
        let qw = j.get("queue_wait").expect("queue wait summary");
        assert_eq!(qw.req_f64("n").unwrap(), 3.0, "every request reports a queue wait");
        assert!(qw.req_f64("mean").unwrap() >= 0.0);
        // The per-phase breakdown sums to at most the step wall-clock.
        let phase_sum = j.req_f64("time_admit_s").unwrap()
            + j.req_f64("time_prefill_s").unwrap()
            + j.req_f64("time_decode_s").unwrap()
            + j.req_f64("time_retire_s").unwrap();
        assert!(phase_sum > 0.0, "phase clocks must run without tracing");
        assert!(phase_sum <= j.req_f64("time_step_s").unwrap());
        // Untraced runs carry an empty kernel_time object.
        assert!(j.get("kernel_time").is_some());
        // Workspace telemetry: the decode loop allocated something during
        // warmup, and far fewer buffers than decode calls (reuse works).
        assert!(j.req_f64("ws_buffer_allocs").unwrap() > 0.0);
        // Shared-prefix telemetry rides along (the CI gates read these);
        // the digest is a 16-hex-digit string, not a lossy f64.
        assert!(j.req_f64("prefill_tokens_saved").is_ok());
        assert!(j.req_f64("shared_pages").is_ok());
        assert!(j.req_f64("cow_forks").is_ok());
        assert!(j.req_f64("prefix_evictions_cap").is_ok());
        let digest = j.get("completions_digest").and_then(Json::as_str).unwrap();
        assert_eq!(digest.len(), 16);
        assert!(u64::from_str_radix(digest, 16).is_ok());
        assert!(j.req_f64("page_size").unwrap() > 0.0);
        assert!(j.req_f64("kv_pages").unwrap() > 0.0);
        let occ = j.get("page_occupancy").expect("page occupancy summary");
        let occ_mean = occ.req_f64("mean").unwrap();
        assert!(occ_mean > 0.0 && occ_mean <= 1.0, "page occupancy {occ_mean}");
        // Overload telemetry rides along (the CI overload gate reads these);
        // an unpressured closed-loop run reports the quiet baseline.
        assert_eq!(j.req_f64("preemptions").unwrap(), 0.0);
        assert_eq!(j.req_f64("shed").unwrap(), 0.0);
        assert_eq!(j.req_f64("victim_recompute_tokens").unwrap(), 0.0);
        assert_eq!(j.req_f64("goodput_under_slo").unwrap(), 1.0, "no SLO: all first tokens count");
        assert_eq!(j.get("arrivals").and_then(Json::as_str), Some("closed"));
        for tier in ["interactive", "batch", "background"] {
            let key = format!("first_token_latency_{tier}");
            let s = j.get(&key).unwrap_or_else(|| panic!("missing {key}"));
            assert!(s.req_f64("n").is_ok(), "{key} is a summary object");
        }
        // Round-trips through the parser (what the CI smoke gate does).
        let parsed = crate::json::parse(&j.to_pretty()).unwrap();
        assert!(parsed.get("slot_occupancy").is_some());
        assert!(parsed.get("pages_in_use").is_some());
    }
}
