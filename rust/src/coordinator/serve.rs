//! The compressed-inference serving layer (the DeepSparse stand-in for
//! Table 7 / Table 14).
//!
//! Architecture: a request channel feeds the admission queue ([`Batcher`]);
//! the **continuous-batching engine** ([`crate::coordinator::engine`])
//! owns a fixed **paged** KV arena and, every step, admits queued requests
//! into free slots (gated on each joiner's worst-case page reservation),
//! runs chunked prefill for joiners, decodes all resident sequences in
//! lockstep through the batched planned kernels, and retires finished
//! sequences — returning their pages to the free list and backfilling
//! their slots from the queue in the same step. With `page_size <
//! seq_len`, short sequences hold only the pages their length needs, so
//! mixed-length traffic fits more concurrent sequences into the same KV
//! bytes. Requests join and leave mid-flight; nothing waits for a batch to
//! drain. Per-token streaming, per-request latency (completion and first
//! token), and per-step engine telemetry are reported via [`ServeStats`].

use crate::coordinator::engine::{Engine, EngineConfig, EngineTelemetry, SeqEvent};
use crate::json::{self, Json};
use crate::model::{KvCache, TransformerLM};
use crate::sparse::PackOptions;
use crate::tensor::argmax;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::coordinator::engine::{AdmissionPolicy, Batcher, Request, ResponseStatus};

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// KV-slot arena size: the bound on resident sequences, decode batch
    /// width, and KV memory (`slots` preallocated caches).
    pub slots: usize,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// Max prompt tokens a joining sequence prefills per engine step
    /// (higher = faster first token for joiners, chunkier interleaving
    /// with resident decodes).
    pub prefill_chunk: usize,
    /// Order in which queued requests claim freed slots.
    pub admission: AdmissionPolicy,
    /// Pre-pack compressed layers into their planned kernel formats
    /// (BCSR/N:M/CSR per `sparse::KernelPlan`) at server startup.
    pub prepack: bool,
    /// Opt-in i8 tile quantization while pre-packing: BCSR-planned layers
    /// upgrade to QBcsr when their per-tile quantization error passes the
    /// plan gate (`sparse::QBCSR_MAX_REL_ERROR`); checkpoints on disk stay
    /// f32.
    pub quantize: bool,
    /// KV positions per page. `0` ⇒ whole-sequence pages (`seq_len`): the
    /// contiguous pre-paging layout. Smaller pages let short sequences
    /// hold only the KV bytes they use, so more of them fit per byte.
    pub page_size: usize,
    /// Total KV pages in the arena. `0` ⇒ `slots` full sequences' worth
    /// (byte-equivalent to the whole-cache arena).
    pub kv_pages: usize,
    /// Let requests reuse shared prefix KV pages (the engine's prefix
    /// index). `false` stamps every submitted request with the per-request
    /// opt-out — the A/B switch the CI byte-identity gate flips.
    pub share_prefix: bool,
    /// Max entries the prefix index keeps resident (`0` ⇒ unbounded).
    /// Overflow LRU-evicts unreferenced entries deterministically and
    /// reports them as `prefix_evictions_cap`.
    pub prefix_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 8,
            gen_tokens: 16,
            prefill_chunk: 8,
            admission: AdmissionPolicy::Fcfs,
            prepack: true,
            quantize: false,
            page_size: 0,
            kv_pages: 0,
            share_prefix: true,
            prefix_cap: 0,
        }
    }
}

impl ServeConfig {
    /// The packing policy this serving configuration implies: decode
    /// batches are at most `slots` wide, so layers pack for that shape.
    pub fn pack_options(&self) -> PackOptions {
        PackOptions { batch_hint: self.slots, quantize: self.quantize, ..Default::default() }
    }

    /// The engine knobs this configuration implies.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            slots: self.slots.max(1),
            prefill_chunk: self.prefill_chunk.max(1),
            gen_tokens: self.gen_tokens,
            admission: self.admission,
            page_size: self.page_size,
            kv_pages: self.kv_pages,
            prefix_cap: self.prefix_cap,
        }
    }
}

/// A completed generation.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Enqueue → completion.
    pub latency: Duration,
    /// Enqueue → admission into a KV slot (for slot-free answers: to the
    /// answering step) — the queueing share of `first_token_latency`.
    pub queue_wait: Duration,
    /// Enqueue → first generated token (`None` if nothing was generated).
    pub first_token_latency: Option<Duration>,
    /// [`ResponseStatus::Truncated`] marks a prompt that exceeded the
    /// model's `seq_len` and was rejected rather than silently cut;
    /// [`ResponseStatus::CapacityStopped`] marks generation cut short by
    /// KV capacity (fewer tokens than the budget, by memory not choice);
    /// [`ResponseStatus::StoppedAtToken`] marks generation ended by one of
    /// the request's stop tokens (which is the last token returned).
    pub status: ResponseStatus,
}

/// One event on a streaming response channel.
#[derive(Debug)]
pub enum StreamEvent {
    /// A generated token, sent as soon as the engine emits it.
    Token { token: usize, first: bool },
    /// Terminal event: the full response (tokens repeated in order).
    Done(Response),
}

/// How a submission wants its results delivered.
enum ResponseSink {
    Unary(mpsc::Sender<Response>),
    Stream(mpsc::Sender<StreamEvent>),
}

/// One queued submission: the request plus its response channel.
type Submission = (Request, ResponseSink);

/// Aggregate serving statistics: request-level latencies plus the engine's
/// per-step telemetry.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub n_requests: usize,
    pub tokens_generated: usize,
    pub wall_seconds: f64,
    /// Enqueue → completion, per request (seconds).
    pub latency: Summary,
    /// Enqueue → admission, per request (seconds) — how long requests sat
    /// in the queue before the engine took them, reported separately from
    /// `first_token_latency` (which it is a component of).
    pub queue_wait: Summary,
    /// Enqueue → first generated token, over requests that generated.
    pub first_token_latency: Summary,
    /// Decode-batch width per engine step.
    pub batch_sizes: Summary,
    /// Occupied-slot fraction per engine step (1.0 = arena full).
    pub slot_occupancy: Summary,
    /// Admission-queue depth per engine step.
    pub queue_depth: Summary,
    /// Held-page fraction per engine step (1.0 = every KV page attached).
    pub page_occupancy: Summary,
    /// Pages attached to resident sequences, per engine step.
    pub pages_in_use: Summary,
    /// Sequences admitted into / retired from KV slots.
    pub joins: usize,
    pub leaves: usize,
    /// Requests rejected for oversized prompts.
    pub truncated: usize,
    /// Requests stopped by KV capacity before their generation budget.
    pub capacity_stopped: usize,
    /// Engine steps that did work.
    pub steps: usize,
    /// Configured KV-slot arena size.
    pub slots: usize,
    /// KV positions per page / total pages in the arena.
    pub page_size: usize,
    pub kv_pages: usize,
    /// Pages still attached when the run drained (0 = nothing leaked).
    pub pages_in_use_at_drain: usize,
    /// Constant KV-arena footprint in bytes.
    pub kv_bytes: usize,
    /// Fresh heap buffers the decode workspace ever allocated — flat once
    /// decode reaches steady state (the xt/out-reuse regression check).
    pub ws_buffer_allocs: usize,
    /// Prompt tokens admission skipped because their KV already existed as
    /// shared prefix pages.
    pub prefill_tokens_saved: usize,
    /// Shared prefix page mappings attached to joiners at admission.
    pub shared_pages: usize,
    /// Copy-on-write forks of shared pages.
    pub cow_forks: usize,
    /// Prefix-index entries LRU-evicted by the capacity cap.
    pub prefix_evictions_cap: usize,
    /// Engine wall-clock by phase, lifetime totals in seconds (admission
    /// incl. same-step backfill / chunked prefill / lockstep decode /
    /// retirement / whole step). Always measured; the four phase totals
    /// sum to at most `time_step_s`.
    pub time_admit_s: f64,
    pub time_prefill_s: f64,
    pub time_decode_s: f64,
    pub time_retire_s: f64,
    pub time_step_s: f64,
    /// Per-kernel-format forward time in seconds, aggregated from
    /// `kernel_*` trace spans (e.g. `("bcsr", 1.2)`). Empty unless the run
    /// was traced — kernel spans only exist when tracing is enabled.
    pub kernel_time: Vec<(String, f64)>,
    /// Order-independent FNV-1a digest over every `(id, tokens)` pair,
    /// accumulated in request-id order. Two runs of the same workload with
    /// byte-identical completions produce the same digest — the handle the
    /// CI shared-vs-unshared identity gate compares. Zero when the harness
    /// didn't compute one (e.g. stats taken from a live server snapshot).
    pub completions_digest: u64,
}

impl ServeStats {
    /// End-to-end generated-token throughput.
    pub fn tokens_per_second(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_seconds.max(1e-12)
    }

    fn from_run(
        n_requests: usize,
        tokens_generated: usize,
        wall_seconds: f64,
        latencies: &[f64],
        queue_waits: &[f64],
        first_token_latencies: &[f64],
        t: &EngineTelemetry,
    ) -> ServeStats {
        ServeStats {
            n_requests,
            tokens_generated,
            wall_seconds,
            latency: Summary::of(latencies),
            queue_wait: Summary::of(queue_waits),
            first_token_latency: Summary::of(first_token_latencies),
            batch_sizes: Summary::of(&t.decode_batch),
            slot_occupancy: Summary::of(&t.occupancy),
            queue_depth: Summary::of(&t.queue_depth),
            page_occupancy: Summary::of(&t.page_occupancy),
            pages_in_use: Summary::of(&t.pages_in_use),
            joins: t.joins,
            leaves: t.leaves,
            truncated: t.truncated,
            capacity_stopped: t.capacity_stopped,
            steps: t.steps,
            slots: t.slots,
            page_size: t.page_size,
            kv_pages: t.total_pages,
            pages_in_use_at_drain: t.pages_in_use_now,
            kv_bytes: t.kv_bytes,
            ws_buffer_allocs: t.ws_buffer_allocs,
            prefill_tokens_saved: t.prefill_tokens_saved,
            shared_pages: t.shared_pages,
            cow_forks: t.cow_forks,
            prefix_evictions_cap: t.prefix_evictions_cap,
            time_admit_s: t.time_admit_s,
            time_prefill_s: t.time_prefill_s,
            time_decode_s: t.time_decode_s,
            time_retire_s: t.time_retire_s,
            time_step_s: t.time_step_s,
            kernel_time: Vec::new(),
            completions_digest: 0,
        }
    }

    /// Machine-readable record (`oats-serve-v1`) — the serve analogue of
    /// the bench harness's `oats-bench-v1` document.
    pub fn to_json(&self, suite: &str) -> Json {
        let mut o = Json::obj();
        o.set("suite", json::s(suite))
            .set("schema", json::s("oats-serve-v1"))
            .set("requests", json::num(self.n_requests as f64))
            .set("tokens_generated", json::num(self.tokens_generated as f64))
            .set("wall_seconds", json::num(self.wall_seconds))
            .set("tokens_per_second", json::num(self.tokens_per_second()))
            .set("joins", json::num(self.joins as f64))
            .set("leaves", json::num(self.leaves as f64))
            .set("truncated", json::num(self.truncated as f64))
            .set("capacity_stopped", json::num(self.capacity_stopped as f64))
            .set("steps", json::num(self.steps as f64))
            .set("slots", json::num(self.slots as f64))
            .set("page_size", json::num(self.page_size as f64))
            .set("kv_pages", json::num(self.kv_pages as f64))
            .set("pages_in_use_at_drain", json::num(self.pages_in_use_at_drain as f64))
            .set("kv_arena_bytes", json::num(self.kv_bytes as f64))
            .set("ws_buffer_allocs", json::num(self.ws_buffer_allocs as f64))
            .set("prefill_tokens_saved", json::num(self.prefill_tokens_saved as f64))
            .set("shared_pages", json::num(self.shared_pages as f64))
            .set("cow_forks", json::num(self.cow_forks as f64))
            .set("prefix_evictions_cap", json::num(self.prefix_evictions_cap as f64))
            .set("time_admit_s", json::num(self.time_admit_s))
            .set("time_prefill_s", json::num(self.time_prefill_s))
            .set("time_decode_s", json::num(self.time_decode_s))
            .set("time_retire_s", json::num(self.time_retire_s))
            .set("time_step_s", json::num(self.time_step_s))
            // u64 doesn't fit an f64 losslessly: the digest travels as hex.
            .set("completions_digest", json::s(&format!("{:016x}", self.completions_digest)))
            .set("latency_s", self.latency.to_json())
            .set("queue_wait", self.queue_wait.to_json())
            .set("first_token_latency_s", self.first_token_latency.to_json())
            .set("decode_batch", self.batch_sizes.to_json())
            .set("slot_occupancy", self.slot_occupancy.to_json())
            .set("queue_depth", self.queue_depth.to_json())
            .set("page_occupancy", self.page_occupancy.to_json())
            .set("pages_in_use", self.pages_in_use.to_json());
        let mut kt = Json::obj();
        for (fmt, secs) in &self.kernel_time {
            kt.set(fmt, json::num(*secs));
        }
        o.set("kernel_time", kt);
        o
    }

    /// Write `SERVE_<suite>.json` into `$OATS_BENCH_DIR` (default: cwd),
    /// alongside the `BENCH_*.json` family, so serve-perf history
    /// accumulates per CI run.
    pub fn write_json(&self, suite: &str) -> std::io::Result<PathBuf> {
        let dir = std::env::var("OATS_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        let path = std::path::Path::new(&dir).join(format!("SERVE_{suite}.json"));
        std::fs::write(&path, self.to_json(suite).to_pretty())?;
        println!("serve json -> {}", path.display());
        Ok(path)
    }
}

/// Greedy-generate `n` tokens from `prompt` (single-stream decode). An
/// empty prompt yields an empty completion: there are no logits to decode
/// from (the buffer would stay all-zero and argmax would emit token 0
/// forever). This is the scalar reference the engine is property-tested
/// against; prompts beyond `seq_len` are truncated here (the serving path
/// rejects them with [`ResponseStatus::Truncated`] instead).
pub fn generate(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    if prompt.is_empty() {
        return Vec::new();
    }
    let mut cache = KvCache::new(&model.cfg);
    let mut logits = vec![0.0f32; model.cfg.vocab];
    let budget = model.cfg.seq_len;
    for &t in prompt.iter().take(budget) {
        logits = model.decode_step(t, &mut cache);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        logits = model.decode_step(next, &mut cache);
    }
    out
}

/// Single-sequence reference that routes EVERY step — prefill included —
/// through [`TransformerLM::decode_step_batch`] at batch 1: the engine's
/// exact compute path. Per-row results of the batched kernels are
/// independent of batch width, so this equals the continuous-batching
/// engine's output for any interleaving. For dense models it also equals
/// [`generate`] bit-for-bit; for packed/compressed models the batched
/// kernels' accumulation order can differ from the scalar `decode_step`
/// path in the last ulps (enough to flip an argmax near-tie), so
/// engine-parity tests on packed models must compare against this, not
/// against the scalar-prefill paths.
pub fn generate_lockstep(model: &TransformerLM, prompt: &[usize], n: usize) -> Vec<usize> {
    if prompt.is_empty() {
        return Vec::new();
    }
    let budget = model.cfg.seq_len;
    let mut cache = KvCache::new(&model.cfg);
    let mut logits: Vec<f32> = vec![0.0; model.cfg.vocab];
    let step = |tok: usize, cache: &mut KvCache, logits: &mut Vec<f32>| {
        let m = model.decode_step_batch(&[tok], &mut [cache]);
        logits.clear();
        logits.extend_from_slice(m.row(0));
    };
    for &t in prompt.iter().take(budget) {
        step(t, &mut cache, &mut logits);
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if cache.len >= budget {
            break;
        }
        let next = argmax(&logits);
        out.push(next);
        step(next, &mut cache, &mut logits);
    }
    out
}

/// Greedy-generate `n` tokens for a whole batch: per-sequence prefill
/// (ragged prompt lengths, fanned across `workers` threads), then lockstep
/// batched decode — each step runs the six linears and the head as
/// [b × d] products, which is the shape the planned BCSR/fused kernels are
/// packed for. Per-sequence results are independent of how requests are
/// grouped into batches here (every output element accumulates in a fixed
/// order), so `generate_batch(m, &[p], n, 1)[0]` is the reference for any
/// `generate_batch` grouping of `p`. It is NOT the engine reference: the
/// engine prefills through the batched kernels (use
/// [`generate_lockstep`]) and rejects oversized prompts instead of
/// truncating them.
pub fn generate_batch(
    model: &TransformerLM,
    prompts: &[Vec<usize>],
    n: usize,
    workers: usize,
) -> Vec<Vec<usize>> {
    let b = prompts.len();
    if b == 0 {
        return Vec::new();
    }
    let budget = model.cfg.seq_len;
    // Phase 1: prefill. Each sequence owns its KV cache, so chunks of the
    // state vector fan out across scoped threads.
    let mut states: Vec<(KvCache, Vec<f32>)> = prompts
        .iter()
        .map(|_| (KvCache::new(&model.cfg), vec![0.0f32; model.cfg.vocab]))
        .collect();
    let chunk = b.div_ceil(workers.max(1)).max(1);
    std::thread::scope(|s| {
        for (chunk_states, chunk_prompts) in states.chunks_mut(chunk).zip(prompts.chunks(chunk)) {
            s.spawn(move || {
                for ((cache, logits), p) in chunk_states.iter_mut().zip(chunk_prompts) {
                    for &t in p.iter().take(budget) {
                        *logits = model.decode_step(t, cache);
                    }
                }
            });
        }
    });
    // Phase 2: lockstep batched generation over the still-active sequences.
    // Empty prompts never activate (matching `generate`: no logits to
    // decode from), so they return empty completions.
    let mut out: Vec<Vec<usize>> = (0..b).map(|_| Vec::with_capacity(n)).collect();
    for _ in 0..n {
        let active: Vec<usize> = (0..b)
            .filter(|&i| !prompts[i].is_empty() && states[i].0.len < budget)
            .collect();
        if active.is_empty() {
            break;
        }
        let tokens: Vec<usize> = active.iter().map(|&i| argmax(&states[i].1)).collect();
        for (&i, &t) in active.iter().zip(&tokens) {
            out[i].push(t);
        }
        let logits = {
            let mut next = 0usize;
            let mut cache_refs: Vec<&mut KvCache> = Vec::with_capacity(active.len());
            for (i, st) in states.iter_mut().enumerate() {
                if next < active.len() && active[next] == i {
                    cache_refs.push(&mut st.0);
                    next += 1;
                }
            }
            model.decode_step_batch(&tokens, &mut cache_refs)
        };
        for (r, &i) in active.iter().enumerate() {
            states[i].1.clear();
            states[i].1.extend_from_slice(logits.row(r));
        }
    }
    out
}

/// Pull requests into the admission queue: block up to `poll` for the
/// first one, then drain everything already queued with `try_recv`, so a
/// burst enters the queue in ONE pump. Returns true once the request
/// channel has disconnected.
fn pump_requests(
    rx: &mpsc::Receiver<Submission>,
    poll: Duration,
    queue: &mut Batcher,
    sinks: &mut HashMap<u64, ResponseSink>,
) -> bool {
    match rx.recv_timeout(poll) {
        Ok((req, sink)) => {
            sinks.insert(req.id, sink);
            queue.push(req);
        }
        Err(mpsc::RecvTimeoutError::Timeout) => return false,
        Err(mpsc::RecvTimeoutError::Disconnected) => return true,
    }
    loop {
        match rx.try_recv() {
            Ok((req, sink)) => {
                sinks.insert(req.id, sink);
                queue.push(req);
            }
            Err(mpsc::TryRecvError::Empty) => return false,
            Err(mpsc::TryRecvError::Disconnected) => return true,
        }
    }
}

/// Route one engine event to its response channel.
fn dispatch(ev: SeqEvent, sinks: &mut HashMap<u64, ResponseSink>) {
    match ev {
        SeqEvent::Token { id, token, first } => {
            if let Some(ResponseSink::Stream(tx)) = sinks.get(&id) {
                let _ = tx.send(StreamEvent::Token { token, first });
            }
        }
        SeqEvent::Finished(f) => {
            let resp = Response {
                id: f.id,
                tokens: f.tokens,
                latency: f.enqueued.elapsed(),
                queue_wait: f.queue_wait,
                first_token_latency: f.first_token_latency,
                status: f.status,
            };
            match sinks.remove(&resp.id) {
                Some(ResponseSink::Unary(tx)) => {
                    let _ = tx.send(resp);
                }
                Some(ResponseSink::Stream(tx)) => {
                    let _ = tx.send(StreamEvent::Done(resp));
                }
                None => {}
            }
        }
    }
}

/// The server: owns the engine thread (admission queue + continuous-
/// batching decode loop) and the request channel into it.
pub struct Server {
    req_tx: Option<mpsc::Sender<Submission>>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
    telemetry: Arc<Mutex<EngineTelemetry>>,
}

impl Server {
    pub fn start(model: Arc<TransformerLM>, cfg: ServeConfig) -> Server {
        // Kernel-dispatch step: decode batches are at most `slots` wide,
        // so pre-pack each compressed layer for that batch shape once, up
        // front, instead of running scalar CSR per request.
        let model = if cfg.prepack && model.needs_packing() {
            Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
        } else {
            model
        };
        let (req_tx, req_rx) = mpsc::channel::<Submission>();
        let mut engine = Engine::new(model, cfg.engine_config());
        let telemetry = engine.telemetry();

        let handle = std::thread::spawn(move || {
            let mut queue = Batcher::default();
            let mut sinks: HashMap<u64, ResponseSink> = HashMap::new();
            let mut closed = false;
            loop {
                // While sequences are resident, only drain what's already
                // queued (zero-poll) so decode never stalls on arrivals;
                // when idle, block briefly so the loop doesn't spin.
                let poll = if engine.is_idle() {
                    Duration::from_micros(200)
                } else {
                    Duration::ZERO
                };
                if pump_requests(&req_rx, poll, &mut queue, &mut sinks) {
                    closed = true;
                }
                for ev in engine.step(&mut queue) {
                    dispatch(ev, &mut sinks);
                }
                if closed && engine.is_idle() && queue.is_empty() {
                    break;
                }
            }
        });

        Server { req_tx: Some(req_tx), engine_handle: Some(handle), telemetry }
    }

    /// Submit a request; returns the response receiver (one terminal
    /// [`Response`]).
    pub fn submit(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<Response> {
        self.submit_budgeted(id, prompt, None)
    }

    /// [`Server::submit`] with a per-request generation budget
    /// (`None` ⇒ the server-wide `gen_tokens` default). Short budgets also
    /// shrink the request's worst-case KV page reservation, so they admit
    /// alongside bigger requests on a tight paged arena.
    pub fn submit_budgeted(
        &self,
        id: u64,
        prompt: Vec<usize>,
        gen_tokens: Option<usize>,
    ) -> mpsc::Receiver<Response> {
        let mut req = Request::new(id, prompt);
        req.gen_tokens = gen_tokens;
        self.submit_request(req)
    }

    /// Submit a fully-specified [`Request`] — the entry point for the
    /// per-request knobs the shorthand submitters leave at their defaults
    /// ([`Request::with_stop_tokens`], [`Request::without_prefix_sharing`],
    /// [`Request::with_budget`]).
    pub fn submit_request(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.send(req, ResponseSink::Unary(tx));
        rx
    }

    /// Submit a request for per-token streaming: the receiver yields a
    /// [`StreamEvent::Token`] per generated token as the engine emits it,
    /// then [`StreamEvent::Done`] with the full response.
    pub fn submit_streaming(&self, id: u64, prompt: Vec<usize>) -> mpsc::Receiver<StreamEvent> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::new(id, prompt), ResponseSink::Stream(tx));
        rx
    }

    fn send(&self, req: Request, sink: ResponseSink) {
        self.req_tx.as_ref().expect("server stopped").send((req, sink)).expect("engine alive");
    }

    /// Snapshot of the engine's per-step telemetry so far.
    pub fn telemetry(&self) -> EngineTelemetry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Stop accepting requests and wait for in-flight work.
    pub fn shutdown(mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

/// Closed-loop load test: submit `n_requests` prompts, wait for all, and
/// report stats. This is the Table 7 / Table 14 measurement harness and
/// the `serve-load` smoke driver.
pub fn run_load(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    prompts: Vec<Vec<usize>>,
) -> ServeStats {
    run_load_mixed(model, cfg, prompts.into_iter().map(|p| (p, None)).collect())
}

/// [`run_load`] with per-request generation budgets: each entry is
/// `(prompt, gen_tokens)` where `None` takes the server-wide default —
/// the `oats serve-load --gen-tokens-mix` driver.
pub fn run_load_mixed(
    model: Arc<TransformerLM>,
    cfg: ServeConfig,
    requests: Vec<(Vec<usize>, Option<usize>)>,
) -> ServeStats {
    // Pack before starting the clock: packing is one-time startup cost and
    // must not bias the measured throughput of compressed models (the dense
    // baseline pays no equivalent cost).
    let model = if cfg.prepack && model.needs_packing() {
        Arc::new(model.packed_for_serving_with(&cfg.pack_options()))
    } else {
        model
    };
    let share = cfg.share_prefix;
    let t0 = Instant::now();
    let server = Server::start(model, cfg);
    let rxs: Vec<mpsc::Receiver<Response>> = requests
        .into_iter()
        .enumerate()
        .map(|(i, (p, gen))| {
            let mut req = Request::new(i as u64, p);
            req.gen_tokens = gen;
            req.share_prefix = share;
            server.submit_request(req)
        })
        .collect();
    let mut latencies = Vec::new();
    let mut queue_waits = Vec::new();
    let mut first_token_latencies = Vec::new();
    let mut tokens = 0usize;
    // FNV-1a over (id, completion) in id order: receivers are indexed by
    // id, so this digest depends only on what each request got back —
    // identical completions ⇒ identical digest, whatever the engine's
    // step-by-step interleaving was.
    let mut digest: u64 = 0xcbf29ce484222325;
    let mut fold = |x: u64| digest = (digest ^ x).wrapping_mul(0x100000001b3);
    let n = rxs.len();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        latencies.push(resp.latency.as_secs_f64());
        queue_waits.push(resp.queue_wait.as_secs_f64());
        if let Some(ftl) = resp.first_token_latency {
            first_token_latencies.push(ftl.as_secs_f64());
        }
        tokens += resp.tokens.len();
        fold(i as u64);
        fold(resp.tokens.len() as u64);
        for &t in &resp.tokens {
            fold(t as u64);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let telemetry = server.telemetry();
    server.shutdown();
    let mut stats = ServeStats::from_run(
        n,
        tokens,
        wall,
        &latencies,
        &queue_waits,
        &first_token_latencies,
        &telemetry,
    );
    stats.completions_digest = digest;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::TransformerLM;

    fn tiny() -> Arc<TransformerLM> {
        Arc::new(TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 5))
    }

    #[test]
    fn pump_drains_queued_burst_in_one_call() {
        // The engine loop must not need one poll cycle per request: a burst
        // already sitting in the channel enters the queue in one pump.
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for i in 0..5u64 {
            let (rtx, _rrx) = mpsc::channel();
            let mut req = Request::new(i, vec![1]);
            req.enqueued = t0;
            tx.send((req, ResponseSink::Unary(rtx))).unwrap();
        }
        let mut b = Batcher::default();
        let mut sinks = HashMap::new();
        let closed = pump_requests(&rx, Duration::from_millis(10), &mut b, &mut sinks);
        assert!(!closed);
        assert_eq!(b.len(), 5, "burst must enter the queue in one pump");
        assert_eq!(sinks.len(), 5);
        // Disconnect is reported once the senders are gone.
        drop(tx);
        assert!(pump_requests(&rx, Duration::from_millis(1), &mut b, &mut sinks));
    }

    #[test]
    fn generate_respects_budget() {
        let m = tiny();
        let out = generate(&m, &[1, 2, 3], 10);
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|&t| t < m.cfg.vocab));
        // Over-long generation stops at seq_len.
        let out2 = generate(&m, &[1, 2, 3], 10_000);
        assert!(out2.len() <= m.cfg.seq_len);
    }

    #[test]
    fn generate_deterministic() {
        let m = tiny();
        assert_eq!(generate(&m, &[4, 5], 8), generate(&m, &[4, 5], 8));
    }

    #[test]
    fn generate_batch_matches_scalar_generate() {
        // Dense model: the batched lockstep path is arithmetically identical
        // to per-sequence scalar decode, ragged prompt lengths included —
        // and an empty prompt yields an empty completion in both paths
        // (decoding from the all-zero logits buffer would emit token 0).
        let m = tiny();
        let prompts = vec![vec![1usize, 2, 3], vec![], vec![4usize, 5], vec![9usize]];
        let batch = generate_batch(&m, &prompts, 6, 2);
        assert_eq!(batch.len(), 4);
        for (p, got) in prompts.iter().zip(&batch) {
            assert_eq!(got, &generate(&m, p, 6), "prompt {p:?}");
        }
        assert!(batch[1].is_empty(), "empty prompt must yield empty completion");
        assert!(generate(&m, &[], 5).is_empty());
        assert!(generate_batch(&m, &[], 4, 2).is_empty());
    }

    #[test]
    fn generate_batch_respects_budget() {
        let m = tiny();
        let long: Vec<usize> = (0..m.cfg.seq_len - 2).map(|i| i % 16).collect();
        let outs = generate_batch(&m, &[long.clone(), vec![1, 2]], 10_000, 2);
        assert_eq!(outs[0].len(), 2, "near-full cache generates to the cap");
        assert!(outs[1].len() <= m.cfg.seq_len);
    }

    #[test]
    fn server_round_trip() {
        let m = tiny();
        let cfg = ServeConfig { slots: 4, gen_tokens: 4, ..Default::default() };
        let stats = run_load(m, cfg, (0..10).map(|i| vec![i % 16, 1, 2]).collect());
        assert_eq!(stats.n_requests, 10);
        assert_eq!(stats.tokens_generated, 40);
        assert!(stats.tokens_per_second() > 0.0);
        assert!(stats.latency.max >= stats.latency.min);
        assert_eq!(stats.joins, 10);
        assert_eq!(stats.leaves, 10);
        assert_eq!(stats.truncated, 0);
        assert_eq!(stats.capacity_stopped, 0);
        assert!(stats.steps > 0);
        assert!(stats.slot_occupancy.mean > 0.0);
        assert!(stats.kv_bytes > 0);
        assert_eq!(stats.first_token_latency.n, 10);
        // Default config is the whole-cache degenerate arena.
        assert_eq!(stats.kv_pages, stats.slots);
        assert!(stats.page_occupancy.mean > 0.0);
        assert_eq!(stats.pages_in_use_at_drain, 0, "pages leaked");
    }

    #[test]
    fn paged_server_matches_scalar_outputs_and_conserves_pages() {
        let m = tiny();
        let cfg = ServeConfig {
            slots: 6,
            gen_tokens: 5,
            page_size: 8,
            kv_pages: 18,
            ..Default::default()
        };
        let prompts: Vec<Vec<usize>> =
            (0..12).map(|i| (0..(1 + i % 5)).map(|j| (i * 7 + j) % 16).collect()).collect();
        let server = Server::start(Arc::clone(&m), cfg);
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens, generate(&m, p, 5), "prompt {p:?}");
            assert_eq!(resp.status, ResponseStatus::Complete);
        }
        let t = server.telemetry();
        assert_eq!(t.page_size, 8);
        assert_eq!(t.total_pages, 18);
        assert_eq!(t.pages_in_use_now, 0, "pages leaked after drain");
        assert!(t.pages_in_use.iter().all(|&p| p <= 18.0));
        drop(server);
    }

    #[test]
    fn budgeted_submissions_cap_generation_per_request() {
        let m = tiny();
        let cfg = ServeConfig { slots: 4, gen_tokens: 8, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let default_rx = server.submit(0, vec![1, 2, 3]);
        let short_rx = server.submit_budgeted(1, vec![1, 2, 3], Some(2));
        let zero_rx = server.submit_budgeted(2, vec![4, 5], Some(0));
        let default = default_rx.recv().unwrap();
        assert_eq!(default.tokens, generate(&m, &[1, 2, 3], 8));
        let short = short_rx.recv().unwrap();
        assert_eq!(short.tokens, generate(&m, &[1, 2, 3], 2));
        assert_eq!(short.status, ResponseStatus::Complete);
        let zero = zero_rx.recv().unwrap();
        assert!(zero.tokens.is_empty(), "zero budget must complete empty");
        assert_eq!(zero.status, ResponseStatus::Complete);
        drop(server);
    }

    #[test]
    fn run_load_mixed_applies_budgets() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 6, ..Default::default() };
        let reqs =
            vec![(vec![1usize, 2], None), (vec![3usize, 4], Some(3)), (vec![5usize], Some(1))];
        let stats = run_load_mixed(m, cfg, reqs);
        assert_eq!(stats.n_requests, 3);
        assert_eq!(stats.tokens_generated, 6 + 3 + 1);
        assert_eq!(stats.joins, 3);
        assert_eq!(stats.leaves, 3);
    }

    #[test]
    fn rejection_only_load_still_reports_steps_and_summaries() {
        // Regression: a run that produces nothing but slot-free rejections
        // used to emit SERVE json with steps == 0 and empty summaries,
        // which the CI smoke gates would read as a dead engine.
        let m = tiny();
        let cap = m.cfg.seq_len;
        let cfg = ServeConfig { slots: 2, gen_tokens: 4, ..Default::default() };
        let stats = run_load(m, cfg, vec![vec![1; cap + 1], vec![2; cap + 7]]);
        assert_eq!(stats.n_requests, 2);
        assert_eq!(stats.tokens_generated, 0);
        assert_eq!(stats.truncated, 2);
        assert!(stats.steps > 0, "rejections are worked steps");
        assert!(stats.queue_depth.n > 0, "telemetry sampled");
        assert_eq!(stats.latency.n, 2, "rejected requests still report latency");
    }

    #[test]
    fn server_matches_scalar_generate_per_request() {
        // Continuous batching must not change any request's tokens.
        let m = tiny();
        let prompts: Vec<Vec<usize>> =
            (0..9).map(|i| (0..(1 + i % 4)).map(|j| (i * 5 + j) % 16).collect()).collect();
        let cfg = ServeConfig { slots: 3, gen_tokens: 5, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens, generate(&m, p, 5), "prompt {p:?}");
            assert_eq!(resp.status, ResponseStatus::Complete);
        }
    }

    #[test]
    fn streaming_submission_yields_tokens_then_done() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 6, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rx = server.submit_streaming(7, vec![1, 2, 3]);
        let mut streamed = Vec::new();
        let mut done: Option<Response> = None;
        for ev in rx {
            match ev {
                StreamEvent::Token { token, first } => {
                    assert_eq!(first, streamed.is_empty(), "first flag on first token only");
                    streamed.push(token);
                }
                StreamEvent::Done(resp) => {
                    done = Some(resp);
                    break;
                }
            }
        }
        let resp = done.expect("terminal Done event");
        assert_eq!(resp.tokens, streamed, "stream must equal the final response");
        assert_eq!(resp.tokens, generate(&m, &[1, 2, 3], 6));
        let ftl = resp.first_token_latency.expect("first token seen");
        assert!(ftl <= resp.latency, "first token cannot be later than completion");
    }

    #[test]
    fn generate_lockstep_matches_generate_on_dense() {
        // Dense layers run identical arithmetic through decode_step and
        // decode_step_batch, so the two references coincide exactly.
        let m = tiny();
        for p in [vec![1usize, 2, 3], vec![], vec![9usize]] {
            assert_eq!(generate_lockstep(&m, &p, 7), generate(&m, &p, 7), "prompt {p:?}");
        }
    }

    #[test]
    fn prepacked_server_matches_unpacked_outputs() {
        // Compress a model, then serve it with and without kernel pre-packing:
        // generated tokens must be identical to batch-of-1 lockstep decode
        // through the same kernels (`generate_lockstep` — the engine prefills
        // through the batched kernels, so scalar-prefill references could
        // differ in the last ulps on packed layers). Packed vs unpacked
        // numerics only agree to ~1e-4, so cross-mode token equality would be
        // tie-dependent; per-sequence results are independent of how the
        // engine batches, so continuous batching's groupings don't matter.
        let base = TransformerLM::init(&ModelConfig::preset("tiny").unwrap(), 21);
        let corpus = crate::data::SyntheticCorpus::new(crate::data::CorpusConfig::for_vocab(
            base.cfg.vocab,
            2,
        ));
        let calib = crate::calib::CalibSet::sample(&corpus, 4, 16, 4);
        let ccfg = crate::config::CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 2,
            ..Default::default()
        };
        let (cm, _) =
            crate::coordinator::pipeline::compress_clone(&base, &calib, &ccfg, 2).unwrap();
        assert!(cm.needs_packing());
        let prompts: Vec<Vec<usize>> = (0..6).map(|i| vec![i % 16, 3, 5]).collect();
        let run = |prepack: bool| -> Vec<Vec<usize>> {
            let cfg = ServeConfig { slots: 4, gen_tokens: 6, prepack, ..Default::default() };
            let server = Server::start(Arc::new(cm.clone()), cfg);
            let rxs: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| server.submit(i as u64, p.clone()))
                .collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect()
        };
        let packed = cm.packed_for_serving(4);
        let want_packed: Vec<Vec<usize>> =
            prompts.iter().map(|p| generate_lockstep(&packed, p, 6)).collect();
        assert_eq!(run(true), want_packed);
        let want_unpacked: Vec<Vec<usize>> =
            prompts.iter().map(|p| generate_lockstep(&cm, p, 6)).collect();
        assert_eq!(run(false), want_unpacked);
    }

    #[test]
    fn oversized_prompt_surfaces_truncated_status() {
        let m = tiny();
        let cap = m.cfg.seq_len;
        let cfg = ServeConfig { slots: 2, gen_tokens: 4, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let ok_rx = server.submit(0, vec![1, 2, 3]);
        let over_rx = server.submit(1, vec![1; cap + 5]);
        let over = over_rx.recv().unwrap();
        assert_eq!(over.status, ResponseStatus::Truncated);
        assert!(over.tokens.is_empty());
        assert!(over.first_token_latency.is_none());
        let ok = ok_rx.recv().unwrap();
        assert_eq!(ok.status, ResponseStatus::Complete);
        assert_eq!(ok.tokens.len(), 4);
        drop(server);
    }

    #[test]
    fn decode_batches_never_exceed_slots() {
        let m = tiny();
        let cfg = ServeConfig { slots: 3, gen_tokens: 2, ..Default::default() };
        let server = Server::start(m, cfg);
        let rxs: Vec<_> = (0..7).map(|i| server.submit(i, vec![1, 2])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let t = server.telemetry();
        assert!(t.decode_batch.iter().all(|&b| b <= 3.0), "{:?}", t.decode_batch);
        assert_eq!(t.joins, 7);
        assert_eq!(t.leaves, 7);
        drop(server);
    }

    #[test]
    fn prequeued_burst_fills_the_arena() {
        // A queued burst of exactly `slots` requests must be admitted
        // together and decode at full width. Driven synchronously at the
        // engine level: through the threaded server the engine admits
        // whatever has *arrived*, so full-width there would race the
        // submitting thread.
        let m = tiny();
        let cfg = EngineConfig { slots: 6, gen_tokens: 2, ..Default::default() };
        let mut engine = Engine::new(m, cfg);
        let mut queue = Batcher::default();
        for i in 0..6u64 {
            queue.push(Request::new(i, vec![1, 2]));
        }
        let mut finished = 0;
        for _ in 0..100 {
            for ev in engine.step(&mut queue) {
                if matches!(ev, SeqEvent::Finished(_)) {
                    finished += 1;
                }
            }
            if finished == 6 {
                break;
            }
        }
        assert_eq!(finished, 6);
        let t = engine.telemetry().lock().unwrap().clone();
        let peak = t.occupancy.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(peak, 1.0, "burst must fill all slots: {:?}", t.occupancy);
        let widest = t.decode_batch.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(widest, 6.0, "full-width decode batch expected: {:?}", t.decode_batch);
    }

    #[test]
    fn shared_prefix_load_matches_unshared_digest_and_saves_prefill() {
        // The CI shared-prefix gate in miniature: the same workload run
        // with sharing on and off must produce byte-identical completions
        // (equal digests) at equal KV bytes, with the shared run actually
        // skipping prefill work and leaking nothing.
        let m = tiny();
        let head: Vec<usize> = (1..=12).collect();
        let prompts: Vec<Vec<usize>> = (0..8)
            .map(|i| head.iter().copied().chain([(i * 3) % 16, (i * 5 + 1) % 16]).collect())
            .collect();
        let cfg = |share: bool| ServeConfig {
            slots: 4,
            gen_tokens: 4,
            page_size: 4,
            kv_pages: 24,
            share_prefix: share,
            ..Default::default()
        };
        let shared = run_load(Arc::clone(&m), cfg(true), prompts.clone());
        let unshared = run_load(Arc::clone(&m), cfg(false), prompts.clone());
        assert_eq!(
            shared.completions_digest, unshared.completions_digest,
            "prefix sharing changed some completion"
        );
        assert_ne!(shared.completions_digest, 0);
        assert_eq!(shared.kv_bytes, unshared.kv_bytes, "A/B must compare equal arenas");
        assert!(shared.prefill_tokens_saved > 0, "no prefill was reused");
        assert!(shared.shared_pages > 0);
        assert_eq!(unshared.prefill_tokens_saved, 0);
        assert_eq!(unshared.shared_pages, 0);
        assert_eq!(shared.pages_in_use_at_drain, 0, "shared run leaked pages");
        assert_eq!(unshared.pages_in_use_at_drain, 0);
        // Per-request tokens also equal the scalar reference.
        let server = Server::start(Arc::clone(&m), cfg(true));
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| server.submit(i as u64, p.clone()))
            .collect();
        for (rx, p) in rxs.into_iter().zip(&prompts) {
            assert_eq!(rx.recv().unwrap().tokens, generate(&m, p, 4), "prompt {p:?}");
        }
        drop(server);
    }

    #[test]
    fn stop_tokens_surface_stopped_status_through_the_server() {
        let m = tiny();
        let prompt = vec![1, 2, 3];
        let free = generate(&m, &prompt, 8);
        let stop = free[1];
        let cut = free.iter().position(|&t| t == stop).unwrap();
        let cfg = ServeConfig { slots: 2, gen_tokens: 8, ..Default::default() };
        let server = Server::start(Arc::clone(&m), cfg);
        let rx = server.submit_request(Request::new(0, prompt).with_stop_tokens(vec![stop]));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.status, ResponseStatus::StoppedAtToken);
        assert_eq!(resp.tokens, &free[..=cut]);
        assert_eq!(*resp.tokens.last().unwrap(), stop);
        drop(server);
    }

    #[test]
    fn serve_stats_json_round_trips() {
        let m = tiny();
        let cfg = ServeConfig { slots: 2, gen_tokens: 3, ..Default::default() };
        let stats = run_load(m, cfg, vec![vec![1, 2], vec![3], vec![4, 5, 6]]);
        let j = stats.to_json("unittest");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("oats-serve-v1"));
        assert!(j.req_f64("tokens_per_second").unwrap() > 0.0);
        assert_eq!(j.req_f64("joins").unwrap(), 3.0);
        let lat = j.get("latency_s").expect("latency summary");
        assert!(lat.req_f64("p95").unwrap() >= lat.req_f64("p50").unwrap());
        assert!(lat.req_f64("p99").unwrap() >= lat.req_f64("p95").unwrap());
        // Paged-arena telemetry rides along (the CI gates read these).
        assert_eq!(j.req_f64("capacity_stopped").unwrap(), 0.0);
        assert_eq!(j.req_f64("pages_in_use_at_drain").unwrap(), 0.0);
        // Queue wait is its own summary, distinct from first-token latency.
        let qw = j.get("queue_wait").expect("queue wait summary");
        assert_eq!(qw.req_f64("n").unwrap(), 3.0, "every request reports a queue wait");
        assert!(qw.req_f64("mean").unwrap() >= 0.0);
        // The per-phase breakdown sums to at most the step wall-clock.
        let phase_sum = j.req_f64("time_admit_s").unwrap()
            + j.req_f64("time_prefill_s").unwrap()
            + j.req_f64("time_decode_s").unwrap()
            + j.req_f64("time_retire_s").unwrap();
        assert!(phase_sum > 0.0, "phase clocks must run without tracing");
        assert!(phase_sum <= j.req_f64("time_step_s").unwrap());
        // Untraced runs carry an empty kernel_time object.
        assert!(j.get("kernel_time").is_some());
        // Workspace telemetry: the decode loop allocated something during
        // warmup, and far fewer buffers than decode calls (reuse works).
        assert!(j.req_f64("ws_buffer_allocs").unwrap() > 0.0);
        // Shared-prefix telemetry rides along (the CI gates read these);
        // the digest is a 16-hex-digit string, not a lossy f64.
        assert!(j.req_f64("prefill_tokens_saved").is_ok());
        assert!(j.req_f64("shared_pages").is_ok());
        assert!(j.req_f64("cow_forks").is_ok());
        assert!(j.req_f64("prefix_evictions_cap").is_ok());
        let digest = j.get("completions_digest").and_then(Json::as_str).unwrap();
        assert_eq!(digest.len(), 16);
        assert!(u64::from_str_radix(digest, 16).is_ok());
        assert!(j.req_f64("page_size").unwrap() > 0.0);
        assert!(j.req_f64("kv_pages").unwrap() > 0.0);
        let occ = j.get("page_occupancy").expect("page occupancy summary");
        let occ_mean = occ.req_f64("mean").unwrap();
        assert!(occ_mean > 0.0 && occ_mean <= 1.0, "page occupancy {occ_mean}");
        // Round-trips through the parser (what the CI smoke gate does).
        let parsed = crate::json::parse(&j.to_pretty()).unwrap();
        assert!(parsed.get("slot_occupancy").is_some());
        assert!(parsed.get("pages_in_use").is_some());
    }
}
