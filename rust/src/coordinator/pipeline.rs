//! Compression pipeline orchestrator.
//!
//! Responsibilities (paper §3.1 experiment protocol):
//! 1. optional OWL pre-pass computing per-block compression rates;
//! 2. sequential block loop propagating calibration activations through
//!    already-compressed blocks (Algorithm 2);
//! 3. per-block parallel compression of the six linear layers (the paper
//!    notes per-block parallelism in §A.2);
//! 4. commit + telemetry (per-layer residuals, achieved rates, wall-clock).

use crate::calib::{BlockPropagator, CalibSet};
use crate::compress::slice::{self, SliceGate, SliceMap};
use crate::compress::{self, owl, CalibStats, CompressedLayer};
use crate::config::{CompressConfig, Method};
use crate::model::{LinearId, LinearOp, TransformerLM, LINEAR_NAMES};
use crate::util::trace;
use anyhow::Result;
use std::sync::mpsc;

/// Telemetry for one compressed layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub id: LinearId,
    pub target_rate: f64,
    pub achieved_rate: f64,
    /// ‖W − Ŵ‖_F / ‖W‖_F (unscaled reconstruction error).
    pub rel_error: f64,
    pub seconds: f64,
}

/// Full pipeline telemetry.
#[derive(Clone, Debug, Default)]
pub struct CompressionReport {
    pub layers: Vec<LayerReport>,
    pub total_seconds: f64,
    /// Per-block wall-clock (Table 9's measurement).
    pub block_seconds: Vec<f64>,
    pub owl_rates: Option<Vec<f64>>,
}

impl CompressionReport {
    pub fn mean_rel_error(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.rel_error).sum::<f64>() / self.layers.len() as f64
    }

    pub fn achieved_rate(&self) -> f64 {
        // parameter-weighted is what the model reports; this is the mean.
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.achieved_rate).sum::<f64>() / self.layers.len() as f64
    }
}

/// Compress every prunable layer of `model` in place.
///
/// `workers` controls the per-block fan-out (1 = sequential). The calibration
/// set must have been sampled with the same corpus/stream for every method
/// being compared (paper §3.1).
pub fn compress_model(
    model: &mut TransformerLM,
    calib: &CalibSet,
    cfg: &CompressConfig,
    workers: usize,
) -> Result<CompressionReport> {
    let mut report = CompressionReport::default();
    // Always-measuring spans double as the report's wall-clock source, so
    // the numbers in `CompressionReport` and an exported trace agree.
    let whole = trace::timed("compress_model");

    // ── OWL pre-pass: per-block rates from outlier fractions ──
    let n_blocks = model.blocks.len();
    let block_rates: Vec<f64> = if cfg.owl {
        let t_owl = trace::timed("owl_calibration");
        let mut prop = BlockPropagator::new(model, calib);
        let mut fracs = Vec::with_capacity(n_blocks);
        let mut params = Vec::with_capacity(n_blocks);
        for b in 0..n_blocks {
            let stats = prop.capture_stats();
            // Block outlier fraction: parameter-weighted mean over linears.
            let mut f = 0.0;
            let mut p_total = 0usize;
            for name in LINEAR_NAMES {
                let w = model.blocks[b].linear(name).dense_view();
                let pc = w.rows * w.cols;
                f += owl::outlier_fraction(&w, &stats[name], cfg.owl_m) * pc as f64;
                p_total += pc;
            }
            fracs.push(f / p_total as f64);
            params.push(p_total);
            prop.advance();
        }
        let rates = owl::layerwise_rates(&fracs, &params, cfg.rate, cfg.owl_lambda);
        report.owl_rates = Some(rates.clone());
        t_owl.finish();
        rates
    } else {
        vec![cfg.rate; n_blocks]
    };

    // ── main block loop (Algorithm 2) ──
    // BlockPropagator borrows the model immutably, so each iteration scopes
    // the borrow: capture stats → drop propagator → mutate → re-embed would
    // be O(L²). Instead we keep hidden states outside and call block_forward
    // directly.
    let mut hidden: Vec<crate::tensor::Matrix> =
        calib.batches.iter().map(|b| model.embed(&b.inputs)).collect();
    let batch_sizes: Vec<usize> = calib.batches.iter().map(|b| b.inputs.len()).collect();
    let s = calib.seq_len;

    for b in 0..n_blocks {
        let t_block = trace::timed("compress_block");
        // capture stats with current (compressed-so-far) activations
        let stats: std::collections::HashMap<&'static str, CalibStats> = {
            let mut map: std::collections::HashMap<&'static str, CalibStats> =
                std::collections::HashMap::new();
            for (h, &bsz) in hidden.iter().zip(&batch_sizes) {
                let mut cap = crate::model::ForwardCapture::default();
                let _ = model.block_forward(b, h, bsz, s, Some(&mut cap), None);
                for name in LINEAR_NAMES {
                    let x = &cap.inputs[name];
                    map.entry(name)
                        .or_insert_with(|| CalibStats::new(x.cols))
                        .update(x, 128);
                }
            }
            for st in map.values_mut() {
                st.finalize();
            }
            map
        };

        // compress the six linears (possibly in parallel)
        let layer_cfg = CompressConfig { rate: block_rates[b], ..cfg.clone() };
        let jobs: Vec<(&'static str, crate::tensor::Matrix, CalibStats)> = LINEAR_NAMES
            .iter()
            .map(|&name| (name, model.blocks[b].linear(name).dense_view(), stats[name].clone()))
            .collect();

        let results: Vec<(&'static str, Result<CompressedLayer>, f64)> = if workers > 1 {
            let (tx, rx) = mpsc::channel();
            std::thread::scope(|scope| {
                for (name, w, st) in &jobs {
                    let tx = tx.clone();
                    let lc = layer_cfg.clone();
                    scope.spawn(move || {
                        let t_layer = trace::timed("compress_layer");
                        let r = compress::compress_layer(w, st, &lc);
                        let _ = tx.send((*name, r, t_layer.finish()));
                    });
                }
            });
            drop(tx);
            rx.into_iter().collect()
        } else {
            jobs.iter()
                .map(|(name, w, st)| {
                    let t_layer = trace::timed("compress_layer");
                    let r = compress::compress_layer(w, st, &layer_cfg);
                    (*name, r, t_layer.finish())
                })
                .collect()
        };

        // commit + telemetry
        for (name, result, dt) in results {
            let compressed = result?;
            let id = LinearId { block: b, name };
            let w_orig = model.blocks[b].linear(name).dense_view();
            let w_new = compressed.to_dense();
            let mut diff = w_orig.clone();
            diff.axpy(-1.0, &w_new);
            let denom = w_orig.fro_norm().max(1e-12);
            report.layers.push(LayerReport {
                id,
                target_rate: block_rates[b],
                // Rate accounting is always against the ORIGINAL dense
                // shape — `shape()`-derived denominators over-report for
                // shape-changing variants.
                achieved_rate: compressed.compression_rate((w_orig.rows, w_orig.cols)),
                rel_error: diff.fro_norm() / denom,
                seconds: dt,
            });
            model.set_linear(id, LinearOp::Compressed(compressed));
        }

        // ── rotate-and-slice arbitration for the FFN pair ──
        // The structured candidate is computed from the pre-compression
        // dense weights and the same per-block stats; the gate (identical
        // rel_error machinery to `QuantGate`) decides per block whether the
        // sliced-dense pair replaces whatever the unstructured pass chose.
        // Only up's output / down's input shrink — the residual stream and
        // attention/KV stay at d_model, so forward propagation is unchanged.
        if let Some(sr) = cfg.slice_rate {
            let w_up = &jobs.iter().find(|j| j.0 == "up").expect("up job").1;
            let w_down = &jobs.iter().find(|j| j.0 == "down").expect("down job").1;
            let d_model = w_up.cols;
            let pair = slice::slice_ffn_pair(w_up, w_down, &stats["down"], sr);
            let up_back = slice::scatter_to_original(
                &pair.up,
                &pair.map,
                &SliceMap::identity(d_model),
            );
            let down_back = slice::scatter_to_original(
                &pair.down,
                &SliceMap::identity(d_model),
                &pair.map,
            );
            let up_gate = SliceGate::evaluate(w_up, &up_back, cfg.slice_max_rel_error);
            let down_gate = SliceGate::evaluate(w_down, &down_back, cfg.slice_max_rel_error);
            if up_gate.accept() && down_gate.accept() {
                let commits = [
                    (
                        "up",
                        CompressedLayer::SlicedDense {
                            w: pair.up,
                            in_map: SliceMap::identity(d_model),
                            out_map: pair.map.clone(),
                        },
                        up_gate.rel_error,
                        (w_up.rows, w_up.cols),
                    ),
                    (
                        "down",
                        CompressedLayer::SlicedDense {
                            w: pair.down,
                            in_map: pair.map,
                            out_map: SliceMap::identity(d_model),
                        },
                        down_gate.rel_error,
                        (w_down.rows, w_down.cols),
                    ),
                ];
                for (name, layer, rel_error, orig) in commits {
                    let id = LinearId { block: b, name };
                    let entry = report
                        .layers
                        .iter_mut()
                        .rev()
                        .find(|l| l.id == id)
                        .expect("layer committed above");
                    entry.achieved_rate = layer.compression_rate(orig);
                    entry.rel_error = rel_error;
                    model.set_linear(id, LinearOp::Compressed(layer));
                }
            }
        }

        // propagate through the now-compressed block
        for (h, &bsz) in hidden.iter_mut().zip(&batch_sizes) {
            *h = model.block_forward(b, h, bsz, s, None, None);
        }
        report.block_seconds.push(t_block.finish());
    }

    report.total_seconds = whole.finish();
    report.layers.sort_by_key(|l| (l.id.block, l.id.name));
    Ok(report)
}

/// Convenience: compress a fresh clone of the model, leaving the input
/// untouched (used by the sweep/table harnesses that compare methods).
pub fn compress_clone(
    model: &TransformerLM,
    calib: &CalibSet,
    cfg: &CompressConfig,
    workers: usize,
) -> Result<(TransformerLM, CompressionReport)> {
    let mut m = model.clone();
    let report = compress_model(&mut m, calib, cfg, workers)?;
    Ok((m, report))
}

/// Methods with no compression work (Dense) skip the pipeline entirely —
/// unless a slice pass is requested, which has work to do even at
/// `method = Dense` (and even at slice rate 0: the rotation still permutes).
pub fn is_noop(cfg: &CompressConfig) -> bool {
    (matches!(cfg.method, Method::Dense) || cfg.rate <= 0.0) && cfg.slice_rate.is_none()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{CorpusConfig, SyntheticCorpus};

    fn setup() -> (TransformerLM, CalibSet) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 17);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 4));
        let calib = CalibSet::sample(&corpus, 8, 16, 4);
        (model, calib)
    }

    #[test]
    fn oats_pipeline_compresses_all_layers() {
        let (model, calib) = setup();
        let cfg = CompressConfig { rate: 0.5, rank_ratio: 0.25, iters: 3, ..Default::default() };
        let (m, report) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        assert_eq!(report.layers.len(), model.blocks.len() * 6);
        let achieved = m.achieved_compression();
        assert!((achieved - 0.5).abs() < 0.05, "achieved {achieved}");
        assert_eq!(report.block_seconds.len(), model.blocks.len());
    }

    #[test]
    fn parallel_matches_sequential() {
        let (model, calib) = setup();
        let cfg = CompressConfig { rate: 0.4, rank_ratio: 0.2, iters: 2, ..Default::default() };
        let (m1, _) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        let (m4, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        let d = m1.forward(&toks).fro_dist(&m4.forward(&toks));
        assert!(d < 1e-4, "parallel/sequential divergence {d}");
    }

    #[test]
    fn wanda_pipeline_runs() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            method: Method::Wanda,
            rate: 0.5,
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        assert!((m.achieved_compression() - 0.5).abs() < 0.05);
        assert!(report.mean_rel_error() > 0.0);
    }

    #[test]
    fn owl_rates_vary_but_preserve_mean() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            rate: 0.6,
            rank_ratio: 0.25,
            iters: 2,
            owl: true,
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        let rates = report.owl_rates.as_ref().unwrap();
        assert_eq!(rates.len(), model.blocks.len());
        let achieved = m.achieved_compression();
        assert!((achieved - 0.6).abs() < 0.07, "achieved {achieved} rates {rates:?}");
    }

    #[test]
    fn slice_pass_slices_ffn_pair_only() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            method: Method::Dense,
            slice_rate: Some(0.25),
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        let d_ff = model.cfg.d_ff;
        let keep = d_ff - d_ff / 4;
        for blk in &m.blocks {
            assert_eq!(blk.up.out_dim(), keep, "up output sliced");
            assert_eq!(blk.down.in_dim(), keep, "down input sliced");
            assert_eq!(blk.up.in_dim(), model.cfg.d_model);
            assert_eq!(blk.q.out_dim(), model.cfg.d_model, "attention untouched");
            assert!(matches!(
                blk.up,
                LinearOp::Compressed(CompressedLayer::SlicedDense { .. })
            ));
        }
        // Per-layer telemetry: sliced layers report nonzero rel_error and
        // an achieved rate against the ORIGINAL dense shape.
        for l in report.layers.iter().filter(|l| l.id.name == "up" || l.id.name == "down") {
            assert!(l.rel_error > 0.0, "{}: {}", l.id, l.rel_error);
            assert!((l.achieved_rate - 0.25).abs() < 1e-9, "{}: {}", l.id, l.achieved_rate);
        }
        // The sliced model still runs end to end.
        let logits = m.forward(&[vec![1usize, 2, 3, 4]]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rotation_only_slice_matches_dense_logits() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            method: Method::Dense,
            slice_rate: Some(0.0),
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        for blk in &m.blocks {
            assert_eq!(blk.up.out_dim(), model.cfg.d_ff, "rate 0 deletes nothing");
        }
        for l in report.layers.iter().filter(|l| l.id.name == "up" || l.id.name == "down") {
            assert_eq!(l.rel_error, 0.0, "{}: permutation is exact in weight space", l.id);
        }
        let toks = vec![vec![3usize, 1, 4, 1, 5, 9, 2, 6]];
        let d = m.forward(&toks).fro_dist(&model.forward(&toks));
        assert!(d < 1e-3, "rotation-only divergence {d}");
    }

    #[test]
    fn slice_gate_rejects_at_tight_bound() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            method: Method::Dense,
            slice_rate: Some(0.25),
            slice_max_rel_error: 1e-9,
            ..Default::default()
        };
        let (m, _) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        for blk in &m.blocks {
            assert_eq!(blk.up.out_dim(), model.cfg.d_ff, "gate must keep the dense pair");
            assert!(matches!(blk.up, LinearOp::Compressed(CompressedLayer::Dense(_))));
        }
    }

    #[test]
    fn slice_composes_with_oats_on_attention() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            rate: 0.5,
            rank_ratio: 0.25,
            iters: 2,
            slice_rate: Some(0.25),
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        for blk in &m.blocks {
            assert!(
                matches!(blk.up, LinearOp::Compressed(CompressedLayer::SlicedDense { .. })),
                "FFN pair goes sliced-dense"
            );
            assert!(
                matches!(blk.q, LinearOp::Compressed(CompressedLayer::Spl(_))),
                "attention stays OATS"
            );
        }
        assert_eq!(report.layers.len(), model.blocks.len() * 6);
        let logits = m.forward(&[vec![1usize, 2, 3, 4]]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn is_noop_accounts_for_slice() {
        let dense = CompressConfig { method: Method::Dense, ..Default::default() };
        assert!(is_noop(&dense));
        let sliced = CompressConfig {
            method: Method::Dense,
            slice_rate: Some(0.0),
            ..Default::default()
        };
        assert!(!is_noop(&sliced), "rotation-only still has work to do");
    }

    #[test]
    fn compression_error_grows_with_rate() {
        let (model, calib) = setup();
        let mut errs = Vec::new();
        for rate in [0.3, 0.6] {
            let cfg = CompressConfig { rate, rank_ratio: 0.25, iters: 2, ..Default::default() };
            let (_, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
            errs.push(report.mean_rel_error());
        }
        assert!(errs[0] < errs[1], "{errs:?}");
    }
}
