//! Compression pipeline orchestrator.
//!
//! Responsibilities (paper §3.1 experiment protocol):
//! 1. optional OWL pre-pass computing per-block compression rates;
//! 2. sequential block loop propagating calibration activations through
//!    already-compressed blocks (Algorithm 2);
//! 3. per-block parallel compression of the six linear layers (the paper
//!    notes per-block parallelism in §A.2);
//! 4. commit + telemetry (per-layer residuals, achieved rates, wall-clock).

use crate::calib::{BlockPropagator, CalibSet};
use crate::compress::{self, owl, CalibStats, CompressedLayer};
use crate::config::{CompressConfig, Method};
use crate::model::{LinearId, LinearOp, TransformerLM, LINEAR_NAMES};
use crate::util::trace;
use anyhow::Result;
use std::sync::mpsc;

/// Telemetry for one compressed layer.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub id: LinearId,
    pub target_rate: f64,
    pub achieved_rate: f64,
    /// ‖W − Ŵ‖_F / ‖W‖_F (unscaled reconstruction error).
    pub rel_error: f64,
    pub seconds: f64,
}

/// Full pipeline telemetry.
#[derive(Clone, Debug, Default)]
pub struct CompressionReport {
    pub layers: Vec<LayerReport>,
    pub total_seconds: f64,
    /// Per-block wall-clock (Table 9's measurement).
    pub block_seconds: Vec<f64>,
    pub owl_rates: Option<Vec<f64>>,
}

impl CompressionReport {
    pub fn mean_rel_error(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.rel_error).sum::<f64>() / self.layers.len() as f64
    }

    pub fn achieved_rate(&self) -> f64 {
        // parameter-weighted is what the model reports; this is the mean.
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.achieved_rate).sum::<f64>() / self.layers.len() as f64
    }
}

/// Compress every prunable layer of `model` in place.
///
/// `workers` controls the per-block fan-out (1 = sequential). The calibration
/// set must have been sampled with the same corpus/stream for every method
/// being compared (paper §3.1).
pub fn compress_model(
    model: &mut TransformerLM,
    calib: &CalibSet,
    cfg: &CompressConfig,
    workers: usize,
) -> Result<CompressionReport> {
    let mut report = CompressionReport::default();
    // Always-measuring spans double as the report's wall-clock source, so
    // the numbers in `CompressionReport` and an exported trace agree.
    let whole = trace::timed("compress_model");

    // ── OWL pre-pass: per-block rates from outlier fractions ──
    let n_blocks = model.blocks.len();
    let block_rates: Vec<f64> = if cfg.owl {
        let t_owl = trace::timed("owl_calibration");
        let mut prop = BlockPropagator::new(model, calib);
        let mut fracs = Vec::with_capacity(n_blocks);
        let mut params = Vec::with_capacity(n_blocks);
        for b in 0..n_blocks {
            let stats = prop.capture_stats();
            // Block outlier fraction: parameter-weighted mean over linears.
            let mut f = 0.0;
            let mut p_total = 0usize;
            for name in LINEAR_NAMES {
                let w = model.blocks[b].linear(name).dense_view();
                let pc = w.rows * w.cols;
                f += owl::outlier_fraction(&w, &stats[name], cfg.owl_m) * pc as f64;
                p_total += pc;
            }
            fracs.push(f / p_total as f64);
            params.push(p_total);
            prop.advance();
        }
        let rates = owl::layerwise_rates(&fracs, &params, cfg.rate, cfg.owl_lambda);
        report.owl_rates = Some(rates.clone());
        t_owl.finish();
        rates
    } else {
        vec![cfg.rate; n_blocks]
    };

    // ── main block loop (Algorithm 2) ──
    // BlockPropagator borrows the model immutably, so each iteration scopes
    // the borrow: capture stats → drop propagator → mutate → re-embed would
    // be O(L²). Instead we keep hidden states outside and call block_forward
    // directly.
    let mut hidden: Vec<crate::tensor::Matrix> =
        calib.batches.iter().map(|b| model.embed(&b.inputs)).collect();
    let batch_sizes: Vec<usize> = calib.batches.iter().map(|b| b.inputs.len()).collect();
    let s = calib.seq_len;

    for b in 0..n_blocks {
        let t_block = trace::timed("compress_block");
        // capture stats with current (compressed-so-far) activations
        let stats: std::collections::HashMap<&'static str, CalibStats> = {
            let mut map: std::collections::HashMap<&'static str, CalibStats> =
                std::collections::HashMap::new();
            for (h, &bsz) in hidden.iter().zip(&batch_sizes) {
                let mut cap = crate::model::ForwardCapture::default();
                let _ = model.block_forward(b, h, bsz, s, Some(&mut cap), None);
                for name in LINEAR_NAMES {
                    let x = &cap.inputs[name];
                    map.entry(name)
                        .or_insert_with(|| CalibStats::new(x.cols))
                        .update(x, 128);
                }
            }
            for st in map.values_mut() {
                st.finalize();
            }
            map
        };

        // compress the six linears (possibly in parallel)
        let layer_cfg = CompressConfig { rate: block_rates[b], ..cfg.clone() };
        let jobs: Vec<(&'static str, crate::tensor::Matrix, CalibStats)> = LINEAR_NAMES
            .iter()
            .map(|&name| (name, model.blocks[b].linear(name).dense_view(), stats[name].clone()))
            .collect();

        let results: Vec<(&'static str, Result<CompressedLayer>, f64)> = if workers > 1 {
            let (tx, rx) = mpsc::channel();
            std::thread::scope(|scope| {
                for (name, w, st) in &jobs {
                    let tx = tx.clone();
                    let lc = layer_cfg.clone();
                    scope.spawn(move || {
                        let t_layer = trace::timed("compress_layer");
                        let r = compress::compress_layer(w, st, &lc);
                        let _ = tx.send((*name, r, t_layer.finish()));
                    });
                }
            });
            drop(tx);
            rx.into_iter().collect()
        } else {
            jobs.iter()
                .map(|(name, w, st)| {
                    let t_layer = trace::timed("compress_layer");
                    let r = compress::compress_layer(w, st, &layer_cfg);
                    (*name, r, t_layer.finish())
                })
                .collect()
        };

        // commit + telemetry
        for (name, result, dt) in results {
            let compressed = result?;
            let id = LinearId { block: b, name };
            let w_orig = model.blocks[b].linear(name).dense_view();
            let w_new = compressed.to_dense();
            let mut diff = w_orig.clone();
            diff.axpy(-1.0, &w_new);
            let denom = w_orig.fro_norm().max(1e-12);
            report.layers.push(LayerReport {
                id,
                target_rate: block_rates[b],
                achieved_rate: compressed.compression_rate(),
                rel_error: diff.fro_norm() / denom,
                seconds: dt,
            });
            model.set_linear(id, LinearOp::Compressed(compressed));
        }

        // propagate through the now-compressed block
        for (h, &bsz) in hidden.iter_mut().zip(&batch_sizes) {
            *h = model.block_forward(b, h, bsz, s, None, None);
        }
        report.block_seconds.push(t_block.finish());
    }

    report.total_seconds = whole.finish();
    report.layers.sort_by_key(|l| (l.id.block, l.id.name));
    Ok(report)
}

/// Convenience: compress a fresh clone of the model, leaving the input
/// untouched (used by the sweep/table harnesses that compare methods).
pub fn compress_clone(
    model: &TransformerLM,
    calib: &CalibSet,
    cfg: &CompressConfig,
    workers: usize,
) -> Result<(TransformerLM, CompressionReport)> {
    let mut m = model.clone();
    let report = compress_model(&mut m, calib, cfg, workers)?;
    Ok((m, report))
}

/// Methods with no compression work (Dense) skip the pipeline entirely.
pub fn is_noop(cfg: &CompressConfig) -> bool {
    matches!(cfg.method, Method::Dense) || cfg.rate <= 0.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{CorpusConfig, SyntheticCorpus};

    fn setup() -> (TransformerLM, CalibSet) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let model = TransformerLM::init(&cfg, 17);
        let corpus = SyntheticCorpus::new(CorpusConfig::for_vocab(cfg.vocab, 4));
        let calib = CalibSet::sample(&corpus, 8, 16, 4);
        (model, calib)
    }

    #[test]
    fn oats_pipeline_compresses_all_layers() {
        let (model, calib) = setup();
        let cfg = CompressConfig { rate: 0.5, rank_ratio: 0.25, iters: 3, ..Default::default() };
        let (m, report) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        assert_eq!(report.layers.len(), model.blocks.len() * 6);
        let achieved = m.achieved_compression();
        assert!((achieved - 0.5).abs() < 0.05, "achieved {achieved}");
        assert_eq!(report.block_seconds.len(), model.blocks.len());
    }

    #[test]
    fn parallel_matches_sequential() {
        let (model, calib) = setup();
        let cfg = CompressConfig { rate: 0.4, rank_ratio: 0.2, iters: 2, ..Default::default() };
        let (m1, _) = compress_clone(&model, &calib, &cfg, 1).unwrap();
        let (m4, _) = compress_clone(&model, &calib, &cfg, 4).unwrap();
        let toks = vec![vec![1usize, 2, 3, 4, 5, 6, 7, 8]];
        let d = m1.forward(&toks).fro_dist(&m4.forward(&toks));
        assert!(d < 1e-4, "parallel/sequential divergence {d}");
    }

    #[test]
    fn wanda_pipeline_runs() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            method: Method::Wanda,
            rate: 0.5,
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        assert!((m.achieved_compression() - 0.5).abs() < 0.05);
        assert!(report.mean_rel_error() > 0.0);
    }

    #[test]
    fn owl_rates_vary_but_preserve_mean() {
        let (model, calib) = setup();
        let cfg = CompressConfig {
            rate: 0.6,
            rank_ratio: 0.25,
            iters: 2,
            owl: true,
            ..Default::default()
        };
        let (m, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
        let rates = report.owl_rates.as_ref().unwrap();
        assert_eq!(rates.len(), model.blocks.len());
        let achieved = m.achieved_compression();
        assert!((achieved - 0.6).abs() < 0.07, "achieved {achieved} rates {rates:?}");
    }

    #[test]
    fn compression_error_grows_with_rate() {
        let (model, calib) = setup();
        let mut errs = Vec::new();
        for rate in [0.3, 0.6] {
            let cfg = CompressConfig { rate, rank_ratio: 0.25, iters: 2, ..Default::default() };
            let (_, report) = compress_clone(&model, &calib, &cfg, 2).unwrap();
            errs.push(report.mean_rel_error());
        }
        assert!(errs[0] < errs[1], "{errs:?}");
    }
}
