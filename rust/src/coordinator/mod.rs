//! The L3 coordinator — the system half of the reproduction.
//!
//! * [`pipeline`] — the compression-job orchestrator: runs the calibration
//!   propagation (Algorithm 2), fans the six linears of each block out to a
//!   worker pool, applies OWL per-layer rates, and commits results back into
//!   the model.
//! * [`engine`] — the continuous-batching decode engine: a paged KV arena
//!   (fixed pages behind a free list, per-sequence page tables,
//!   reservation-gated admission), per-step admission with chunked
//!   prefill, lockstep decode over resident sequences, and same-step slot
//!   backfill.
//! * [`serve`] — the serving layer on top of it: request channel,
//!   admission queue, per-token streaming, latency/occupancy telemetry.

pub mod engine;
pub mod pipeline;
pub mod serve;

pub use engine::{AdmissionPolicy, Engine, EngineConfig, EngineTelemetry};
pub use pipeline::{compress_model, CompressionReport, LayerReport};
pub use serve::{ServeConfig, ServeStats, Server};
