//! The L3 coordinator — the system half of the reproduction.
//!
//! * [`pipeline`] — the compression-job orchestrator: runs the calibration
//!   propagation (Algorithm 2), fans the six linears of each block out to a
//!   worker pool, applies OWL per-layer rates, and commits results back into
//!   the model.
//! * [`serve`] — the compressed-inference serving engine: request queue,
//!   dynamic batcher, KV-cached decode loop, per-request latency metrics.

pub mod pipeline;
pub mod serve;

pub use pipeline::{compress_model, CompressionReport, LayerReport};
pub use serve::{ServeConfig, ServeStats, Server};
