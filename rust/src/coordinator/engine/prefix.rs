//! Prefix index: shared-prefix KV page lookup at page granularity.
//!
//! Requests that open with an identical token prefix (system prompts,
//! few-shot headers) produce identical KV rows for those positions — the
//! engine's bit-identity contract guarantees it. The index maps
//! *page-aligned* token prefixes to the shared KV page holding that page's
//! rows, so admission can attach already-computed pages into a joiner's
//! page table instead of re-prefilling them.
//!
//! Keys are a cumulative FNV-1a hash of the token prefix up to each page
//! boundary; every entry also stores the full prefix tokens and lookups
//! verify token equality, so a hash collision can never alias two distinct
//! prefixes into the same KV rows (a collision merely prevents the later
//! prefix from being published). Entries hold an `Arc<KvPage>`; the
//! [`KvPool`](super::KvPool) bills shared pages pool-wide and reclaims one
//! only when the index drops the final strong reference.
//!
//! Because the index holds its *own* strong reference to every published
//! page, a donor's published pages survive the donor's release — including
//! a preemption eviction. `KvPool::release` only returns the slot's owned
//! pages; shared pages stay alive under the index's `Arc`, so a readmitted
//! victim (or any other joiner) can re-attach the very pages the victim
//! published before it was evicted.

use crate::model::KvPage;
use crate::util::trace;
use std::collections::BTreeMap;
use std::sync::Arc;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Cumulative FNV-1a over a token slice — the index key for the prefix
/// ending at `tokens.len()`.
fn fnv1a(tokens: &[usize]) -> u64 {
    let mut h = FNV_OFFSET;
    for &t in tokens {
        h = (h ^ t as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

struct PrefixEntry {
    /// Full token prefix this page completes (length is a multiple of the
    /// page size) — checked on lookup so collisions cannot alias.
    prefix: Vec<usize>,
    page: Arc<KvPage>,
    /// Logical-clock stamp of the last publish or successful match —
    /// the LRU tier for capacity eviction.
    last_use: u64,
}

/// Page-granular map from token prefixes to shared KV pages.
///
/// A `BTreeMap` keyed on the prefix hash keeps iteration order
/// deterministic, so eviction under memory pressure picks the same victim
/// on every run — load-independent behaviour is part of the engine's
/// bit-identity story. The same contract shapes the capacity policy: the
/// LRU tier runs on a logical clock bumped per index operation, never wall
/// time.
pub struct PrefixIndex {
    page_size: usize,
    /// Maximum resident entries (0 = unbounded). Enforced best-effort at
    /// insert time: only entries no live sequence maps can be reclaimed,
    /// so the index may transiently exceed the cap under heavy sharing.
    cap: usize,
    /// Deterministic LRU clock (monotone, bumped on publish and match).
    clock: u64,
    entries: BTreeMap<u64, PrefixEntry>,
}

impl PrefixIndex {
    pub fn new(page_size: usize) -> PrefixIndex {
        Self::with_cap(page_size, 0)
    }

    /// An index bounded to `cap` entries (0 = unbounded) — long-running
    /// many-tenant loads keep publishing fresh prefixes, and without a cap
    /// the index (and the pool's shared-page bill) grows monotonically.
    pub fn with_cap(page_size: usize, cap: usize) -> PrefixIndex {
        assert!(page_size > 0, "prefix index needs a positive page size");
        PrefixIndex { page_size, cap, clock: 0, entries: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest run of leading pages of `prompt` already present in the
    /// index. Walks page boundaries left to right and stops at the first
    /// miss; returns one `Arc` per matched page, in position order.
    ///
    /// Index keys of the longest run of leading pages of `prompt` present
    /// in the index (token-verified, stops at the first miss).
    fn matched_keys(&self, prompt: &[usize]) -> Vec<u64> {
        let ps = self.page_size;
        let mut keys = Vec::new();
        let mut h = FNV_OFFSET;
        let mut pos = 0;
        while pos + ps <= prompt.len() {
            for &t in &prompt[pos..pos + ps] {
                h = (h ^ t as u64).wrapping_mul(FNV_PRIME);
            }
            pos += ps;
            match self.entries.get(&h) {
                Some(e) if e.prefix == prompt[..pos] => keys.push(h),
                _ => break,
            }
        }
        keys
    }

    /// Only *fully filled* prompt-covered pages are candidates: boundary
    /// `b` is probed only while `b <= prompt.len()`, so a partial last
    /// page is never matched (its rows would differ beyond the prompt).
    ///
    /// Read-only: admission predicates probe with this (possibly many
    /// times per step) without disturbing LRU recency. The commitment
    /// path uses [`PrefixIndex::match_and_touch`].
    pub fn match_prefix(&self, prompt: &[usize]) -> Vec<Arc<KvPage>> {
        self.matched_keys(prompt).iter().map(|k| Arc::clone(&self.entries[k].page)).collect()
    }

    /// [`PrefixIndex::match_prefix`], plus an LRU-stamp refresh on every
    /// matched entry — a prefix a joiner actually maps is exactly the one
    /// the capacity policy must keep resident.
    pub fn match_and_touch(&mut self, prompt: &[usize]) -> Vec<Arc<KvPage>> {
        let keys = self.matched_keys(prompt);
        let mut pages = Vec::with_capacity(keys.len());
        for k in keys {
            self.clock += 1;
            let e = self.entries.get_mut(&k).expect("matched key present");
            e.last_use = self.clock;
            pages.push(Arc::clone(&e.page));
        }
        pages
    }

    /// True when the key for this exact prefix length is occupied at all —
    /// even by a colliding different prefix. Publishing checks this before
    /// [`PrefixIndex::insert`]: an overwrite would silently drop the
    /// displaced entry's `Arc` and strand its page in the pool's
    /// shared-page bill, so occupied keys are simply left alone.
    pub fn contains(&self, prefix: &[usize]) -> bool {
        debug_assert!(prefix.len() % self.page_size == 0);
        self.entries.contains_key(&fnv1a(prefix))
    }

    /// Publish the page completing `prefix`. The key must be vacant
    /// (callers gate on [`PrefixIndex::contains`]) and the prefix must be
    /// page-aligned. Returns the pages LRU-evicted to honor the capacity
    /// cap — the caller must hand them back to the pool.
    pub fn insert(&mut self, prefix: &[usize], page: Arc<KvPage>) -> Vec<Arc<KvPage>> {
        assert!(
            prefix.len() % self.page_size == 0 && !prefix.is_empty(),
            "published prefixes must cover whole pages"
        );
        let key = fnv1a(prefix);
        trace::instant_args("prefix_publish", &[("prefix_len", prefix.len() as f64)]);
        self.clock += 1;
        let prev = self.entries.insert(
            key,
            PrefixEntry { prefix: prefix.to_vec(), page, last_use: self.clock },
        );
        assert!(prev.is_none(), "prefix index insert over an occupied key");
        self.enforce_cap()
    }

    /// LRU-tier capacity eviction: drop least-recently-used unreferenced
    /// entries until the index fits `cap`. Ties on the stamp break by key,
    /// so the victim sequence is identical on every run. Entries a live
    /// sequence still maps are never touched — their pages cannot be
    /// reclaimed — so under heavy sharing the cap is exceeded rather than
    /// violated-by-aliasing.
    fn enforce_cap(&mut self) -> Vec<Arc<KvPage>> {
        let mut evicted = Vec::new();
        if self.cap == 0 {
            return evicted;
        }
        while self.entries.len() > self.cap {
            let key = self
                .entries
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
                .min_by_key(|(&k, e)| (e.last_use, k))
                .map(|(&k, _)| k);
            let Some(key) = key else { break };
            let entry = self.entries.remove(&key).unwrap();
            trace::instant_args("prefix_evict", &[("prefix_len", entry.prefix.len() as f64)]);
            evicted.push(entry.page);
        }
        evicted
    }

    /// Evict one entry that no live sequence maps (`strong_count == 1`,
    /// i.e. the index holds the only reference), preferring the *longest*
    /// prefix so the trie is pruned leaf-first and shorter, more reusable
    /// prefixes survive. Returns the reclaimed `Arc` for the pool, or
    /// `None` when every entry is still mapped.
    pub fn evict_unreferenced(&mut self) -> Option<Arc<KvPage>> {
        let key = self
            .entries
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.page) == 1)
            .max_by_key(|(&k, e)| (e.prefix.len(), k))
            .map(|(&k, _)| k)?;
        let entry = self.entries.remove(&key).unwrap();
        trace::instant_args("prefix_evict", &[("prefix_len", entry.prefix.len() as f64)]);
        Some(entry.page)
    }

    /// Drop every entry, returning the pages for reclamation. Called at
    /// drain (no residents, empty queue) so the engine's zero-pages-held
    /// invariant stays exact between workloads.
    pub fn drain_pages(&mut self) -> Vec<Arc<KvPage>> {
        let entries = std::mem::take(&mut self.entries);
        if !entries.is_empty() {
            trace::instant_args("prefix_drain", &[("pages", entries.len() as f64)]);
        }
        entries.into_values().map(|e| e.page).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn page(ps: usize, tag: f32) -> Arc<KvPage> {
        // Geometry is irrelevant to the index; a tiny distinguishable page
        // is enough to check identity plumbing.
        let cfg = ModelConfig {
            name: "prefix-test".into(),
            vocab: 4,
            d_model: 2,
            n_heads: 1,
            n_layers: 1,
            d_ff: 4,
            seq_len: ps,
        };
        let mut p = KvPage::new(&cfg, ps);
        p.k[0].data[0] = tag;
        Arc::new(p)
    }

    fn tag_of(p: &KvPage) -> f32 {
        p.k[0].data[0]
    }

    #[test]
    fn match_walks_leading_pages_and_stops_at_first_miss() {
        let ps = 4;
        let mut idx = PrefixIndex::new(ps);
        let prompt: Vec<usize> = (0..12).collect();
        idx.insert(&prompt[..4], page(ps, 1.0));
        idx.insert(&prompt[..8], page(ps, 2.0));
        // Third page unpublished: match stops after two.
        let m = idx.match_prefix(&prompt);
        assert_eq!(m.len(), 2);
        assert_eq!(tag_of(&m[0]), 1.0);
        assert_eq!(tag_of(&m[1]), 2.0);

        // A prompt diverging inside page two matches only page one.
        let mut div = prompt.clone();
        div[5] = 99;
        assert_eq!(idx.match_prefix(&div).len(), 1);

        // Shorter than one page: nothing to match.
        assert!(idx.match_prefix(&prompt[..3]).is_empty());
        // Exactly one page: partial-page rule is about the *prompt* end —
        // a 5-token prompt only ever matches its first page.
        assert_eq!(idx.match_prefix(&prompt[..5]).len(), 1);
    }

    #[test]
    fn lookup_verifies_tokens_so_collisions_cannot_alias() {
        let ps = 2;
        let mut idx = PrefixIndex::new(ps);
        let a = [1usize, 2];
        idx.insert(&a, page(ps, 1.0));
        // Forge a colliding entry by inserting under a's hash via the map
        // directly is not possible from outside; instead simulate the
        // defensive path: a prompt with different tokens but (hypothetically)
        // the same hash must not match. We can't construct a real FNV
        // collision cheaply, so assert the equality check exists by way of
        // `contains` vs `match_prefix` semantics: contains() is key-based,
        // match is token-based.
        assert!(idx.contains(&a));
        let b = [3usize, 4];
        assert!(idx.match_prefix(&b).is_empty());
    }

    #[test]
    fn eviction_prunes_longest_unreferenced_first_and_skips_mapped() {
        let ps = 2;
        let mut idx = PrefixIndex::new(ps);
        let prompt: Vec<usize> = (10..16).collect();
        idx.insert(&prompt[..2], page(ps, 1.0));
        idx.insert(&prompt[..4], page(ps, 2.0));
        idx.insert(&prompt[..6], page(ps, 3.0));

        // Hold a reference to the longest entry, as a mapped joiner would.
        let held = idx.match_prefix(&prompt);
        assert_eq!(held.len(), 3);
        // Everything is mapped: nothing evictable.
        assert!(idx.evict_unreferenced().is_none());
        drop(held);

        // Now leaf-first: 6-token prefix goes before 4 before 2.
        assert_eq!(tag_of(&idx.evict_unreferenced().unwrap()), 3.0);
        assert_eq!(tag_of(&idx.evict_unreferenced().unwrap()), 2.0);
        assert_eq!(tag_of(&idx.evict_unreferenced().unwrap()), 1.0);
        assert!(idx.evict_unreferenced().is_none());
        assert!(idx.is_empty());
    }

    #[test]
    fn drain_returns_every_page() {
        let ps = 3;
        let mut idx = PrefixIndex::new(ps);
        idx.insert(&[1, 2, 3], page(ps, 1.0));
        idx.insert(&[1, 2, 3, 4, 5, 6], page(ps, 2.0));
        let pages = idx.drain_pages();
        assert_eq!(pages.len(), 2);
        assert!(idx.is_empty());
        assert!(pages.iter().all(|p| Arc::strong_count(p) == 1));
    }

    #[test]
    fn cap_evicts_least_recently_used_unreferenced_entry() {
        let ps = 2;
        let mut idx = PrefixIndex::with_cap(ps, 2);
        idx.insert(&[1, 2], page(ps, 1.0));
        idx.insert(&[3, 4], page(ps, 2.0));
        // A mapped match refreshes the older entry's LRU stamp (the pages
        // drop at the end of the statement, so nothing stays referenced)...
        assert_eq!(idx.match_and_touch(&[1, 2]).len(), 1);
        // ...so the overflow victim is the *untouched* entry even though
        // it was published later.
        let evicted = idx.insert(&[5, 6], page(ps, 3.0));
        assert_eq!(evicted.len(), 1);
        assert_eq!(tag_of(&evicted[0]), 2.0);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.match_prefix(&[1, 2]).len(), 1);
        assert!(idx.match_prefix(&[3, 4]).is_empty());
        assert_eq!(idx.match_prefix(&[5, 6]).len(), 1);
    }

    #[test]
    fn read_only_match_does_not_disturb_lru_order() {
        let ps = 2;
        let mut idx = PrefixIndex::with_cap(ps, 2);
        idx.insert(&[1, 2], page(ps, 1.0));
        idx.insert(&[3, 4], page(ps, 2.0));
        // A reservation *probe* must not refresh recency: the oldest
        // publish stays the victim.
        assert_eq!(idx.match_prefix(&[1, 2]).len(), 1);
        let evicted = idx.insert(&[5, 6], page(ps, 3.0));
        assert_eq!(evicted.len(), 1);
        assert_eq!(tag_of(&evicted[0]), 1.0);
    }

    #[test]
    fn cap_never_evicts_entries_live_sequences_still_map() {
        let ps = 2;
        let mut idx = PrefixIndex::with_cap(ps, 1);
        idx.insert(&[1, 2], page(ps, 1.0));
        let held = idx.match_and_touch(&[1, 2]); // mapped by a joiner
        // The mapped entry cannot go, so the strict-LRU victim is the
        // newcomer itself — the cap holds without aliasing a live page.
        let evicted = idx.insert(&[3, 4], page(ps, 2.0));
        assert_eq!(evicted.len(), 1);
        assert_eq!(tag_of(&evicted[0]), 2.0);
        assert_eq!(idx.len(), 1);
        drop(held);
        // Unmapped now: the stale resident finally goes.
        let evicted = idx.insert(&[5, 6], page(ps, 3.0));
        assert_eq!(evicted.len(), 1);
        assert_eq!(tag_of(&evicted[0]), 1.0);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.match_prefix(&[5, 6]).len(), 1);
    }

    #[test]
    fn cap_exceeds_rather_than_evicting_when_everything_is_mapped() {
        let ps = 2;
        let mut idx = PrefixIndex::with_cap(ps, 1);
        let p1 = page(ps, 1.0);
        let p2 = page(ps, 2.0);
        idx.insert(&[1, 2], Arc::clone(&p1));
        let evicted = idx.insert(&[3, 4], Arc::clone(&p2));
        assert!(evicted.is_empty(), "both pages are mapped — nothing reclaimable");
        assert_eq!(idx.len(), 2, "cap is exceeded, never aliased");
    }

    #[test]
    fn published_pages_survive_donor_eviction() {
        // Preemption releases the donor's slot, but the index's own Arc
        // keeps every page it published alive and matchable — a readmitted
        // victim re-attaches the prefix it computed before the eviction.
        let ps = 2;
        let mut idx = PrefixIndex::new(ps);
        let prompt = [1usize, 2, 3, 4];
        let donor_view = {
            // Scope the donor's mapping the way `KvPool::release` ends it:
            // the donor publishes, then its references drop.
            let p1 = page(ps, 1.0);
            let p2 = page(ps, 2.0);
            idx.insert(&prompt[..2], Arc::clone(&p1));
            idx.insert(&prompt, Arc::clone(&p2));
            vec![p1, p2]
        };
        drop(donor_view); // the eviction: donor's page table is torn down
        let m = idx.match_and_touch(&prompt);
        assert_eq!(m.len(), 2, "published pages outlive the donor");
        assert_eq!(tag_of(&m[0]), 1.0);
        assert_eq!(tag_of(&m[1]), 2.0);
        assert!(
            m.iter().all(|p| Arc::strong_count(p) == 2),
            "index + readmitted mapping are the only references"
        );
    }

    #[test]
    #[should_panic(expected = "occupied key")]
    fn insert_over_occupied_key_panics() {
        let ps = 2;
        let mut idx = PrefixIndex::new(ps);
        idx.insert(&[7, 8], page(ps, 1.0));
        idx.insert(&[7, 8], page(ps, 2.0));
    }
}
