//! The paged KV arena: a fixed pool of preallocated [`KvPage`]s plus
//! per-slot [`KvCache`] shells (page tables).
//!
//! Every page and every shell is allocated once at engine startup, so
//! sequence join/leave and mid-flight growth never allocate or free KV
//! buffers on the hot path, and KV memory is bounded by configuration
//! (`pages × n_layers × 2 × page_size × d_model × 4 B`) rather than by
//! offered load. A joining sequence takes a slot plus a **worst-case page
//! reservation** (`ceil(min(len + gen − 1, seq_len) / page_size)` — the
//! final sampled token is never written back, so `len + gen − 1` is the
//! most KV positions a sequence can touch); pages are attached on demand
//! as the sequence grows and all returned to the free list at retirement.
//!
//! The reservation is what makes mid-flight growth deadlock-free:
//! admission only succeeds while `Σ reservations ≤ total pages − shared`,
//! and a resident sequence never *owns* more pages than it reserved, so
//! `free pages = total − shared − Σ owned ≥ Σ reserved − Σ owned ≥
//! reserved_i − owned_i ≥ 1` whenever sequence *i* needs its next page —
//! an acquired slot can always run to retirement without waiting on
//! another sequence.
//!
//! **Shared prefix pages.** A filled prefix page can be converted from
//! owned to *shared* ([`KvPool::share_page`]): the page leaves its
//! sequence's ownership (and reservation — both sides of the invariant
//! shrink by one, keeping it intact) and becomes a refcounted [`Arc`]
//! held by the prefix index and mapped read-only into any number of
//! joiners ([`KvPool::attach_shared`], no reservation cost — the page is
//! already paid for pool-wide via `shared_alive`). A joiner that must
//! write inside a shared page forks it first ([`KvPool::fork_page`]):
//! one page off the free list, covered by the joiner's own reservation,
//! carrying a copy of the shared rows. Shared pages return to the free
//! list only through [`KvPool::reclaim_shared`] once the index holds the
//! last reference. Conservation therefore reads
//! `free + Σ owned + shared_alive == total`.
//!
//! Slots hand out plain `usize` indices; the pool tracks which are in use
//! and panics on double-release, on touching a slot that was never
//! acquired, or on a sequence outgrowing its reservation — the engine's
//! bookkeeping is an invariant, not a recoverable condition.
//!
//! The whole-cache arena of PR 3 is the degenerate configuration
//! `page_size == seq_len, pages == slots` ([`KvPool::new`]): every
//! reservation is exactly one page, so admission reduces to slot
//! availability and each resident cache is one contiguous buffer.

use crate::config::ModelConfig;
use crate::model::{KvCache, KvPage};
use crate::util::trace;
use std::sync::Arc;

/// Fixed-size paged arena of reusable KV storage.
pub struct KvPool {
    caches: Vec<KvCache>,
    in_use: Vec<bool>,
    free: Vec<usize>,
    free_pages: Vec<KvPage>,
    total_pages: usize,
    page_size: usize,
    page_bytes: usize,
    reserved: Vec<usize>,
    reserved_total: usize,
    /// Pages converted to shared prefix views: off the free list, owned by
    /// no slot, alive until [`KvPool::reclaim_shared`].
    shared_alive: usize,
}

impl KvPool {
    /// Whole-cache degenerate arena: `slots` slots, one `seq_len`-sized
    /// page per slot. Byte-for-byte the PR 3 behavior.
    pub fn new(cfg: &ModelConfig, slots: usize) -> KvPool {
        KvPool::with_pages(cfg, slots, cfg.seq_len, slots)
    }

    /// Paged arena: `slots` sequence shells over a shared free list of
    /// `pages` pages of `page_size` positions each. All allocation happens
    /// here; acquire/release only move indices and page buffers.
    pub fn with_pages(cfg: &ModelConfig, slots: usize, page_size: usize, pages: usize) -> KvPool {
        assert!(slots > 0, "KV pool needs at least one slot");
        let page_size = page_size.clamp(1, cfg.seq_len);
        let per_seq = cfg.seq_len.div_ceil(page_size);
        assert!(
            pages >= per_seq,
            "KV pool needs at least {per_seq} pages of {page_size} (one full sequence)"
        );
        let free_pages: Vec<KvPage> = (0..pages).map(|_| KvPage::new(cfg, page_size)).collect();
        let page_bytes = free_pages[0].memory_bytes();
        KvPool {
            caches: (0..slots).map(|_| KvCache::paged(cfg, page_size)).collect(),
            in_use: vec![false; slots],
            free: (0..slots).rev().collect(),
            free_pages,
            total_pages: pages,
            page_size,
            page_bytes,
            reserved: vec![0; slots],
            reserved_total: 0,
            shared_alive: 0,
        }
    }

    /// Total slot count (the configured bound on resident sequences).
    pub fn slots(&self) -> usize {
        self.caches.len()
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by sequences.
    pub fn occupied(&self) -> usize {
        self.caches.len() - self.free.len()
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the arena (the configured bound on KV positions).
    pub fn pages_total(&self) -> usize {
        self.total_pages
    }

    /// Pages on the free list.
    pub fn pages_free(&self) -> usize {
        self.free_pages.len()
    }

    /// Pages off the free list: owned by resident sequences or alive as
    /// shared prefix views.
    pub fn pages_held(&self) -> usize {
        self.total_pages - self.free_pages.len()
    }

    /// Pages currently alive as shared prefix views.
    pub fn pages_shared(&self) -> usize {
        self.shared_alive
    }

    /// Pages promised to resident sequences (owned + not yet attached).
    pub fn pages_reserved(&self) -> usize {
        self.reserved_total
    }

    /// Pages a sequence spanning `positions` KV positions needs.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.page_size)
    }

    /// Whether a joiner reserving `need` owned pages can be admitted now:
    /// a free slot plus unreserved headroom among the non-shared pages.
    pub fn can_admit(&self, need: usize) -> bool {
        !self.free.is_empty()
            && self.total_pages - self.shared_alive - self.reserved_total >= need
    }

    /// Resident KV memory of the whole arena in bytes (constant for the
    /// pool's lifetime — this is the "bounded by config" number). Shared
    /// pages are billed here exactly once, however many sequences map them.
    pub fn memory_bytes(&self) -> usize {
        self.caches.iter().map(KvCache::memory_bytes).sum::<usize>()
            + self.free_pages.iter().map(KvPage::memory_bytes).sum::<usize>()
            + self.shared_alive * self.page_bytes
    }

    /// Take a free slot and reserve `reserve_pages` pages for its whole
    /// lifetime, or `None` when no slot is free or the unreserved page
    /// headroom is too small. The returned shell is empty (`len == 0`, no
    /// pages) and ready for [`KvPool::acquire_page`] + prefill.
    pub fn acquire(&mut self, reserve_pages: usize) -> Option<usize> {
        assert!(
            (1..=self.total_pages).contains(&reserve_pages),
            "reservation of {reserve_pages} pages outside 1..={}",
            self.total_pages
        );
        if self.total_pages - self.shared_alive - self.reserved_total < reserve_pages {
            return None;
        }
        let idx = self.free.pop()?;
        debug_assert!(!self.in_use[idx], "free list handed out an in-use slot");
        debug_assert_eq!(self.caches[idx].len, 0, "released slot was not reset");
        debug_assert_eq!(self.caches[idx].pages_held(), 0, "released slot kept pages");
        self.in_use[idx] = true;
        self.reserved[idx] = reserve_pages;
        self.reserved_total += reserve_pages;
        trace::instant_args(
            "kv_slot_acquire",
            &[("slot", idx as f64), ("reserved", reserve_pages as f64)],
        );
        Some(idx)
    }

    /// Attach the next page to an acquired slot, from the free list.
    /// Panics if the slot would exceed its reservation (an engine
    /// admission bug) — the free list can never be empty below that bound
    /// (see the module docs for the invariant).
    pub fn acquire_page(&mut self, idx: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        assert!(
            self.caches[idx].owned_pages_held() < self.reserved[idx],
            "KV slot {idx} exceeding its reservation of {} pages",
            self.reserved[idx]
        );
        let page = self.free_pages.pop().expect("free pages despite reservation headroom");
        self.caches[idx].push_page(page);
        trace::instant_args("kv_page_acquire", &[("slot", idx as f64)]);
    }

    /// Map an existing shared prefix page into slot `idx`'s page table
    /// (read-only). Costs no reservation and touches no free list — the
    /// page is already accounted for in `shared_alive`.
    pub fn attach_shared(&mut self, idx: usize, page: Arc<KvPage>) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        self.caches[idx].push_shared(page);
        trace::instant_args("kv_shared_attach", &[("slot", idx as f64)]);
    }

    /// Convert slot `idx`'s owned page `page_idx` into a shared prefix
    /// view and return the refcounted handle (for the prefix index). The
    /// page leaves the slot's ownership *and* its reservation: both sides
    /// of `Σ reserved ≤ total − shared` drop by one, so the deadlock-
    /// freedom invariant is preserved, and the slot's remaining pulls
    /// (`reserved − owned`) are unchanged.
    pub fn share_page(&mut self, idx: usize, page_idx: usize) -> Arc<KvPage> {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        assert!(
            !self.caches[idx].page_is_shared(page_idx),
            "KV slot {idx} page {page_idx} is already shared"
        );
        let arc = self.caches[idx].share_page(page_idx);
        self.shared_alive += 1;
        self.reserved[idx] -= 1;
        self.reserved_total -= 1;
        trace::instant_args("kv_page_share", &[("slot", idx as f64), ("page", page_idx as f64)]);
        arc
    }

    /// Copy-on-write: fork slot `idx`'s shared page `page_idx` into a
    /// fresh owned page off the free list (covered by the slot's own
    /// reservation), copying the shared rows. The shared original is
    /// unaffected; this slot's reference to it is dropped.
    pub fn fork_page(&mut self, idx: usize, page_idx: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        assert!(
            self.caches[idx].owned_pages_held() < self.reserved[idx],
            "KV slot {idx} forking past its reservation of {} pages",
            self.reserved[idx]
        );
        let fresh = self.free_pages.pop().expect("free pages despite reservation headroom");
        self.caches[idx].fork_page(page_idx, fresh);
        trace::instant_args("kv_cow_fork", &[("slot", idx as f64), ("page", page_idx as f64)]);
    }

    /// Return a shared page to the free list. The caller (the prefix
    /// index) must hold the last reference — reclaiming a page some
    /// sequence still maps would corrupt its history, so that is a panic,
    /// not a recoverable condition.
    pub fn reclaim_shared(&mut self, page: Arc<KvPage>) {
        let page = Arc::try_unwrap(page)
            .unwrap_or_else(|_| panic!("reclaiming a shared KV page that is still mapped"));
        self.shared_alive -= 1;
        self.free_pages.push(page);
        trace::instant("kv_shared_reclaim");
    }

    /// Fast-forward slot `idx`'s cache to `len` positions — the prefix-
    /// reuse admission step after attaching shared pages, whose KV rows
    /// already hold the prefix (re-prefilling them is the work being
    /// skipped). Every skipped position must have a backing page.
    pub fn resume_at(&mut self, idx: usize, len: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        assert!(
            len <= self.caches[idx].pages_held() * self.page_size,
            "KV slot {idx} resuming at {len} beyond its attached pages"
        );
        self.caches[idx].len = len;
    }

    /// Attach a page to `idx` iff its next written position has no backing
    /// page yet — the engine's acquire-on-demand step before each
    /// prefill/decode batch.
    pub fn ensure_page(&mut self, idx: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        if self.caches[idx].needs_page() {
            self.acquire_page(idx);
        }
    }

    /// Return a slot to the arena: every attached page goes back to the
    /// free list, the reservation is dropped, and the shell resets for the
    /// next sequence. Panics on double release.
    pub fn release(&mut self, idx: usize) {
        assert!(self.in_use[idx], "double release of KV slot {idx}");
        self.free_pages.extend(self.caches[idx].take_pages());
        self.reserved_total -= self.reserved[idx];
        self.reserved[idx] = 0;
        self.in_use[idx] = false;
        self.free.push(idx);
        trace::instant_args("kv_slot_release", &[("slot", idx as f64)]);
    }

    /// Debug-build conservation audit over the whole arena, asserting the
    /// module-doc invariants directly on the live state:
    ///
    /// * `free + Σ owned + shared_alive == total` — no page is ever lost
    ///   or double-tracked across acquire/share/fork/release churn;
    /// * `owned_i ≤ reserved_i` per in-use slot (a slot never outgrows its
    ///   admission-time reservation — the deadlock-freedom premise);
    /// * free slots hold no reservation and no pages;
    /// * `Σ reserved == reserved_total` and
    ///   `reserved_total + shared_alive ≤ total` (admission headroom
    ///   bookkeeping is exact).
    ///
    /// The invariants are deliberately phrased over the live state, so
    /// they also pin the **preemption lifecycle** (evict → requeue →
    /// readmit): an evicted victim's release must return every owned page
    /// to the free list and drop its reservation ledger entry in the same
    /// call, while pages it *published* stay accounted under
    /// `shared_alive` (the prefix index owns them now) — any eviction
    /// path that strands a page between those ledgers fails the
    /// conservation sum on the very step it happens.
    ///
    /// The engine calls this once per step and at drain, so every debug
    /// test run checks pool conservation continuously instead of only in
    /// the dedicated property tests. Compiled out of release builds.
    #[cfg(debug_assertions)]
    pub fn audit(&self) {
        let mut owned = 0;
        let mut reserved_sum = 0;
        for (i, cache) in self.caches.iter().enumerate() {
            if self.in_use[i] {
                let held = cache.owned_pages_held();
                assert!(
                    held <= self.reserved[i],
                    "audit: slot {i} owns {held} pages past its reservation of {}",
                    self.reserved[i]
                );
                owned += held;
                reserved_sum += self.reserved[i];
            } else {
                assert_eq!(self.reserved[i], 0, "audit: free slot {i} holds a reservation");
                assert_eq!(cache.pages_held(), 0, "audit: free slot {i} holds pages");
                assert_eq!(cache.len, 0, "audit: free slot {i} was not reset");
            }
        }
        assert_eq!(
            self.free_pages.len() + owned + self.shared_alive,
            self.total_pages,
            "audit: page conservation broken (free {} + owned {owned} + shared {} != total {})",
            self.free_pages.len(),
            self.shared_alive,
            self.total_pages
        );
        assert_eq!(
            reserved_sum,
            self.reserved_total,
            "audit: reservation ledger out of sync with per-slot reservations"
        );
        assert!(
            self.reserved_total + self.shared_alive <= self.total_pages,
            "audit: reservations {} + shared {} overcommit the {} total pages",
            self.reserved_total,
            self.shared_alive,
            self.total_pages
        );
        assert_eq!(
            self.free.len() + self.in_use.iter().filter(|&&u| u).count(),
            self.caches.len(),
            "audit: slot free list out of sync"
        );
    }

    /// Borrow one acquired slot's cache.
    pub fn cache(&self, idx: usize) -> &KvCache {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        &self.caches[idx]
    }

    /// Distinct mutable borrows of several acquired slots at once, in the
    /// order requested — the shape [`TransformerLM::decode_step_batch`]
    /// needs, where `caches[i]` pairs with `tokens[i]`. Panics if any index
    /// is repeated or not acquired. Only two small index vectors are built
    /// here (negligible next to a decode step); the KV buffers themselves
    /// are never copied, moved, or reallocated.
    ///
    /// [`TransformerLM::decode_step_batch`]: crate::model::TransformerLM::decode_step_batch
    pub fn caches_mut(&mut self, idxs: &[usize]) -> Vec<&mut KvCache> {
        let in_use = &self.in_use;
        let mut by_pos: Vec<Option<&mut KvCache>> = self
            .caches
            .iter_mut()
            .enumerate()
            .map(|(i, c)| in_use[i].then_some(c))
            .collect();
        idxs.iter()
            .map(|&i| {
                by_pos
                    .get_mut(i)
                    .and_then(Option::take)
                    .unwrap_or_else(|| panic!("KV slot {i} not acquired or repeated"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("tiny").unwrap()
    }

    #[test]
    fn pool_is_bounded_and_reusable() {
        let mut p = KvPool::new(&cfg(), 3);
        assert_eq!(p.slots(), 3);
        assert_eq!(p.available(), 3);
        assert_eq!(p.pages_total(), 3, "degenerate arena: one page per slot");
        let a = p.acquire(1).unwrap();
        let b = p.acquire(1).unwrap();
        let c = p.acquire(1).unwrap();
        assert_eq!(p.available(), 0);
        assert!(p.acquire(1).is_none(), "exhausted pool must refuse");
        p.acquire_page(a);
        p.cache_len_bump(a, 5);
        p.release(a);
        assert_eq!(p.available(), 1);
        assert_eq!(p.pages_free(), 1, "released pages return to the free list");
        let a2 = p.acquire(1).unwrap();
        assert_eq!(p.cache(a2).len, 0, "reused slot starts empty");
        assert_eq!(p.cache(a2).pages_held(), 0, "reused slot starts pageless");
        assert_ne!(b, c);
        assert_eq!(p.occupied(), 3);
    }

    impl KvPool {
        /// Test helper: simulate a used cache.
        fn cache_len_bump(&mut self, idx: usize, len: usize) {
            assert!(self.in_use[idx]);
            self.caches[idx].len = len;
        }
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut p = KvPool::new(&cfg(), 2);
        let a = p.acquire(1).unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    #[should_panic(expected = "exceeding its reservation")]
    fn page_acquire_beyond_reservation_panics() {
        // seq_len 64, page_size 16 → 4 pages per full sequence.
        let mut p = KvPool::with_pages(&cfg(), 2, 16, 8);
        let a = p.acquire(2).unwrap();
        p.acquire_page(a);
        p.acquire_page(a);
        p.acquire_page(a); // third page on a 2-page reservation
    }

    #[test]
    fn reservations_gate_admission_before_slots_do() {
        // 4 slots but only 4 pages: one full-sequence reservation (4 pages
        // at page_size 16, seq_len 64) starves admission even though three
        // slots stay free.
        let mut p = KvPool::with_pages(&cfg(), 4, 16, 4);
        let a = p.acquire(4).unwrap();
        assert_eq!(p.available(), 3);
        assert!(!p.can_admit(1));
        assert!(p.acquire(1).is_none(), "no unreserved pages left");
        p.release(a);
        assert!(p.can_admit(4));
        assert!(p.acquire(1).is_some());
    }

    #[test]
    #[should_panic(expected = "not acquired")]
    fn caches_mut_rejects_unacquired() {
        let mut p = KvPool::new(&cfg(), 2);
        let _ = p.caches_mut(&[0]);
    }

    #[test]
    fn caches_mut_preserves_request_order() {
        let mut p = KvPool::new(&cfg(), 4);
        let s: Vec<usize> = (0..4).map(|_| p.acquire(1).unwrap()).collect();
        p.cache_len_bump(s[2], 7);
        // Request in a non-monotone order; returned borrows must match it.
        let got = p.caches_mut(&[s[2], s[0], s[3]]);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].len, 7, "first borrow must be the slot asked for first");
        assert_eq!(got[1].len, 0);
    }

    #[test]
    fn memory_is_constant_across_churn() {
        let mut p = KvPool::with_pages(&cfg(), 2, 8, 16);
        let bytes = p.memory_bytes();
        assert!(bytes > 0);
        for _ in 0..10 {
            let a = p.acquire(3).unwrap();
            p.acquire_page(a);
            p.acquire_page(a);
            assert_eq!(p.memory_bytes(), bytes, "pages move, bytes don't");
            p.release(a);
        }
        assert_eq!(p.memory_bytes(), bytes, "churn must not allocate");
        assert_eq!(p.pages_free(), 16, "all pages back after churn");
    }

    #[test]
    fn ensure_page_attaches_only_when_needed() {
        let mut p = KvPool::with_pages(&cfg(), 1, 8, 8);
        let a = p.acquire(2).unwrap();
        p.ensure_page(a);
        assert_eq!(p.cache(a).pages_held(), 1);
        p.ensure_page(a); // len 0 < allocated 8: no-op
        assert_eq!(p.cache(a).pages_held(), 1);
        p.cache_len_bump(a, 8);
        p.ensure_page(a);
        assert_eq!(p.cache(a).pages_held(), 2, "full first page demands the second");
    }

    #[test]
    fn share_fork_reclaim_roundtrip() {
        // seq_len 64, page_size 16 → 4 pages per full sequence.
        let mut p = KvPool::with_pages(&cfg(), 3, 16, 12);
        let bytes = p.memory_bytes();
        let donor = p.acquire(4).unwrap();
        p.acquire_page(donor);
        p.acquire_page(donor);
        // Publish the first page: it leaves the donor's ownership AND its
        // reservation, freeing that headroom for other joiners.
        let reserved_before = p.pages_reserved();
        let page = p.share_page(donor, 0);
        assert_eq!(p.pages_shared(), 1);
        assert_eq!(p.pages_reserved(), reserved_before - 1);
        assert_eq!(p.memory_bytes(), bytes, "sharing must not change arena bytes");

        // A joiner maps it for free and forks when it must write.
        let joiner = p.acquire(2).unwrap();
        p.attach_shared(joiner, Arc::clone(&page));
        assert_eq!(p.cache(joiner).shared_pages_held(), 1);
        let free_before = p.pages_free();
        p.fork_page(joiner, 0);
        assert_eq!(p.pages_free(), free_before - 1, "fork consumes one free page");
        assert_eq!(p.cache(joiner).owned_pages_held(), 1);
        assert_eq!(p.memory_bytes(), bytes);

        // Releases drop references; the index (this test) holds the last
        // one, and reclaiming returns the page to the free list.
        p.release(donor);
        p.release(joiner);
        assert_eq!(Arc::strong_count(&page), 1);
        assert_eq!(p.pages_free(), 11);
        p.reclaim_shared(page);
        assert_eq!(p.pages_shared(), 0);
        assert_eq!(p.pages_free(), 12, "all pages home after reclaim");
        assert_eq!(p.memory_bytes(), bytes);
    }

    // The audit (and therefore these tests) only exists in debug builds;
    // `--release --all-targets` must still compile, so the gate is on the
    // tests too, not just the method.
    #[test]
    #[cfg(debug_assertions)]
    fn audit_holds_across_share_fork_release_churn() {
        let mut p = KvPool::with_pages(&cfg(), 3, 16, 12);
        p.audit();
        let donor = p.acquire(4).unwrap();
        p.acquire_page(donor);
        p.acquire_page(donor);
        p.audit();
        let page = p.share_page(donor, 0);
        p.audit();
        let joiner = p.acquire(2).unwrap();
        p.attach_shared(joiner, Arc::clone(&page));
        p.audit();
        p.fork_page(joiner, 0);
        p.audit();
        p.release(donor);
        p.release(joiner);
        p.audit();
        p.reclaim_shared(page);
        p.audit();
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "page conservation broken")]
    fn audit_catches_a_leaked_page() {
        let mut p = KvPool::with_pages(&cfg(), 2, 16, 8);
        let a = p.acquire(2).unwrap();
        p.acquire_page(a);
        // Corrupt the arena the way a bookkeeping bug would: a page leaves
        // the cache without returning to the free list.
        let _leaked = p.caches[a].take_pages();
        p.audit();
    }

    #[test]
    #[cfg(debug_assertions)]
    fn audit_holds_across_a_preemption_lifecycle() {
        // Evict → requeue → readmit, exactly as `Engine::preempt_for`
        // drives the pool: the victim's owned pages all return to the
        // free list and its reservation entry drops in the same release,
        // while the page it published stays alive in the index and is
        // re-attachable after readmission.
        let mut p = KvPool::with_pages(&cfg(), 3, 16, 8);
        let victim = p.acquire(3).unwrap();
        p.acquire_page(victim);
        p.acquire_page(victim);
        let published = p.share_page(victim, 0);
        p.audit();

        let free_before = p.pages_free();
        p.release(victim); // the eviction
        p.audit();
        assert_eq!(p.pages_free(), free_before + 1, "victim's owned page came home");
        assert_eq!(p.pages_reserved(), 0, "victim's reservation entry dropped");
        assert_eq!(p.pages_shared(), 1, "published page survives the eviction");

        // Readmission: a fresh reservation maps the surviving shared page
        // and recomputes the rest into newly owned pages.
        let again = p.acquire(2).unwrap();
        p.attach_shared(again, Arc::clone(&published));
        p.acquire_page(again);
        p.audit();

        p.release(again);
        p.reclaim_shared(published);
        p.audit();
        assert_eq!(p.pages_free(), 8, "clean drain after the preemption round trip");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "page conservation broken")]
    fn audit_catches_a_leaked_victim_page() {
        // A buggy eviction path that detaches the victim's pages without
        // handing them back to the free list must trip the conservation
        // audit on the very step — even though the release itself then
        // completes "cleanly" from the slot ledger's point of view.
        let mut p = KvPool::with_pages(&cfg(), 2, 16, 8);
        let victim = p.acquire(2).unwrap();
        p.acquire_page(victim);
        let _lost = p.caches[victim].take_pages();
        p.release(victim); // slot freed, but one page never came home
        p.audit();
    }

    #[test]
    #[should_panic(expected = "still mapped")]
    fn reclaiming_a_mapped_page_panics() {
        let mut p = KvPool::with_pages(&cfg(), 2, 16, 8);
        let donor = p.acquire(2).unwrap();
        p.acquire_page(donor);
        let page = p.share_page(donor, 0);
        // The donor still maps the page: the index may not reclaim it.
        p.reclaim_shared(page);
    }

    #[test]
    fn shared_pages_gate_admission_headroom() {
        // 8 pages; a 4-page resident plus 2 shared pages leaves headroom
        // for a 2-page joiner but not a 3-page one.
        let mut p = KvPool::with_pages(&cfg(), 4, 16, 8);
        let donor = p.acquire(4).unwrap();
        for _ in 0..4 {
            p.acquire_page(donor);
        }
        let s0 = p.share_page(donor, 0);
        let s1 = p.share_page(donor, 1);
        assert_eq!(p.pages_reserved(), 2, "sharing shrank the reservation");
        assert_eq!(p.pages_shared(), 2);
        assert!(p.can_admit(4), "8 − 2 shared − 2 reserved = 4");
        assert!(!p.can_admit(5));
        p.release(donor);
        p.reclaim_shared(s0);
        p.reclaim_shared(s1);
        assert!(p.can_admit(8));
    }

    #[test]
    fn acquire_release_conserves_slots_and_pages_prop() {
        check("kv pool conserves slots and pages", 50, |g| {
            let c = cfg();
            let slots = g.usize_range(1, 6);
            let page_size = [1, 4, 16, c.seq_len][g.usize_range(0, 4)];
            let per_seq = c.seq_len.div_ceil(page_size);
            let total = per_seq + g.usize_range(0, 2 * per_seq * slots);
            let mut p = KvPool::with_pages(&c, slots, page_size, total);
            let mut held: Vec<usize> = Vec::new();
            // Simulates the prefix index: the out-of-slot holders of
            // shared pages.
            let mut index: Vec<Arc<KvPage>> = Vec::new();
            for _ in 0..60 {
                match g.usize_range(0, 6) {
                    0 => {
                        let want = g.usize_range(1, per_seq + 1);
                        let admissible = p.can_admit(want);
                        if let Some(idx) = p.acquire(want) {
                            assert!(admissible, "acquire succeeded past can_admit");
                            assert!(!held.contains(&idx), "slot handed out twice");
                            held.push(idx);
                        } else {
                            assert!(
                                held.len() == slots || !admissible,
                                "refused while slots and pages were free"
                            );
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let idx = held[g.usize_range(0, held.len())];
                            if p.cache(idx).owned_pages_held() < p.reserved[idx] {
                                p.acquire_page(idx);
                            }
                        }
                    }
                    2 => {
                        // Publish: convert the first still-owned page of
                        // some resident (sharing proceeds front to back,
                        // like prefix publication).
                        if !held.is_empty() {
                            let idx = held[g.usize_range(0, held.len())];
                            let first_owned = p.cache(idx).shared_pages_held();
                            if first_owned < p.cache(idx).pages_held()
                                && !p.cache(idx).page_is_shared(first_owned)
                            {
                                index.push(p.share_page(idx, first_owned));
                            }
                        }
                    }
                    3 => {
                        // Map a published page into a fresh (pageless)
                        // resident.
                        if !held.is_empty() && !index.is_empty() {
                            let idx = held[g.usize_range(0, held.len())];
                            let page = &index[g.usize_range(0, index.len())];
                            if p.cache(idx).pages_held() == 0 {
                                p.attach_shared(idx, Arc::clone(page));
                            }
                        }
                    }
                    4 => {
                        // CoW fork of some mapped shared page, reservation
                        // permitting.
                        if !held.is_empty() {
                            let idx = held[g.usize_range(0, held.len())];
                            let cache = p.cache(idx);
                            let shared_at = (0..cache.pages_held())
                                .find(|&i| cache.page_is_shared(i));
                            if let Some(i) = shared_at {
                                if cache.owned_pages_held() < p.reserved[idx] {
                                    p.fork_page(idx, i);
                                }
                            }
                        }
                    }
                    _ => {
                        if g.bool() && !index.is_empty() {
                            // Index eviction: only sole-referenced pages
                            // may be reclaimed.
                            let i = g.usize_range(0, index.len());
                            if Arc::strong_count(&index[i]) == 1 {
                                p.reclaim_shared(index.swap_remove(i));
                            }
                        } else if !held.is_empty() {
                            let i = g.usize_range(0, held.len());
                            p.release(held.swap_remove(i));
                        }
                    }
                }
                assert_eq!(p.occupied(), held.len());
                assert_eq!(p.available() + p.occupied(), slots);
                let owned: usize =
                    held.iter().map(|&i| p.cache(i).owned_pages_held()).sum();
                assert_eq!(
                    p.pages_free() + owned + p.pages_shared(),
                    total,
                    "pages leaked"
                );
                assert_eq!(p.pages_shared(), index.len(), "index out of sync");
                assert!(
                    held.iter().all(|&i| p.cache(i).owned_pages_held() <= p.reserved[i]),
                    "owned past reservation"
                );
                assert!(
                    p.pages_reserved() + p.pages_shared() <= total,
                    "over-reserved against shared headroom"
                );
            }
            for idx in held {
                p.release(idx);
            }
            for page in index {
                assert_eq!(Arc::strong_count(&page), 1, "drain left a mapping alive");
                p.reclaim_shared(page);
            }
            assert_eq!(p.pages_free(), total, "pages leaked after full drain");
            assert_eq!(p.pages_reserved(), 0);
            assert_eq!(p.pages_shared(), 0);
        });
    }
}
