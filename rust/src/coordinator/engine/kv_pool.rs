//! The paged KV arena: a fixed pool of preallocated [`KvPage`]s plus
//! per-slot [`KvCache`] shells (page tables).
//!
//! Every page and every shell is allocated once at engine startup, so
//! sequence join/leave and mid-flight growth never allocate or free KV
//! buffers on the hot path, and KV memory is bounded by configuration
//! (`pages × n_layers × 2 × page_size × d_model × 4 B`) rather than by
//! offered load. A joining sequence takes a slot plus a **worst-case page
//! reservation** (`ceil(min(len + gen − 1, seq_len) / page_size)` — the
//! final sampled token is never written back, so `len + gen − 1` is the
//! most KV positions a sequence can touch); pages are attached on demand
//! as the sequence grows and all returned to the free list at retirement.
//!
//! The reservation is what makes mid-flight growth deadlock-free:
//! admission only succeeds while `Σ reservations ≤ total pages`, and a
//! resident sequence never holds more pages than it reserved, so
//! `free pages = total − Σ held ≥ Σ reserved − Σ held ≥ reserved_i −
//! held_i ≥ 1` whenever sequence *i* needs its next page — an acquired
//! slot can always run to retirement without waiting on another sequence.
//!
//! Slots hand out plain `usize` indices; the pool tracks which are in use
//! and panics on double-release, on touching a slot that was never
//! acquired, or on a sequence outgrowing its reservation — the engine's
//! bookkeeping is an invariant, not a recoverable condition.
//!
//! The whole-cache arena of PR 3 is the degenerate configuration
//! `page_size == seq_len, pages == slots` ([`KvPool::new`]): every
//! reservation is exactly one page, so admission reduces to slot
//! availability and each resident cache is one contiguous buffer.

use crate::config::ModelConfig;
use crate::model::{KvCache, KvPage};

/// Fixed-size paged arena of reusable KV storage.
pub struct KvPool {
    caches: Vec<KvCache>,
    in_use: Vec<bool>,
    free: Vec<usize>,
    free_pages: Vec<KvPage>,
    total_pages: usize,
    page_size: usize,
    reserved: Vec<usize>,
    reserved_total: usize,
}

impl KvPool {
    /// Whole-cache degenerate arena: `slots` slots, one `seq_len`-sized
    /// page per slot. Byte-for-byte the PR 3 behavior.
    pub fn new(cfg: &ModelConfig, slots: usize) -> KvPool {
        KvPool::with_pages(cfg, slots, cfg.seq_len, slots)
    }

    /// Paged arena: `slots` sequence shells over a shared free list of
    /// `pages` pages of `page_size` positions each. All allocation happens
    /// here; acquire/release only move indices and page buffers.
    pub fn with_pages(cfg: &ModelConfig, slots: usize, page_size: usize, pages: usize) -> KvPool {
        assert!(slots > 0, "KV pool needs at least one slot");
        let page_size = page_size.clamp(1, cfg.seq_len);
        let per_seq = cfg.seq_len.div_ceil(page_size);
        assert!(
            pages >= per_seq,
            "KV pool needs at least {per_seq} pages of {page_size} (one full sequence)"
        );
        KvPool {
            caches: (0..slots).map(|_| KvCache::paged(cfg, page_size)).collect(),
            in_use: vec![false; slots],
            free: (0..slots).rev().collect(),
            free_pages: (0..pages).map(|_| KvPage::new(cfg, page_size)).collect(),
            total_pages: pages,
            page_size,
            reserved: vec![0; slots],
            reserved_total: 0,
        }
    }

    /// Total slot count (the configured bound on resident sequences).
    pub fn slots(&self) -> usize {
        self.caches.len()
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by sequences.
    pub fn occupied(&self) -> usize {
        self.caches.len() - self.free.len()
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the arena (the configured bound on KV positions).
    pub fn pages_total(&self) -> usize {
        self.total_pages
    }

    /// Pages on the free list.
    pub fn pages_free(&self) -> usize {
        self.free_pages.len()
    }

    /// Pages attached to resident sequences.
    pub fn pages_held(&self) -> usize {
        self.total_pages - self.free_pages.len()
    }

    /// Pages promised to resident sequences (held + not yet attached).
    pub fn pages_reserved(&self) -> usize {
        self.reserved_total
    }

    /// Pages a sequence spanning `positions` KV positions needs.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.page_size)
    }

    /// Whether a joiner reserving `need` pages can be admitted now: a free
    /// slot plus unreserved page headroom.
    pub fn can_admit(&self, need: usize) -> bool {
        !self.free.is_empty() && self.total_pages - self.reserved_total >= need
    }

    /// Resident KV memory of the whole arena in bytes (constant for the
    /// pool's lifetime — this is the "bounded by config" number).
    pub fn memory_bytes(&self) -> usize {
        self.caches.iter().map(KvCache::memory_bytes).sum::<usize>()
            + self.free_pages.iter().map(KvPage::memory_bytes).sum::<usize>()
    }

    /// Take a free slot and reserve `reserve_pages` pages for its whole
    /// lifetime, or `None` when no slot is free or the unreserved page
    /// headroom is too small. The returned shell is empty (`len == 0`, no
    /// pages) and ready for [`KvPool::acquire_page`] + prefill.
    pub fn acquire(&mut self, reserve_pages: usize) -> Option<usize> {
        assert!(
            (1..=self.total_pages).contains(&reserve_pages),
            "reservation of {reserve_pages} pages outside 1..={}",
            self.total_pages
        );
        if self.total_pages - self.reserved_total < reserve_pages {
            return None;
        }
        let idx = self.free.pop()?;
        debug_assert!(!self.in_use[idx], "free list handed out an in-use slot");
        debug_assert_eq!(self.caches[idx].len, 0, "released slot was not reset");
        debug_assert_eq!(self.caches[idx].pages_held(), 0, "released slot kept pages");
        self.in_use[idx] = true;
        self.reserved[idx] = reserve_pages;
        self.reserved_total += reserve_pages;
        Some(idx)
    }

    /// Attach the next page to an acquired slot, from the free list.
    /// Panics if the slot would exceed its reservation (an engine
    /// admission bug) — the free list can never be empty below that bound
    /// (see the module docs for the invariant).
    pub fn acquire_page(&mut self, idx: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        assert!(
            self.caches[idx].pages_held() < self.reserved[idx],
            "KV slot {idx} exceeding its reservation of {} pages",
            self.reserved[idx]
        );
        let page = self.free_pages.pop().expect("free pages despite reservation headroom");
        self.caches[idx].push_page(page);
    }

    /// Attach a page to `idx` iff its next written position has no backing
    /// page yet — the engine's acquire-on-demand step before each
    /// prefill/decode batch.
    pub fn ensure_page(&mut self, idx: usize) {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        if self.caches[idx].needs_page() {
            self.acquire_page(idx);
        }
    }

    /// Return a slot to the arena: every attached page goes back to the
    /// free list, the reservation is dropped, and the shell resets for the
    /// next sequence. Panics on double release.
    pub fn release(&mut self, idx: usize) {
        assert!(self.in_use[idx], "double release of KV slot {idx}");
        self.free_pages.extend(self.caches[idx].take_pages());
        self.reserved_total -= self.reserved[idx];
        self.reserved[idx] = 0;
        self.in_use[idx] = false;
        self.free.push(idx);
    }

    /// Borrow one acquired slot's cache.
    pub fn cache(&self, idx: usize) -> &KvCache {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        &self.caches[idx]
    }

    /// Distinct mutable borrows of several acquired slots at once, in the
    /// order requested — the shape [`TransformerLM::decode_step_batch`]
    /// needs, where `caches[i]` pairs with `tokens[i]`. Panics if any index
    /// is repeated or not acquired. Only two small index vectors are built
    /// here (negligible next to a decode step); the KV buffers themselves
    /// are never copied, moved, or reallocated.
    ///
    /// [`TransformerLM::decode_step_batch`]: crate::model::TransformerLM::decode_step_batch
    pub fn caches_mut(&mut self, idxs: &[usize]) -> Vec<&mut KvCache> {
        let in_use = &self.in_use;
        let mut by_pos: Vec<Option<&mut KvCache>> = self
            .caches
            .iter_mut()
            .enumerate()
            .map(|(i, c)| in_use[i].then_some(c))
            .collect();
        idxs.iter()
            .map(|&i| {
                by_pos
                    .get_mut(i)
                    .and_then(Option::take)
                    .unwrap_or_else(|| panic!("KV slot {i} not acquired or repeated"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("tiny").unwrap()
    }

    #[test]
    fn pool_is_bounded_and_reusable() {
        let mut p = KvPool::new(&cfg(), 3);
        assert_eq!(p.slots(), 3);
        assert_eq!(p.available(), 3);
        assert_eq!(p.pages_total(), 3, "degenerate arena: one page per slot");
        let a = p.acquire(1).unwrap();
        let b = p.acquire(1).unwrap();
        let c = p.acquire(1).unwrap();
        assert_eq!(p.available(), 0);
        assert!(p.acquire(1).is_none(), "exhausted pool must refuse");
        p.acquire_page(a);
        p.cache_len_bump(a, 5);
        p.release(a);
        assert_eq!(p.available(), 1);
        assert_eq!(p.pages_free(), 1, "released pages return to the free list");
        let a2 = p.acquire(1).unwrap();
        assert_eq!(p.cache(a2).len, 0, "reused slot starts empty");
        assert_eq!(p.cache(a2).pages_held(), 0, "reused slot starts pageless");
        assert_ne!(b, c);
        assert_eq!(p.occupied(), 3);
    }

    impl KvPool {
        /// Test helper: simulate a used cache.
        fn cache_len_bump(&mut self, idx: usize, len: usize) {
            assert!(self.in_use[idx]);
            self.caches[idx].len = len;
        }
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut p = KvPool::new(&cfg(), 2);
        let a = p.acquire(1).unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    #[should_panic(expected = "exceeding its reservation")]
    fn page_acquire_beyond_reservation_panics() {
        // seq_len 64, page_size 16 → 4 pages per full sequence.
        let mut p = KvPool::with_pages(&cfg(), 2, 16, 8);
        let a = p.acquire(2).unwrap();
        p.acquire_page(a);
        p.acquire_page(a);
        p.acquire_page(a); // third page on a 2-page reservation
    }

    #[test]
    fn reservations_gate_admission_before_slots_do() {
        // 4 slots but only 4 pages: one full-sequence reservation (4 pages
        // at page_size 16, seq_len 64) starves admission even though three
        // slots stay free.
        let mut p = KvPool::with_pages(&cfg(), 4, 16, 4);
        let a = p.acquire(4).unwrap();
        assert_eq!(p.available(), 3);
        assert!(!p.can_admit(1));
        assert!(p.acquire(1).is_none(), "no unreserved pages left");
        p.release(a);
        assert!(p.can_admit(4));
        assert!(p.acquire(1).is_some());
    }

    #[test]
    #[should_panic(expected = "not acquired")]
    fn caches_mut_rejects_unacquired() {
        let mut p = KvPool::new(&cfg(), 2);
        let _ = p.caches_mut(&[0]);
    }

    #[test]
    fn caches_mut_preserves_request_order() {
        let mut p = KvPool::new(&cfg(), 4);
        let s: Vec<usize> = (0..4).map(|_| p.acquire(1).unwrap()).collect();
        p.cache_len_bump(s[2], 7);
        // Request in a non-monotone order; returned borrows must match it.
        let got = p.caches_mut(&[s[2], s[0], s[3]]);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].len, 7, "first borrow must be the slot asked for first");
        assert_eq!(got[1].len, 0);
    }

    #[test]
    fn memory_is_constant_across_churn() {
        let mut p = KvPool::with_pages(&cfg(), 2, 8, 16);
        let bytes = p.memory_bytes();
        assert!(bytes > 0);
        for _ in 0..10 {
            let a = p.acquire(3).unwrap();
            p.acquire_page(a);
            p.acquire_page(a);
            assert_eq!(p.memory_bytes(), bytes, "pages move, bytes don't");
            p.release(a);
        }
        assert_eq!(p.memory_bytes(), bytes, "churn must not allocate");
        assert_eq!(p.pages_free(), 16, "all pages back after churn");
    }

    #[test]
    fn ensure_page_attaches_only_when_needed() {
        let mut p = KvPool::with_pages(&cfg(), 1, 8, 8);
        let a = p.acquire(2).unwrap();
        p.ensure_page(a);
        assert_eq!(p.cache(a).pages_held(), 1);
        p.ensure_page(a); // len 0 < allocated 8: no-op
        assert_eq!(p.cache(a).pages_held(), 1);
        p.cache_len_bump(a, 8);
        p.ensure_page(a);
        assert_eq!(p.cache(a).pages_held(), 2, "full first page demands the second");
    }

    #[test]
    fn acquire_release_conserves_slots_and_pages_prop() {
        check("kv pool conserves slots and pages", 50, |g| {
            let c = cfg();
            let slots = g.usize_range(1, 6);
            let page_size = [1, 4, 16, c.seq_len][g.usize_range(0, 4)];
            let per_seq = c.seq_len.div_ceil(page_size);
            let total = per_seq + g.usize_range(0, 2 * per_seq * slots);
            let mut p = KvPool::with_pages(&c, slots, page_size, total);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..40 {
                match g.usize_range(0, 3) {
                    0 => {
                        let want = g.usize_range(1, per_seq + 1);
                        let admissible = p.can_admit(want);
                        if let Some(idx) = p.acquire(want) {
                            assert!(admissible, "acquire succeeded past can_admit");
                            assert!(!held.contains(&idx), "slot handed out twice");
                            held.push(idx);
                        } else {
                            assert!(
                                held.len() == slots || !admissible,
                                "refused while slots and pages were free"
                            );
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let idx = held[g.usize_range(0, held.len())];
                            if p.cache(idx).pages_held() < p.reserved[idx] {
                                p.acquire_page(idx);
                            }
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let i = g.usize_range(0, held.len());
                            p.release(held.swap_remove(i));
                        }
                    }
                }
                assert_eq!(p.occupied(), held.len());
                assert_eq!(p.available() + p.occupied(), slots);
                assert_eq!(p.pages_free() + p.pages_held(), total, "pages leaked");
                assert!(p.pages_held() <= p.pages_reserved(), "held past reservation");
                assert!(p.pages_reserved() <= total, "over-reserved");
            }
            for idx in held {
                p.release(idx);
            }
            assert_eq!(p.pages_free(), total, "pages leaked after full drain");
            assert_eq!(p.pages_reserved(), 0);
        });
    }
}
