//! The KV-slot arena: a fixed pool of preallocated [`KvCache`] buffers.
//!
//! Every slot is allocated once at engine startup, so sequence join/leave
//! never allocates or frees KV buffers on the hot path, and KV memory is
//! bounded by configuration (`slots × n_layers × 2 × seq_len × d_model ×
//! 4 B`) rather than by offered load. Slots hand out plain `usize` indices; the pool
//! tracks which are in use and panics on double-release or on touching a
//! slot that was never acquired — the engine's slot bookkeeping is an
//! invariant, not a recoverable condition.

use crate::config::ModelConfig;
use crate::model::KvCache;

/// Fixed-size arena of reusable KV caches.
pub struct KvPool {
    caches: Vec<KvCache>,
    in_use: Vec<bool>,
    free: Vec<usize>,
}

impl KvPool {
    /// Preallocate `slots` caches sized for `cfg`. All allocation happens
    /// here; [`KvPool::acquire`]/[`KvPool::release`] only move indices.
    pub fn new(cfg: &ModelConfig, slots: usize) -> KvPool {
        assert!(slots > 0, "KV pool needs at least one slot");
        KvPool {
            caches: (0..slots).map(|_| KvCache::new(cfg)).collect(),
            in_use: vec![false; slots],
            free: (0..slots).rev().collect(),
        }
    }

    /// Total slot count (the configured bound).
    pub fn slots(&self) -> usize {
        self.caches.len()
    }

    /// Slots currently free.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by sequences.
    pub fn occupied(&self) -> usize {
        self.caches.len() - self.free.len()
    }

    /// Resident KV memory of the whole arena in bytes (constant for the
    /// pool's lifetime — this is the "bounded by config" number).
    pub fn memory_bytes(&self) -> usize {
        self.caches.iter().map(KvCache::memory_bytes).sum()
    }

    /// Take a free slot, or `None` when the arena is fully occupied. The
    /// returned cache is empty (`len == 0`) and ready for prefill.
    pub fn acquire(&mut self) -> Option<usize> {
        let idx = self.free.pop()?;
        debug_assert!(!self.in_use[idx], "free list handed out an in-use slot");
        debug_assert_eq!(self.caches[idx].len, 0, "released slot was not reset");
        self.in_use[idx] = true;
        Some(idx)
    }

    /// Return a slot to the arena, resetting its cache for the next
    /// sequence. Panics on double release.
    pub fn release(&mut self, idx: usize) {
        assert!(self.in_use[idx], "double release of KV slot {idx}");
        self.caches[idx].reset_for_reuse();
        self.in_use[idx] = false;
        self.free.push(idx);
    }

    /// Borrow one acquired slot's cache.
    pub fn cache(&self, idx: usize) -> &KvCache {
        assert!(self.in_use[idx], "KV slot {idx} not acquired");
        &self.caches[idx]
    }

    /// Distinct mutable borrows of several acquired slots at once, in the
    /// order requested — the shape [`TransformerLM::decode_step_batch`]
    /// needs, where `caches[i]` pairs with `tokens[i]`. Panics if any index
    /// is repeated or not acquired. Only two small index vectors are built
    /// here (negligible next to a decode step); the KV buffers themselves
    /// are never copied, moved, or reallocated.
    ///
    /// [`TransformerLM::decode_step_batch`]: crate::model::TransformerLM::decode_step_batch
    pub fn caches_mut(&mut self, idxs: &[usize]) -> Vec<&mut KvCache> {
        let in_use = &self.in_use;
        let mut by_pos: Vec<Option<&mut KvCache>> = self
            .caches
            .iter_mut()
            .enumerate()
            .map(|(i, c)| in_use[i].then_some(c))
            .collect();
        idxs.iter()
            .map(|&i| {
                by_pos
                    .get_mut(i)
                    .and_then(Option::take)
                    .unwrap_or_else(|| panic!("KV slot {i} not acquired or repeated"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("tiny").unwrap()
    }

    #[test]
    fn pool_is_bounded_and_reusable() {
        let mut p = KvPool::new(&cfg(), 3);
        assert_eq!(p.slots(), 3);
        assert_eq!(p.available(), 3);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        let c = p.acquire().unwrap();
        assert_eq!(p.available(), 0);
        assert!(p.acquire().is_none(), "exhausted pool must refuse");
        p.cache_len_bump(a, 5);
        p.release(a);
        assert_eq!(p.available(), 1);
        let a2 = p.acquire().unwrap();
        assert_eq!(p.cache(a2).len, 0, "reused slot starts empty");
        assert_ne!(b, c);
        assert_eq!(p.occupied(), 3);
    }

    impl KvPool {
        /// Test helper: simulate a used cache.
        fn cache_len_bump(&mut self, idx: usize, len: usize) {
            assert!(self.in_use[idx]);
            self.caches[idx].len = len;
        }
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut p = KvPool::new(&cfg(), 2);
        let a = p.acquire().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    #[should_panic(expected = "not acquired")]
    fn caches_mut_rejects_unacquired() {
        let mut p = KvPool::new(&cfg(), 2);
        let _ = p.caches_mut(&[0]);
    }

    #[test]
    fn caches_mut_preserves_request_order() {
        let mut p = KvPool::new(&cfg(), 4);
        let s: Vec<usize> = (0..4).map(|_| p.acquire().unwrap()).collect();
        p.cache_len_bump(s[2], 7);
        // Request in a non-monotone order; returned borrows must match it.
        let got = p.caches_mut(&[s[2], s[0], s[3]]);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].len, 7, "first borrow must be the slot asked for first");
        assert_eq!(got[1].len, 0);
    }

    #[test]
    fn memory_is_constant_across_churn() {
        let mut p = KvPool::new(&cfg(), 2);
        let bytes = p.memory_bytes();
        assert!(bytes > 0);
        for _ in 0..10 {
            let a = p.acquire().unwrap();
            p.release(a);
        }
        assert_eq!(p.memory_bytes(), bytes, "churn must not allocate");
    }

    #[test]
    fn acquire_release_never_loses_slots_prop() {
        check("kv pool conserves slots", 50, |g| {
            let slots = g.usize_range(1, 6);
            let mut p = KvPool::new(&cfg(), slots);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..30 {
                if g.bool() {
                    if let Some(idx) = p.acquire() {
                        assert!(!held.contains(&idx), "slot handed out twice");
                        held.push(idx);
                    } else {
                        assert_eq!(held.len(), slots, "refused while slots were free");
                    }
                } else if !held.is_empty() {
                    let i = g.usize_range(0, held.len());
                    p.release(held.swap_remove(i));
                }
                assert_eq!(p.occupied(), held.len());
                assert_eq!(p.available() + p.occupied(), slots);
            }
        });
    }
}
