//! Scheduling types for the continuous-batching engine: the admission
//! queue ([`Batcher`] — the surviving piece of the old static batcher), the
//! admission policy, and the per-sequence in-flight state.
//!
//! Everything here is pure bookkeeping (no model, no threads), so the
//! admission behavior is unit-testable in isolation; the model-touching
//! step loop lives in [`super::Engine`].

use crate::util::trace;
use std::collections::VecDeque;
use std::time::Instant;

/// An inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub enqueued: Instant,
    /// Per-request generation budget; `None` ⇒ the server-wide
    /// `gen_tokens` default. The engine consumes it in the retire check
    /// and in the paged-arena reservation formula
    /// `ceil(min(len + gen − 1, seq_len) / page_size)`, so a short-budget
    /// request reserves fewer KV pages and admits alongside bigger ones.
    pub gen_tokens: Option<usize>,
    /// Opt into shared-prefix KV reuse (the default). When `false` this
    /// request neither maps published prefix pages at admission nor
    /// publishes its own — useful for privacy-sensitive prompts and for
    /// the bit-identity gates that compare shared vs unshared runs.
    pub share_prefix: bool,
    /// Generation stops early the moment any of these tokens is emitted;
    /// the stop token itself is included in the output (so the response is
    /// a prefix of the unstopped generation) and the response reports
    /// [`ResponseStatus::StoppedAtToken`].
    pub stop_tokens: Vec<usize>,
}

impl Request {
    /// A request with the server-default generation budget, enqueued now.
    pub fn new(id: u64, prompt: Vec<usize>) -> Request {
        trace::instant_args("request_enqueued", &[("id", id as f64)]);
        Request {
            id,
            prompt,
            enqueued: Instant::now(),
            gen_tokens: None,
            share_prefix: true,
            stop_tokens: Vec::new(),
        }
    }

    /// Attach a per-request generation budget.
    pub fn with_budget(mut self, gen_tokens: usize) -> Request {
        self.gen_tokens = Some(gen_tokens);
        self
    }

    /// Attach per-request stop tokens.
    pub fn with_stop_tokens(mut self, stop_tokens: Vec<usize>) -> Request {
        self.stop_tokens = stop_tokens;
        self
    }

    /// Opt this request out of shared-prefix KV reuse.
    pub fn without_prefix_sharing(mut self) -> Request {
        self.share_prefix = false;
        self
    }

    /// The generation budget this request runs under, given the
    /// server-wide default.
    pub fn budget(&self, default_gen: usize) -> usize {
        self.gen_tokens.unwrap_or(default_gen)
    }
}

/// How a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    /// Served to its full generation budget.
    Complete,
    /// The prompt exceeded the model's `seq_len`; the request was rejected
    /// without prefill instead of being silently truncated.
    Truncated,
    /// Generation stopped because the KV cache filled (`seq_len` reached)
    /// before the generation budget did — truncated-by-memory, not done.
    /// Clients see fewer tokens than they asked for and can tell this
    /// apart from a budget-complete response.
    CapacityStopped,
    /// Generation ended because a [`Request::stop_tokens`] entry was
    /// emitted before the budget ran out. The stop token is the last
    /// output token. Takes precedence over `Complete` when the stop fires
    /// exactly on the budget's final token — the stop predicate matched,
    /// whatever the budget said.
    StoppedAtToken,
}

/// Per-step admission order for queued requests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// First come, first served.
    #[default]
    Fcfs,
    /// Shortest prompt first (FIFO among equals) — favors fast first
    /// tokens for cheap requests under a backlog, at the cost of strict
    /// fairness.
    ShortestPrompt,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> anyhow::Result<AdmissionPolicy> {
        match s {
            "fcfs" => Ok(AdmissionPolicy::Fcfs),
            "shortest" => Ok(AdmissionPolicy::ShortestPrompt),
            other => anyhow::bail!("unknown admission policy '{other}' (fcfs|shortest)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fcfs => "fcfs",
            AdmissionPolicy::ShortestPrompt => "shortest",
        }
    }
}

/// The admission queue: requests wait here until the engine has a free KV
/// slot. (This is what remains of the old dynamic batcher — batch *shape*
/// is no longer decided here; the engine re-forms its decode batch every
/// step from whatever sequences are resident.)
#[derive(Default)]
pub struct Batcher {
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Remove every queued request matching `pred`, preserving FIFO order
    /// among the kept ones — the engine's slot-free fast path: requests
    /// that can be answered without a KV slot (rejections, trivially
    /// empty completions) must not wait behind a full arena. The common
    /// no-match case is a single allocation-free scan, so calling this
    /// every engine step is cheap under a backlog; `pred` must be pure
    /// (it runs twice on matching queues).
    pub fn take_where(&mut self, mut pred: impl FnMut(&Request) -> bool) -> Vec<Request> {
        if !self.queue.iter().any(&mut pred) {
            return Vec::new();
        }
        let mut taken = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            if pred(&r) {
                taken.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.queue = kept;
        taken
    }

    /// Index of the next request `policy` would admit, if any.
    fn next_index(&self, policy: AdmissionPolicy) -> Option<usize> {
        match policy {
            AdmissionPolicy::Fcfs => (!self.queue.is_empty()).then_some(0),
            AdmissionPolicy::ShortestPrompt => self
                .queue
                .iter()
                .enumerate()
                .min_by_key(|(i, r)| (r.prompt.len(), *i))
                .map(|(i, _)| i),
        }
    }

    /// Remove the next request under `policy`, if any.
    pub fn pop(&mut self, policy: AdmissionPolicy) -> Option<Request> {
        let idx = self.next_index(policy)?;
        self.queue.remove(idx)
    }

    /// The request `policy` would admit next, without removing it — the
    /// engine inspects it (prefix match, page-need computation, index
    /// eviction under pressure) before committing to the admission.
    /// `next_index` is deterministic, so a [`Batcher::pop`] with no
    /// intervening queue mutation removes exactly this request.
    pub fn peek(&self, policy: AdmissionPolicy) -> Option<&Request> {
        self.next_index(policy).map(|i| &self.queue[i])
    }

    /// Remove the next request under `policy` only if `admit` accepts it.
    /// A rejected head blocks this admission pass rather than being
    /// skipped: later (smaller) requests never jump an earlier one that is
    /// waiting for KV pages, so a big request cannot be starved by a
    /// stream of small ones — and because the head's worst-case page need
    /// is bounded by one full sequence (which the pool is required to
    /// hold), it always fits once enough residents retire.
    pub fn pop_where(
        &mut self,
        policy: AdmissionPolicy,
        admit: impl FnOnce(&Request) -> bool,
    ) -> Option<Request> {
        let idx = self.next_index(policy)?;
        if admit(&self.queue[idx]) {
            self.queue.remove(idx)
        } else {
            None
        }
    }
}

/// One in-flight sequence: its KV slot, prefill cursor, last logits,
/// generated tokens, and resolved generation budget.
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<usize>,
    /// Index into the engine's [`super::KvPool`].
    pub slot: usize,
    /// Next prompt position to prefill; `== prompt.len()` once decoding.
    /// The prefix-reuse admission path starts this past the shared pages
    /// (the tokens whose KV already exists are never re-prefilled).
    pub next_prefill: usize,
    /// Logits from this sequence's latest decode step.
    pub logits: Vec<f32>,
    pub out: Vec<usize>,
    /// Tokens to generate — the per-request budget, or the server default
    /// resolved at admission (the engine's retire check reads this).
    pub budget: usize,
    /// Shared-prefix participation, carried from the request.
    pub share_prefix: bool,
    /// Prompt pages this sequence has published to the prefix index so
    /// far (the publish cursor — pages `0..published` are done).
    pub published: usize,
    /// Stop tokens, carried from the request (the engine's retire check
    /// reads these next to the budget).
    pub stop_tokens: Vec<usize>,
    pub enqueued: Instant,
    /// When the engine admitted this sequence into its KV slot (stamped in
    /// [`Sequence::new`]); `admitted − enqueued` is the queue wait the
    /// serve layer summarizes.
    pub admitted: Instant,
    pub first_token_at: Option<Instant>,
}

impl Sequence {
    pub fn new(req: Request, slot: usize, vocab: usize, default_gen: usize) -> Sequence {
        let budget = req.budget(default_gen);
        Sequence {
            id: req.id,
            prompt: req.prompt,
            slot,
            next_prefill: 0,
            logits: vec![0.0; vocab],
            out: Vec::new(),
            budget,
            share_prefix: req.share_prefix,
            published: 0,
            stop_tokens: req.stop_tokens,
            enqueued: req.enqueued,
            admitted: Instant::now(),
            first_token_at: None,
        }
    }

    /// Still consuming prompt tokens?
    pub fn prefilling(&self) -> bool {
        self.next_prefill < self.prompt.len()
    }

    /// True when the most recent output token is one of this request's
    /// stop tokens — the retire check's token predicate, evaluated next to
    /// the budget.
    pub fn stopped_at_token(&self) -> bool {
        self.out.last().is_some_and(|t| self.stop_tokens.contains(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len])
    }

    #[test]
    fn budget_resolves_against_default() {
        let r = req(0, 2);
        assert_eq!(r.budget(16), 16, "no per-request budget ⇒ server default");
        let r = req(1, 2).with_budget(3);
        assert_eq!(r.budget(16), 3);
        let r = req(2, 2).with_budget(0);
        assert_eq!(r.budget(16), 0, "explicit zero budget is honored");
    }

    #[test]
    fn fcfs_pops_in_arrival_order() {
        let mut b = Batcher::default();
        for i in 0..5 {
            b.push(req(i, (5 - i) as usize));
        }
        let ids: Vec<u64> = (0..5).map(|_| b.pop(AdmissionPolicy::Fcfs).unwrap().id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(b.pop(AdmissionPolicy::Fcfs).is_none());
    }

    #[test]
    fn shortest_prompt_pops_cheapest_first_fifo_on_ties() {
        let mut b = Batcher::default();
        b.push(req(0, 4));
        b.push(req(1, 2));
        b.push(req(2, 2));
        b.push(req(3, 1));
        let ids: Vec<u64> =
            (0..4).map(|_| b.pop(AdmissionPolicy::ShortestPrompt).unwrap().id).collect();
        assert_eq!(ids, vec![3, 1, 2, 0], "shortest first, FIFO among equal lengths");
    }

    #[test]
    fn pop_conserves_requests() {
        let mut b = Batcher::default();
        for i in 0..7 {
            b.push(req(i, i as usize % 3));
        }
        let mut seen = std::collections::HashSet::new();
        while let Some(r) = b.pop(AdmissionPolicy::ShortestPrompt) {
            assert!(seen.insert(r.id), "request popped twice");
        }
        assert_eq!(seen.len(), 7);
        assert!(b.is_empty());
    }

    #[test]
    fn take_where_extracts_and_preserves_order() {
        let mut b = Batcher::default();
        for i in 0..6 {
            b.push(req(i, i as usize));
        }
        let taken = b.take_where(|r| r.prompt.len() % 2 == 0);
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(b.len(), 3);
        let rest: Vec<u64> = (0..3).map(|_| b.pop(AdmissionPolicy::Fcfs).unwrap().id).collect();
        assert_eq!(rest, vec![1, 3, 5], "kept requests stay FIFO");
    }

    #[test]
    fn pop_where_blocks_on_rejected_head() {
        let mut b = Batcher::default();
        b.push(req(0, 9)); // big head
        b.push(req(1, 1)); // small follower
        // FCFS: the big head is rejected and the small one must NOT jump it.
        assert!(b.pop_where(AdmissionPolicy::Fcfs, |r| r.prompt.len() <= 4).is_none());
        assert_eq!(b.len(), 2, "rejected head stays queued");
        let got = b.pop_where(AdmissionPolicy::Fcfs, |r| r.prompt.len() <= 9).unwrap();
        assert_eq!(got.id, 0);
        // ShortestPrompt: the policy's own pick is the one gated.
        b.push(req(2, 5));
        let got = b.pop_where(AdmissionPolicy::ShortestPrompt, |_| true).unwrap();
        assert_eq!(got.id, 1, "shortest prompt admitted first");
        assert!(b.pop_where(AdmissionPolicy::ShortestPrompt, |_| false).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [AdmissionPolicy::Fcfs, AdmissionPolicy::ShortestPrompt] {
            assert_eq!(AdmissionPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(AdmissionPolicy::parse("lifo").is_err());
    }
}
